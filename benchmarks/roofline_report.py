"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--mesh pod16x16]
"""
import argparse
import json
from pathlib import Path

from repro.configs import ARCH_NAMES, SHAPES

ADIR = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
PEAK = 197e12


def fmt_cell(rec):
    if rec["status"] == "skipped":
        return None
    r = rec["roofline"]
    h = rec["hlo"]
    mfu = rec["model_flops_per_dev"] / (max(r["bound_s"], 1e-12) * PEAK)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "compute_s": r["compute_s"], "memory_s": r["memory_s"],
        "collective_s": r["collective_s"], "dominant": r["dominant"],
        "bound_s": r["bound_s"], "mfu": mfu,
        "ratio": rec["useful_flops_ratio"],
        "gib": rec["memory"]["per_device_bytes"] / 2**30,
        "fits": rec["memory"]["fits_hbm"],
        "flops": h["flops"], "hbm": h["hbm_bytes"], "wire": h["wire_bytes_total"],
        "compile_s": rec.get("compile_s", 0),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--kind", default="roofline", choices=["roofline", "dryrun"])
    args = ap.parse_args()

    rows = []
    for arch in ARCH_NAMES:
        for cell in SHAPES:
            f = ADIR / f"{arch}__{cell.name}__{args.mesh}.json"
            if not f.exists():
                continue
            rec = json.loads(f.read_text())
            if rec["status"] == "skipped":
                rows.append({"arch": arch, "shape": cell.name, "skip": True})
            elif rec["status"] == "ok":
                rows.append(fmt_cell(rec))

    if args.kind == "roofline":
        print("| arch | shape | compute s | memory s | collective s | dominant "
              "| bound s | MFU@bound | useful-FLOP ratio | GiB/dev | fits |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("skip"):
                print(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                      f"(full attention @524k) | — | — | — | — | — |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
                  f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
                  f"**{r['dominant']}** | {r['bound_s']:.3f} | {r['mfu']*100:.1f}% | "
                  f"{r['ratio']:.2f} | {r['gib']:.1f} | "
                  f"{'yes' if r['fits'] else 'NO'} |")
    else:
        print("| arch | shape | FLOPs/dev | HBM B/dev | wire B/dev | GiB/dev "
              "| fits | compile s |")
        print("|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r.get("skip"):
                print(f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — |")
                continue
            print(f"| {r['arch']} | {r['shape']} | {r['flops']:.3e} | "
                  f"{r['hbm']:.3e} | {r['wire']:.3e} | {r['gib']:.1f} | "
                  f"{'yes' if r['fits'] else 'NO'} | {r['compile_s']:.0f} |")


if __name__ == "__main__":
    main()
