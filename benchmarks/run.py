"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Outputs ``name,us_per_call,derived`` CSV rows:
  * table1_latency_*   — HLS latency/II analog for the generated vecmul
                         accelerator (paper Table 1): per-module latency from
                         the analytic model + measured interpret-mode wall time.
  * table2_resources_* — resource-utilization analog (paper Table 2): VMEM
                         (BRAM), MXU (DSP), VPU-lane alignment per kernel.
  * kernel_*           — interpret-mode microbenchmarks vs jnp oracles.
  * dse_convergence    — the SECDA-DSE loop on a reduced workload: best
                         roofline bound per iteration (paper's envisioned
                         §5.2 search-efficiency evaluation).
  * roofline_*         — per (arch x shape) roofline bound from the committed
                         production-mesh dry-run artifacts.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


N_TIMING_RUNS = 3


def _time(fn, n=N_TIMING_RUNS):
    """Min-of-``n`` wall time in microseconds: one warm call (amortizes
    compile/tracing), then ``n`` individually timed calls. Min, not mean —
    a single GC or recompilation hiccup can inflate a mean forever but can
    never lower a min (same policy as ``repro.launch.measure``)."""
    fn()  # compile/warm
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times) * 1e6


# ---------------------------------------------------------------------------
def bench_table1_vecmul_latency():
    """Paper Table 1: latency (cycles) + II per module of the generated
    element-wise vecmul accelerator."""
    import jax
    import jax.numpy as jnp

    from repro.core.llm_stack import LLMStack
    from repro.core.llm_client import MockLLM
    from repro.kernels import ops, ref
    from repro.kernels.resource_model import vecmul_resources

    spec = ("take two input vectors X and Y, both of length L ... perform an "
            "element-wise multiplication ... loading should be performed using "
            "a load module ... written back to main memory using a store module")
    design, _ = LLMStack(client=MockLLM()).generate_accelerator(spec, length=4096)
    assert design["kernel"] == "vecmul"
    L, block = design["parameters"]["L"], design["parameters"]["block"]

    x = jnp.arange(L, dtype=jnp.float32)
    y = jnp.ones((L,), jnp.float32) * 2
    z = ops.vecmul(x, y, block=block)
    assert jnp.allclose(z, ref.vecmul_ref(x, y))
    res = vecmul_resources(L, block, itemsize=4)
    # paper modules: Send (load), Compute, Recv (store); our pipeline streams
    # them per block — report per-module cycle estimates
    stream_cycles = res.est_cycles_per_block
    emit("table1_latency_send_cycles", stream_cycles, "per-block HBM->VMEM load")
    emit("table1_latency_compute_cycles", max(block / (8 * 128), 1.0),
         "VPU elementwise, 8x128 lanes")
    emit("table1_latency_recv_cycles", stream_cycles, "per-block VMEM->HBM store")
    emit("table1_latency_total_us", res.est_latency_us,
         f"L={L} block={block} (HLS total-latency analog)")
    wall = _time(lambda: jax.block_until_ready(ops.vecmul(x, y, block=block)))
    emit("table1_vecmul_interpret_wall", wall,
         f"CPU interpret-mode wall time, min of n={N_TIMING_RUNS}")


def bench_table2_resources():
    """Paper Table 2: resource utilization per kernel candidate."""
    from repro.kernels.resource_model import (flash_attention_resources,
                                              rmsnorm_resources,
                                              ssd_scan_resources,
                                              vecmul_resources)

    for r in (
        vecmul_resources(4096, 1024, itemsize=4),
        rmsnorm_resources(8192, 4096, 128),
        flash_attention_resources(1, 4096, 4096, 32, 8, 128, 512, 512),
        ssd_scan_resources(8, 4096, 48, 64, 128, 256),
    ):
        emit(f"table2_resources_{r.name}_vmem_pct", 100.0 * r.vmem_util,
             f"BRAM-analog; feasible={r.feasible} mxu={r.mxu_aligned} "
             f"vpu={r.vpu_aligned} ({r.notes})")


def bench_kernels():
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    k = jax.random.key(0)
    x = jax.random.normal(k, (8, 512, 256))
    w = jnp.ones((256,))
    emit("kernel_rmsnorm_us", _time(lambda: jax.block_until_ready(
        ops.rmsnorm(x, w))), f"interpret mode, [4096,256], n={N_TIMING_RUNS}")
    q = 0.3 * jax.random.normal(k, (1, 256, 8, 64))
    kk = 0.3 * jax.random.normal(k, (1, 256, 4, 64))
    emit("kernel_flash_attention_us", _time(lambda: jax.block_until_ready(
        ops.flash_attention(q, kk, kk, block_q=128, block_k=128))),
        f"interpret, s=256 h=8 gqa, n={N_TIMING_RUNS}")
    xs = 0.5 * jax.random.normal(k, (2, 128, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(k, (2, 128, 4)))
    A = -jnp.exp(jax.random.normal(k, (4,)) * 0.3)
    B = 0.3 * jax.random.normal(k, (2, 128, 32))
    emit("kernel_ssd_scan_us", _time(lambda: jax.block_until_ready(
        ops.ssd_scan(xs, dt, A, B, B, chunk=32)[0])),
        f"interpret, s=128, n={N_TIMING_RUNS}")


def bench_dse_convergence(fast: bool):
    """SECDA-DSE loop: best bound vs iteration on a reduced workload."""
    import dataclasses

    import repro.configs as C
    from repro.configs import get_config, reduced
    from repro.configs.base import ShapeCell
    import repro.launch.dryrun as D
    import repro.core.evaluator as E

    tiny_cell = ShapeCell("train_4k", "train", 64, 8)
    C.SHAPE_BY_NAME = dict(C.SHAPE_BY_NAME, train_4k=tiny_cell)
    tiny = reduced(get_config("qwen3-0.6b"))
    D.get_config = lambda name: tiny
    D.SHAPE_BY_NAME = C.SHAPE_BY_NAME
    E.get_config = lambda name: tiny
    E.SHAPE_BY_NAME = C.SHAPE_BY_NAME

    import tempfile

    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.evaluator import Evaluator
    from repro.core.llm_client import MockLLM
    from repro.core.llm_stack import LLMStack
    from repro.core.loop import DSELoop
    from repro.launch.mesh import make_mesh

    with tempfile.TemporaryDirectory() as td:
        mesh = make_mesh((1, 1), ("data", "model"))
        db = CostDB(Path(td) / "db.jsonl")
        t0 = time.perf_counter()
        loop = DSELoop(
            evaluator=Evaluator(mesh, "bench1x1", artifact_dir=td), db=db,
            llm_stack=LLMStack(client=MockLLM(), db=db),
            cost_model=CostModel.create(in_dim=featurize({}, {}).shape[0]))
        rep = loop.run("qwen3-0.6b", "train_4k",
                       iterations=1 if fast else 3,
                       eval_budget=2, verbose=False)
        wall = (time.perf_counter() - t0) * 1e6
        base = rep.baseline.metrics.get("bound_s") or float("nan")
        best = rep.best.metrics.get("bound_s") if rep.best else float("nan")
        emit("dse_convergence_baseline_bound_s", base * 1e6, "expert initial design")
        emit("dse_convergence_best_bound_s", best * 1e6,
             f"after {len(rep.iterations)} iterations; x{rep.improvement():.3f}")
        emit("dse_convergence_wall", wall,
             f"{len(db.all())} designs evaluated (incl. negatives)")


def bench_roofline_tables():
    """Per (arch x shape) roofline bound from committed dry-run artifacts."""
    adir = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not adir.exists():
        emit("roofline_artifacts", 0.0, "missing: run repro.launch.dryrun first")
        return
    for f in sorted(adir.glob("*__pod16x16.json")):
        rec = json.loads(f.read_text())
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        emit(f"roofline_{rec['arch']}_{rec['shape']}", r["bound_s"] * 1e6,
             f"dom={r['dominant']} mfu@bound="
             f"{rec['model_flops_per_dev']/(max(r['bound_s'],1e-9)*197e12)*100:.1f}% "
             f"fits={rec['memory']['fits_hbm']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    bench_table1_vecmul_latency()
    bench_table2_resources()
    bench_kernels()
    bench_dse_convergence(args.fast)
    bench_roofline_tables()
    print(f"\n# {len(ROWS)} rows", file=sys.stderr)


if __name__ == "__main__":
    main()
