import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""DSE evaluation throughput: serial vs process-pool vs cached vs gated.

Default mode evaluates the same candidate set three ways and reports
evaluations/minute:

    serial    in-process compiles, cold cache
    parallel  evaluate_batch over a spawn process pool, cold cache
    cached    same batch again, warm content-addressed dry-run cache

``--gate`` instead runs the surrogate-gated-vs-ungated experiment: a warmup
slice of candidates is compiled to train the surrogate, then the remaining
candidates are evaluated twice — with and without the SurrogateGate — and
the benchmark reports compiles spent per incumbent improvement for each arm
(the gate's whole point is fewer compiles for the same best design).

``--ladder`` runs the promotion-ladder experiment: after the same warmup,
the warmup leaderboard heads are *measured* (tier 2, interpret mode), then
the remaining candidates are evaluated twice — once behind a plain
:class:`SurrogateGate` (whose annealing has no validation signal on the
tiny warmup DB, so its threshold stays at ``--gate-factor``) and once
behind a :class:`PromotionLadder` (whose annealing folds in the
offset-corrected prediction-vs-measured RMSE those wall clocks earned) —
and the benchmark reports tier-1 compiles per incumbent improvement for
each arm (the ladder's whole point is that wall-clock calibration tightens
tier-0 pruning, i.e. fewer compiles for the same best design).
``--bench-out`` writes the full auditable payload (BENCH_ladder.json).

``--transfer`` runs the cross-workload transfer experiment: a donor cell is
explored first, then a *fresh* cell is searched twice — cold (greedy, empty
DB) vs transfer-seeded (the donor's winners transplanted via the shared
DB) — and the benchmark reports each arm's best bound and compiles spent
(transfer's whole point is matching the cold arm's best design on fewer
compiles by skipping re-discovery).

``--pareto`` runs the multi-objective front-growth experiment: the same
candidate set is evaluated serially and after every evaluation the
benchmark records the Pareto front size and the exact hypervolume it
covers (objectives min-max normalized over the final row set, reference
1.1 per dimension). The committed artifact (BENCH_pareto.json via
``--bench-out``) pins the auditable "how fast did the front fill in"
curve that the scalar incumbent trajectory cannot express.

``--straggler`` runs the scheduling experiment: the same tiny grid is
orchestrated twice with shard 0 deliberately slowed (every evaluation
sleeps ``--straggler-sleep-s`` seconds, via the straggler prelude) — once
with the static ``--shard i/n`` cut, once with the dynamic ``--queue``
cell queue + work stealing — and the benchmark reports each arm's
wall-clock, the steal count, and whether the two merged leaderboards are
byte-identical (they must be; the queue's whole point is the same answer,
sooner, when one shard is slow).

Default uses a reduced (CPU-smoke) config so the benchmark finishes in
seconds; pass --full for the real registry config on the 2x4 mesh.

    PYTHONPATH=src python benchmarks/bench_dse_throughput.py --n 6 --workers 2
    PYTHONPATH=src python benchmarks/bench_dse_throughput.py --gate --n 10
    PYTHONPATH=src python benchmarks/bench_dse_throughput.py --ladder --n 12 \
        --bench-out BENCH_ladder.json

The XLA_FLAGS lines above MUST stay the first statements: jax locks the
device count at first init.
"""
import argparse
import json
import random
import shutil
import tempfile
import time
from pathlib import Path


def _tiny_patch(arch: str):
    """Swap the registry config/cell for reduced CPU-smoke versions."""
    import repro.configs as C
    from repro.configs import reduced
    from repro.configs.base import ShapeCell
    import repro.core.evaluator as E
    import repro.launch.dryrun as D

    tiny = reduced(C.get_config(arch))
    C.SHAPE_BY_NAME["train_4k"] = ShapeCell("train_4k", "train", 64, 8)
    for mod in (D, E):
        mod.get_config = lambda name: tiny
        mod.SHAPE_BY_NAME = C.SHAPE_BY_NAME


def _candidates(arch: str, shape: str, mesh, n: int, seed: int = 0):
    from repro.configs import SHAPE_BY_NAME
    from repro.core.design_space import PlanTemplate, baseline_point
    from repro.core.evaluator import get_config

    cfg, cell = get_config(arch), SHAPE_BY_NAME[shape]
    template = PlanTemplate(cfg, cell, dict(mesh.shape))
    seen, points = set(), []
    for p in ([baseline_point(cell, template)]
              + list(template.neighbors(baseline_point(cell, template)))
              + template.random_points(random.Random(seed), n)):
        if p.key() not in seen and template.validate(p)[0]:
            seen.add(p.key())
            points.append(p)
        if len(points) >= n:
            break
    return points


def _mode(label: str, evaluator, arch, shape, points) -> tuple:
    t0 = time.time()
    dps = evaluator.evaluate_batch(arch, shape, points)
    wall = time.time() - t0
    ok = sum(d.status == "ok" for d in dps)
    return {"mode": label, "n": len(points), "ok": ok,
            "wall_s": round(wall, 2),
            "evals_per_min": round(60.0 * len(points) / max(wall, 1e-9), 1)}, dps


def _bound_of(dps):
    ok = [d.metrics["bound_s"] for d in dps
          if d.status == "ok" and d.metrics.get("bound_s")]
    return min(ok) if ok else None


def _gate_mode(args, mesh, mesh_name, points, tmp: Path) -> list:
    """Gated vs ungated: same candidates, same incumbent, count compiles."""
    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.search import SurrogateGate

    n_warm = max(4, len(points) // 3)
    warmup, rest = points[:n_warm], points[n_warm:]
    if not rest:
        raise SystemExit(f"--gate needs --n > {n_warm} (warmup slice)")

    db = CostDB(tmp / "db.jsonl")
    warm_ev = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "w"),
                        cache=DryRunCache(tmp / "cw"),
                        max_workers=args.workers)
    db.append_many(warm_ev.evaluate_batch(args.arch, args.shape, warmup))
    incumbent = _bound_of(db.all())
    cm = CostModel.create(in_dim=featurize({}, {}).shape[0])
    # split=None: train on every warmup row — the tiny warmup DB can't
    # spare a val split, and this arm bypasses the calibration guard anyway
    loss = cm.pretrain(db, split=None)
    print(f"warmup: {len(warmup)} compiles, incumbent={incumbent}, "
          f"surrogate loss={loss:.3f}", flush=True)

    rows = []
    for label, gate in (
            ("ungated", None),
            # require_calibration=False: the warmup DB is far too small to
            # clear the guard; the benchmark demonstrates the mechanics
            ("gated", SurrogateGate(cm, factor=args.gate_factor,
                                    min_factor=args.gate_min_factor,
                                    require_calibration=False))):
        if gate is not None:
            gate.calibrate(db)
            print(f"gate: effective factor {gate.effective_factor:g} "
                  f"(configured {gate.factor:g})", flush=True)
        ev = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / label),
                       cache=DryRunCache(tmp / f"c_{label}"),
                       max_workers=args.workers)
        t0 = time.time()
        dps = ev.evaluate_batch(args.arch, args.shape, rest, gate=gate,
                                incumbent_bound=incumbent)
        best = _bound_of(dps)
        improvement = (incumbent / best) if (best and incumbent) else 1.0
        rows.append({
            "mode": label, "n": len(rest),
            "compiles": ev.compile_count, "pruned": ev.pruned_count,
            "wall_s": round(time.time() - t0, 2),
            "best_bound_s": best, "incumbent_bound_s": incumbent,
            "improvement_x": round(improvement, 4),
            "compiles_per_improvement": round(
                ev.compile_count / max(improvement, 1e-9), 2),
        })
        print(rows[-1], flush=True)
    u, g = rows
    print(f"gate verdict: {g['compiles']}/{u['compiles']} compiles "
          f"({g['pruned']} pruned) for improvement "
          f"x{g['improvement_x']} vs x{u['improvement_x']} ungated -> "
          f"{g['compiles_per_improvement']} vs "
          f"{u['compiles_per_improvement']} compiles/improvement")
    return rows


def _ladder_mode(args, mesh, mesh_name, points, tmp: Path) -> dict:
    """Promotion ladder vs single-factor gate: same candidates, same
    incumbent, count tier-1 compiles per incumbent improvement.

    Shared setup: a warmup slice is compiled (tier 1) to train the
    surrogate — ``split=None``, so no validation rows exist and plain-gate
    annealing has nothing to listen to — then the warmup leaderboard heads
    are measured (tier 2, interpret mode on CPU). Both arms then evaluate
    the remaining candidates behind an annealing gate:

      gate    SurrogateGate   — threshold stays at --gate-factor (the
              validation RMSE is unmeasurable on this DB)
      ladder  PromotionLadder — the offset-corrected prediction-vs-measured
              RMSE from the tier-2 rows anneals the threshold tighter

    Returns the full BENCH_ladder payload (arms + calibration + measured
    rows), written verbatim by ``--bench-out``."""
    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.design_space import PlanPoint
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.core.promotion import plan_promotions
    from repro.search import PromotionLadder, SurrogateGate

    n_warm = max(4, len(points) // 3)
    warmup, rest = points[:n_warm], points[n_warm:]
    if not rest:
        raise SystemExit(f"--ladder needs --n > {n_warm} (warmup slice)")

    db = CostDB(tmp / "db.jsonl")
    warm_ev = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "w"),
                        cache=DryRunCache(tmp / "cw"),
                        measured_cache=DryRunCache(tmp / "mw"),
                        max_workers=args.workers)
    db.append_many(warm_ev.evaluate_batch(args.arch, args.shape, warmup))
    incumbent = _bound_of(db.all())
    cm = CostModel.create(in_dim=featurize({}, {}).shape[0])
    loss = cm.pretrain(db, split=None)
    print(f"warmup: {len(warmup)} tier-1 compiles, incumbent={incumbent}, "
          f"surrogate loss={loss:.3f}", flush=True)

    # tier 2: promote and measure the warmup heads — these wall clocks are
    # the calibration evidence the ladder arm anneals on
    heads = db.winners(args.arch, args.shape, k=args.measure_top_k,
                       mesh=mesh_name)
    measured = []
    for head in plan_promotions(heads, set(), top_k=args.measure_top_k):
        point = PlanPoint(dims={k: v for k, v in head.point.items()
                                if k != "__key__"})
        dp = warm_ev.measure(args.arch, args.shape, point,
                             modeled_bound_s=head.metrics.get("bound_s"))
        db.append(dp)
        measured.append({
            "key": point.key(), "status": dp.status,
            "measured_us": dp.metrics.get("measured_us"),
            "modeled_bound_us": (head.metrics.get("bound_s") or 0.0) * 1e6,
            "backend": dp.metrics.get("backend")})
        print(f"measured: {measured[-1]}", flush=True)

    min_factor = (args.gate_min_factor if args.gate_min_factor is not None
                  else 1.2)
    calibration = None
    arms = []
    for label, gate in (
            # require_calibration=False on both arms: the warmup DB is far
            # too small to clear the guard; the experiment isolates the
            # *annealing signal* difference, not the guard
            ("gate", SurrogateGate(cm, factor=args.gate_factor,
                                   min_factor=min_factor,
                                   require_calibration=False)),
            ("ladder", PromotionLadder(cm, factor=args.gate_factor,
                                       min_factor=min_factor,
                                       require_calibration=False))):
        gate.calibrate(db)
        print(f"{label}: effective factor {gate.effective_factor:g} "
              f"(configured {gate.factor:g})", flush=True)
        ev = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / label),
                       cache=DryRunCache(tmp / f"c_{label}"),
                       max_workers=args.workers)
        t0 = time.time()
        dps = ev.evaluate_batch(args.arch, args.shape, rest, gate=gate,
                                incumbent_bound=incumbent)
        best = _bound_of(dps)
        improvement = (incumbent / best) if (best and incumbent) else 1.0
        arms.append({
            "mode": label, "n": len(rest),
            "compiles": ev.compile_count, "pruned": ev.pruned_count,
            "wall_s": round(time.time() - t0, 2),
            "best_bound_s": best, "incumbent_bound_s": incumbent,
            "improvement_x": round(improvement, 4),
            "compiles_per_improvement": round(
                ev.compile_count / max(improvement, 1e-9), 2),
            "effective_factor": round(gate.effective_factor, 4),
        })
        print(arms[-1], flush=True)
        if label == "ladder":
            calibration = {
                "measured_rmse": _num(gate.last_measured_rmse),
                "measured_n": gate.last_measured_n,
                "measured_offset": _num(gate.measured_offset),
                "val_rmse": _num(gate.last_rmse),
            }
    g, l = arms
    print(f"ladder verdict: {l['compiles']}/{g['compiles']} tier-1 compiles "
          f"({l['pruned']} vs {g['pruned']} pruned) for improvement "
          f"x{l['improvement_x']} vs x{g['improvement_x']} -> "
          f"{l['compiles_per_improvement']} vs "
          f"{g['compiles_per_improvement']} compiles/improvement "
          f"(effective factor {l['effective_factor']:g} vs "
          f"{g['effective_factor']:g})")
    return {
        "schema": "ladder-bench-v1",
        "generated_by": "PYTHONPATH=src python "
                        "benchmarks/bench_dse_throughput.py --ladder",
        "config": {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
                   "n": len(points), "warmup": len(warmup),
                   "measure_top_k": args.measure_top_k,
                   "gate_factor": args.gate_factor, "min_factor": min_factor,
                   "full": args.full},
        "warmup": {"tier1_compiles": len(warmup),
                   "incumbent_bound_s": incumbent,
                   "surrogate_loss": round(loss, 4)},
        "measured": measured,
        "calibration": calibration,
        "arms": arms,
        "verdict": {
            "gate_compiles_per_improvement": g["compiles_per_improvement"],
            "ladder_compiles_per_improvement": l["compiles_per_improvement"],
            "ladder_fewer_compiles_per_improvement":
                l["compiles_per_improvement"] < g["compiles_per_improvement"],
        },
    }


def _num(x):
    """NaN -> None so the BENCH JSON stays strictly spec-compliant."""
    return None if x is None or x != x else round(float(x), 6)


def _transfer_mode(args, mesh, mesh_name, tmp: Path) -> list:
    """Cold vs transfer-seeded search on a fresh cell, donor DB warm."""
    from repro.core.cost_db import CostDB
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.core.llm_client import MockLLM
    from repro.core.llm_stack import LLMStack
    from repro.core.loop import DSELoop
    from repro.search import make_strategy

    donor, target = args.arch, args.transfer_target
    budget = max(2, args.n // 3)

    def run_arm(label, arch, db, strategy):
        ev = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / label),
                       cache=DryRunCache(tmp / f"c_{label}"),
                       max_workers=args.workers)
        loop = DSELoop(evaluator=ev, db=db,
                       llm_stack=LLMStack(client=MockLLM(), db=db),
                       strategy=make_strategy(strategy))
        t0 = time.time()
        rep = loop.run(arch, args.shape, iterations=2, eval_budget=budget,
                       verbose=False)
        best = rep.best.metrics.get("bound_s") if rep.best else None
        return {"mode": label, "arch": arch, "strategy": strategy,
                "compiles": ev.compile_count, "best_bound_s": best,
                "improvement": round(rep.improvement(), 4),
                "wall_s": round(time.time() - t0, 2)}

    shared_db = CostDB(tmp / "shared_db.jsonl")
    rows = [run_arm("donor", donor, shared_db, "greedy")]
    print(rows[-1], flush=True)
    rows.append(run_arm("cold", target, CostDB(tmp / "cold_db.jsonl"),
                        "greedy"))
    print(rows[-1], flush=True)
    rows.append(run_arm("transfer", target, shared_db, "transfer"))
    print(rows[-1], flush=True)
    cold, xfer = rows[1], rows[2]
    print(f"transfer verdict: best {xfer['best_bound_s']} in "
          f"{xfer['compiles']} compiles vs cold {cold['best_bound_s']} in "
          f"{cold['compiles']} compiles "
          f"(donor knowledge {'helped' if (xfer['best_bound_s'] or 9e9) <= (cold['best_bound_s'] or 9e9) else 'did not transfer'})")
    return rows


def _kernels_mode(args, tmp: Path) -> dict:
    """Tuned-vs-default Pallas kernel tiles, through the real DSE engine.

    Runs a kernel campaign (``launch.kernel_cell``) over ``--kernels-list``,
    then times each cell's shipped-default tile config against the campaign
    winner with the measured tier (``measure_kernel_cell``: warm call, then
    min over timed runs, correctness re-checked against the ref oracle).
    Interpret-mode wall clocks on CPU are not production latencies, but
    they are real executions of the real kernels — the point of the
    committed artifact is the tuned-vs-default *pairing* plus the
    correctness audit, both reproducible anywhere."""
    from repro.core.design_space import KernelTemplate, baseline_kernel_point
    from repro.core.kernel_space import KERNEL_SHAPE_BY_NAME
    from repro.launch.kernel_cell import (resolve_kernel_grid,
                                          run_kernel_campaign)
    from repro.launch.measure import measure_kernel_cell

    kernels, shapes = resolve_kernel_grid(args.kernels_list, "all")
    if len(kernels) < 2:
        raise SystemExit(f"--kernels needs >= 2 kernels to compare, got "
                         f"{kernels}")
    summary = run_kernel_campaign(
        kernels, shapes, out_dir=tmp / "campaign", iterations=2,
        budget=max(2, args.n // 2), strategy="greedy", verbose=False)
    lb = json.loads((tmp / "campaign" / "leaderboard.json").read_text())

    cells = []
    for cell in lb:
        kshape = KERNEL_SHAPE_BY_NAME[cell["shape"]]
        default = dict(baseline_kernel_point(
            kshape, KernelTemplate(kshape)).dims)
        tuned = cell.get("best_point")
        if tuned is None:
            continue  # no gate-passing design: nothing to time
        rec_d = measure_kernel_cell(kshape, default, runs=3)
        rec_t = (rec_d if tuned == default
                 else measure_kernel_cell(kshape, tuned, runs=3))
        row = {
            "kernel": kshape.kernel, "shape": kshape.name,
            "dtype": kshape.dtype,
            "default_point": default, "tuned_point": tuned,
            "default_us": _num(rec_d.get("measured_s", float("nan")) * 1e6),
            "tuned_us": _num(rec_t.get("measured_s", float("nan")) * 1e6),
            # leaderboard bounds are already NaN-sanitized; _num's rounding
            # would flatten microsecond-scale values
            "tuned_bound_s": cell.get("bound_s"),
            "backend": rec_t.get("backend"),
            "default_status": rec_d["status"], "tuned_status": rec_t["status"],
            "max_abs_err": _num(rec_t.get("max_abs_err")),
            "tol": _num(rec_t.get("tol")),
        }
        if row["default_us"] and row["tuned_us"]:
            row["speedup_x"] = round(row["default_us"] / row["tuned_us"], 4)
        cells.append(row)
        print(row, flush=True)
    timed = [c for c in cells if c.get("speedup_x")]
    print(f"kernels verdict: {len(timed)}/{len(cells)} cells timed "
          f"tuned-vs-default across {len(kernels)} kernels; correctness "
          f"gate checked {summary['correctness']['checked']} candidates, "
          f"rejected {summary['correctness']['rejected']}")
    return {
        "schema": "kernels-bench-v1",
        "generated_by": "PYTHONPATH=src python "
                        "benchmarks/bench_dse_throughput.py --kernels",
        "config": {"kernels": kernels, "shapes": shapes,
                   "iterations": 2, "budget": max(2, args.n // 2),
                   "strategy": "greedy"},
        "campaign": {"evaluations": summary["evaluations"],
                     "compiles": summary["compiles"],
                     "correctness": summary["correctness"]},
        "cells": cells,
    }


def _pareto_mode(args, mesh, mesh_name, points, tmp: Path) -> dict:
    """Front growth under multi-objective ranking: evaluate the candidate
    set serially, then replay the evaluation order recording, after each
    design, the Pareto front size and the exact hypervolume the front
    covers. Objectives are min-max normalized over the *final* row set
    (so every trajectory entry shares one scale and the curve is
    monotone), with reference point 1.1 per dimension so boundary designs
    still contribute volume."""
    from repro.core.cost_db import MAXIMIZE_OBJECTIVES, pareto_rows
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.core.pareto import hypervolume

    ev = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "p"),
                   cache=DryRunCache(tmp / "cp"), max_workers=1)
    t0 = time.time()
    dps = ev.evaluate_batch(args.arch, args.shape, points)
    wall = time.time() - t0

    final = pareto_rows(dps)
    if not final:
        raise SystemExit("--pareto: no eligible rows — every candidate "
                         "failed or was pruned")
    keys = sorted({k for _, _, _, objs in final for k in objs})

    def vec(objs):
        return tuple(
            float("inf") if objs.get(k) is None
            else -float(objs[k]) if k in MAXIMIZE_OBJECTIVES
            else float(objs[k])
            for k in keys)

    finals = [vec(objs) for _, _, _, objs in final]
    lo = [min(v[i] for v in finals) for i in range(len(keys))]
    hi = [max(v[i] for v in finals) for i in range(len(keys))]

    def norm(v):
        return tuple(0.0 if hi[i] == lo[i] or v[i] == float("inf")
                     else (v[i] - lo[i]) / (hi[i] - lo[i])
                     for i in range(len(keys)))

    ref = tuple(1.1 for _ in keys)
    traj = []
    for i in range(len(dps)):
        ranked = pareto_rows(dps[:i + 1])
        front = [objs for _, rank, _, objs in ranked if rank == 0]
        hv = hypervolume([norm(vec(o)) for o in front], ref)
        traj.append({"eval": i + 1, "front_size": len(front),
                     "hypervolume": round(hv, 6)})
        print(traj[-1], flush=True)

    front_rows = [(d, crowd, objs) for d, rank, crowd, objs in final
                  if rank == 0]
    final_front = [{
        "point": {k: v for k, v in sorted(d.point.items())
                  if k != "__key__"},
        "objectives": {k: objs[k] for k in sorted(objs)},
        "crowding": None if crowd == float("inf") else round(crowd, 6),
    } for d, crowd, objs in front_rows]
    print(f"pareto verdict: {len(final_front)}-point front over "
          f"{len(keys)} objectives ({', '.join(keys)}) after "
          f"{len(dps)} evaluations; hypervolume "
          f"{traj[-1]['hypervolume']:g} in {wall:.1f}s")
    return {
        "schema": "pareto-bench-v1",
        "generated_by": "PYTHONPATH=src python "
                        "benchmarks/bench_dse_throughput.py --pareto",
        "config": {"arch": args.arch, "shape": args.shape, "mesh": mesh_name,
                   "n": len(points), "full": args.full},
        "objectives": keys,
        "normalization": {"lo": [_num(x) for x in lo],
                          "hi": [_num(x) for x in hi],
                          "ref": 1.1},
        "trajectory": traj,
        "final_front": final_front,
        "wall_s": round(wall, 2),
    }


def _straggler_mode(args, tmp: Path) -> list:
    """Static grid cut vs dynamic queue + stealing under one slow shard.

    Runs the orchestrator in subprocesses (the straggler prelude needs the
    shard processes' environment), so this arm never imports jax into the
    benchmark process itself."""
    import os
    import subprocess
    import sys

    repo = Path(__file__).resolve().parents[1]
    env = {**os.environ,
           "PYTHONPATH": str(repo / "src"),
           "REPRO_CAMPAIGN_PRELUDE": str(repo / "tests" / "ci"
                                         / "straggler_prelude.py"),
           "REPRO_TEST_STRAGGLER_SHARD": "0",
           "REPRO_TEST_EVAL_SLEEP_S": str(args.straggler_sleep_s)}
    rows = []
    for label, extra in (
            ("static", []),
            ("queue", ["--queue", "--steal-min-s", "4",
                       "--steal-factor", "2"])):
        out = tmp / label
        cmd = [sys.executable, "-m", "repro.launch.orchestrator",
               "--archs", "qwen3-0.6b,stablelm-3b",
               "--shapes", "train_4k,decode_32k", "--mesh", "tiny",
               "--shards", "2", "--iterations", "1", "--budget", "2",
               "--workers", "1", "--poll-interval", "0.2",
               "--out", str(out)] + extra
        t0 = time.time()
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=1200)
        wall = time.time() - t0
        if r.returncode != 0:
            raise SystemExit(f"{label} arm failed:\n{r.stdout}\n{r.stderr}")
        summary = json.loads((out / "summary.json").read_text())
        rows.append({"mode": label, "wall_s": round(wall, 1),
                     "steals": summary.get("steals"),
                     "restarts": summary.get("restarts"),
                     "cells": summary.get("cells")})
        print(rows[-1], flush=True)
    static, queue = rows
    same = ((tmp / "static" / "leaderboard.json").read_bytes()
            == (tmp / "queue" / "leaderboard.json").read_bytes())
    speed = static["wall_s"] / max(queue["wall_s"], 1e-9)
    print(f"straggler verdict: queue x{speed:.2f} vs static "
          f"({queue['steals']} steal(s)); leaderboards byte-identical: "
          f"{same}")
    if not same:
        raise SystemExit("leaderboard bytes diverged between static and "
                         "queue arms — scheduling must never change the "
                         "answer")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--n", type=int, default=6, help="candidate designs")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="real registry config instead of the reduced smoke config")
    ap.add_argument("--gate", action="store_true",
                    help="surrogate-gated vs ungated evaluation experiment")
    ap.add_argument("--gate-factor", type=float, default=2.0,
                    help="SurrogateGate prune factor for --gate")
    ap.add_argument("--gate-min-factor", type=float, default=None,
                    help="anneal the gate factor toward this as calibration "
                         "improves (see SurrogateGate.min_factor)")
    ap.add_argument("--ladder", action="store_true",
                    help="promotion ladder (measured-calibrated annealing) "
                         "vs single-factor gate experiment")
    ap.add_argument("--measure-top-k", type=int, default=3,
                    help="warmup heads promoted to the measured tier for "
                         "--ladder (the ladder arm needs at least "
                         "PromotionLadder.min_measured_points of them)")
    ap.add_argument("--bench-out", default=None,
                    help="write the committed BENCH JSON here "
                         "(BENCH_ladder.json for --ladder, "
                         "BENCH_pareto.json for --pareto, BENCH_dse.json "
                         "for the default throughput modes)")
    ap.add_argument("--transfer", action="store_true",
                    help="cold vs transfer-seeded search experiment")
    ap.add_argument("--transfer-target", default="stablelm-3b",
                    help="fresh cell arch for --transfer (donor is --arch)")
    ap.add_argument("--kernels", action="store_true",
                    help="kernel-cell experiment: campaign-tune Pallas "
                         "kernel tiles, then time tuned vs default configs "
                         "(emits BENCH_kernels.json via --bench-out)")
    ap.add_argument("--kernels-list", default="rmsnorm,vecmul",
                    help="comma-separated kernel names (or 'all') for "
                         "--kernels; needs >= 2")
    ap.add_argument("--pareto", action="store_true",
                    help="multi-objective front-growth experiment: front "
                         "size + hypervolume after every evaluation (emits "
                         "BENCH_pareto.json via --bench-out)")
    ap.add_argument("--straggler", action="store_true",
                    help="static --shard cut vs --queue work stealing with "
                         "one deliberately slowed shard")
    ap.add_argument("--straggler-sleep-s", type=float, default=10.0,
                    help="per-evaluation sleep injected into the slow "
                         "shard for --straggler (must dwarf one cold "
                         "compile, or the straggler finishes before the "
                         "fleet median exposes it)")
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()

    if args.straggler:
        # subprocess-only arm: keep jax (and the tiny patch) out of this
        # process — the shard subprocesses get theirs from the prelude
        tmp = Path(tempfile.mkdtemp(prefix="bench_straggler_"))
        try:
            rows = _straggler_mode(args, tmp)
            if args.out:
                Path(args.out).write_text(json.dumps(rows, indent=1))
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        return

    if args.kernels:
        # kernel cells never touch the plan registry: no tiny patch needed
        tmp = Path(tempfile.mkdtemp(prefix="bench_kernels_"))
        try:
            bench = _kernels_mode(args, tmp)
            if args.out:
                Path(args.out).write_text(json.dumps(bench["cells"],
                                                     indent=1))
            if args.bench_out:
                Path(args.bench_out).write_text(
                    json.dumps(bench, indent=1) + "\n")
                print(f"bench -> {args.bench_out}")
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
        return

    if not args.full:
        _tiny_patch(args.arch)

    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.launch.mesh import make_mesh

    mesh, mesh_name = make_mesh((2, 4), ("data", "model")), "small2x4"
    points = _candidates(args.arch, args.shape, mesh, args.n)
    print(f"benchmarking {len(points)} designs of {args.arch}/{args.shape} "
          f"on {mesh_name} (workers={args.workers})", flush=True)

    tmp = Path(tempfile.mkdtemp(prefix="bench_dse_"))
    rows = []
    try:
        if args.gate:
            rows = _gate_mode(args, mesh, mesh_name, points, tmp)
            if args.out:
                Path(args.out).write_text(json.dumps(rows, indent=1))
            return

        if args.ladder:
            bench = _ladder_mode(args, mesh, mesh_name, points, tmp)
            if args.out:
                Path(args.out).write_text(json.dumps(bench["arms"], indent=1))
            if args.bench_out:
                Path(args.bench_out).write_text(
                    json.dumps(bench, indent=1) + "\n")
                print(f"bench -> {args.bench_out}")
            return

        if args.transfer:
            rows = _transfer_mode(args, mesh, mesh_name, tmp)
            if args.out:
                Path(args.out).write_text(json.dumps(rows, indent=1))
            return

        if args.pareto:
            bench = _pareto_mode(args, mesh, mesh_name, points, tmp)
            if args.out:
                Path(args.out).write_text(
                    json.dumps(bench["trajectory"], indent=1))
            if args.bench_out:
                Path(args.bench_out).write_text(
                    json.dumps(bench, indent=1) + "\n")
                print(f"bench -> {args.bench_out}")
            return

        serial = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "a"),
                           cache=DryRunCache(tmp / "cache_serial"), max_workers=1)
        row, serial_dps = _mode("serial", serial, args.arch, args.shape, points)
        rows.append(row)
        print(rows[-1], flush=True)

        shared = DryRunCache(tmp / "cache_pool")
        par = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "b"),
                        cache=shared, max_workers=args.workers)
        rows.append(_mode("parallel", par, args.arch, args.shape, points)[0])
        print(rows[-1], flush=True)

        rows.append(_mode("cached", par, args.arch, args.shape, points)[0])
        rows[-1]["cache"] = shared.stats()
        print(rows[-1], flush=True)

        s, p, c = (r["wall_s"] for r in rows)
        print(f"speedup vs serial: parallel x{s / max(p, 0.01):.2f}, "
              f"cached x{s / max(c, 0.01):.0f}")
        print("note: pool workers each pay a fresh jax import; the pool wins "
              "when per-design compile time dominates that startup cost")
        if args.out:
            Path(args.out).write_text(json.dumps(rows, indent=1))
        if args.bench_out:
            # incumbent trajectory: cumulative best bound over the serial
            # evaluation order — the auditable "how fast did we converge"
            # curve the BENCH artifact exists to pin down
            traj, best = [], None
            for d in serial_dps:
                b = (d.metrics.get("bound_s") if d.status == "ok" else None)
                if b and (best is None or b < best):
                    best = b
                traj.append(best)
            bench = {
                "schema": "dse-bench-v1",
                "generated_by": "PYTHONPATH=src python "
                                "benchmarks/bench_dse_throughput.py",
                "config": {"arch": args.arch, "shape": args.shape,
                           "mesh": mesh_name, "n": len(points),
                           "workers": args.workers, "full": args.full},
                "modes": rows,
                "incumbent_by_eval_bound_s": traj,
            }
            Path(args.bench_out).write_text(json.dumps(bench, indent=1) + "\n")
            print(f"bench -> {args.bench_out}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
