import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""DSE evaluation throughput: serial vs process-pool vs cached.

Evaluates the same candidate set three ways and reports evaluations/minute:

    serial    in-process compiles, cold cache
    parallel  evaluate_batch over a spawn process pool, cold cache
    cached    same batch again, warm content-addressed dry-run cache

Default uses a reduced (CPU-smoke) config so the benchmark finishes in
seconds; pass --full for the real registry config on the 2x4 mesh.

    PYTHONPATH=src python benchmarks/bench_dse_throughput.py --n 6 --workers 2

The XLA_FLAGS lines above MUST stay the first statements: jax locks the
device count at first init.
"""
import argparse
import json
import random
import shutil
import tempfile
import time
from pathlib import Path


def _tiny_patch(arch: str):
    """Swap the registry config/cell for reduced CPU-smoke versions."""
    import repro.configs as C
    from repro.configs import reduced
    from repro.configs.base import ShapeCell
    import repro.core.evaluator as E
    import repro.launch.dryrun as D

    tiny = reduced(C.get_config(arch))
    C.SHAPE_BY_NAME["train_4k"] = ShapeCell("train_4k", "train", 64, 8)
    for mod in (D, E):
        mod.get_config = lambda name: tiny
        mod.SHAPE_BY_NAME = C.SHAPE_BY_NAME


def _candidates(arch: str, shape: str, mesh, n: int, seed: int = 0):
    from repro.configs import SHAPE_BY_NAME
    from repro.core.design_space import PlanTemplate, baseline_point
    from repro.core.evaluator import get_config

    cfg, cell = get_config(arch), SHAPE_BY_NAME[shape]
    template = PlanTemplate(cfg, cell, dict(mesh.shape))
    seen, points = set(), []
    for p in ([baseline_point(cell, template)]
              + list(template.neighbors(baseline_point(cell, template)))
              + template.random_points(random.Random(seed), n)):
        if p.key() not in seen and template.validate(p)[0]:
            seen.add(p.key())
            points.append(p)
        if len(points) >= n:
            break
    return points


def _mode(label: str, evaluator, arch, shape, points) -> dict:
    t0 = time.time()
    dps = evaluator.evaluate_batch(arch, shape, points)
    wall = time.time() - t0
    ok = sum(d.status == "ok" for d in dps)
    return {"mode": label, "n": len(points), "ok": ok,
            "wall_s": round(wall, 2),
            "evals_per_min": round(60.0 * len(points) / max(wall, 1e-9), 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--n", type=int, default=6, help="candidate designs")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="real registry config instead of the reduced smoke config")
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()

    if not args.full:
        _tiny_patch(args.arch)

    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.launch.mesh import make_mesh

    mesh, mesh_name = make_mesh((2, 4), ("data", "model")), "small2x4"
    points = _candidates(args.arch, args.shape, mesh, args.n)
    print(f"benchmarking {len(points)} designs of {args.arch}/{args.shape} "
          f"on {mesh_name} (workers={args.workers})", flush=True)

    tmp = Path(tempfile.mkdtemp(prefix="bench_dse_"))
    rows = []
    try:
        serial = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "a"),
                           cache=DryRunCache(tmp / "cache_serial"), max_workers=1)
        rows.append(_mode("serial", serial, args.arch, args.shape, points))
        print(rows[-1], flush=True)

        shared = DryRunCache(tmp / "cache_pool")
        par = Evaluator(mesh, mesh_name, artifact_dir=str(tmp / "b"),
                        cache=shared, max_workers=args.workers)
        rows.append(_mode("parallel", par, args.arch, args.shape, points))
        print(rows[-1], flush=True)

        rows.append(_mode("cached", par, args.arch, args.shape, points))
        rows[-1]["cache"] = shared.stats()
        print(rows[-1], flush=True)

        s, p, c = (r["wall_s"] for r in rows)
        print(f"speedup vs serial: parallel x{s / max(p, 0.01):.2f}, "
              f"cached x{s / max(c, 0.01):.0f}")
        print("note: pool workers each pay a fresh jax import; the pool wins "
              "when per-design compile time dominates that startup cost")
        if args.out:
            Path(args.out).write_text(json.dumps(rows, indent=1))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
