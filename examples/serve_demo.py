"""Serve a small model with batched requests (continuous-batching style).

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serve import step as serve_step
from repro.serve.batcher import Batcher
from repro.sharding.plan import ShardingPlan


def main():
    cfg = reduced(get_config("llama3-8b"))
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    plan = ShardingPlan(rules={})
    prefill = jax.jit(serve_step.make_prefill_step(cfg, plan, None))
    decode = jax.jit(serve_step.make_decode_step(cfg, plan, None))

    batcher = Batcher(cfg, params, prefill, decode,
                      init_cache=lambda b, ml: M.init_cache(cfg, b, ml),
                      max_batch=4, max_len=128)
    rng = np.random.default_rng(0)
    n_requests = 10
    for i in range(n_requests):
        batcher.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 24))),
                       max_new=12)
    print(f"submitted {n_requests} requests (prompt lens 4-24, 12 new tokens each)")
    done = batcher.run()
    s = batcher.stats
    print(f"served {s['requests']} requests, {s['tokens']} tokens in "
          f"{s['wall_s']:.2f}s -> {s['tok_per_s']:.1f} tok/s "
          f"({s['decode_steps']} decode steps)")
    lat = [r.t_done - r.t_submit for r in done]
    print(f"latency p50={np.median(lat)*1e3:.0f}ms p100={max(lat)*1e3:.0f}ms")
    sample = done[0]
    print(f"request 0: prompt[:6]={sample.prompt[:6].tolist()} -> out={sample.out}")


if __name__ == "__main__":
    main()
