"""Quickstart: train a tiny LM for a few steps on CPU, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serve import step as serve_step
from repro.sharding.plan import ShardingPlan
from repro.train import step as step_mod
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig


def main():
    cfg = reduced(get_config("qwen3-0.6b"))
    plan = ShardingPlan(rules={}, remat="none", zero1=False)
    state, _ = step_mod.init_train_state(cfg, jax.random.key(0), plan)
    step = jax.jit(step_mod.make_train_step(
        cfg, plan, None, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60)))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0))

    print(f"model: {cfg.name}  params={cfg.n_params()/1e6:.2f}M")
    first = last = None
    for i in range(60):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in data.batch(i).items()})
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 10 == 0:
            print(f"step {i:3d}  loss {loss:.4f}  lr {float(metrics['lr']):.2e}")
    print(f"loss: {first:.4f} -> {last:.4f} ({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training should reduce loss on structured synthetic data"

    # decode a few tokens from the trained model
    prefill = jax.jit(serve_step.make_prefill_step(cfg, plan, None))
    decode = jax.jit(serve_step.make_decode_step(cfg, plan, None))
    prompt = jnp.asarray(data.batch(999)["tokens"][:1, :8])
    cache = M.init_cache(cfg, 1, 64)
    logits, cache = prefill(state["params"], {"tokens": prompt}, cache)
    toks = []
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(8):
        toks.append(int(cur[0, 0]))
        logits, cache = decode(state["params"], {"tokens": cur}, cache)
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    print("generated:", toks)


if __name__ == "__main__":
    main()
