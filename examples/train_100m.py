"""End-to-end driver: train a ~100M-parameter LM with the full production
stack — sharding plan, AdamW+ZeRO, checkpointing, fault-tolerant trainer,
prefetched data pipeline.

    PYTHONPATH=src python examples/train_100m.py --steps 300        # full run
    PYTHONPATH=src python examples/train_100m.py --preset smoke     # CI-sized

Resume after interruption is automatic (latest committed checkpoint).
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.sharding.plan import ShardingPlan
from repro.train import checkpoint as ckpt
from repro.train import step as step_mod
from repro.train.data import DataConfig, Prefetcher, SyntheticLM
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

# ~106M params: 10L x d640 x ff2560, 32k vocab
CONFIG_100M = ArchConfig(
    name="repro-100m", family="dense", n_layers=10, d_model=640,
    n_heads=10, n_kv_heads=5, d_ff=2560, vocab=32000, d_head=64,
    rope_theta=10_000.0, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--preset", choices=["full", "smoke"], default="full")
    ap.add_argument("--ckpt", default="artifacts/ckpt_100m")
    args = ap.parse_args()

    cfg = CONFIG_100M
    if args.preset == "smoke":
        args.steps, args.seq, args.batch = 20, 64, 4

    print(f"training {cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{args.steps} steps x {args.batch}x{args.seq} tokens")

    plan = ShardingPlan(rules={}, remat="none", zero1=False, loss_chunk=0)
    opt = AdamWConfig(lr=6e-4, warmup_steps=20, total_steps=max(args.steps, 100))
    step = jax.jit(step_mod.make_train_step(cfg, plan, None, opt),
                   donate_argnums=(0,))

    start = ckpt.latest_step(args.ckpt)
    if start is not None:
        state, _ = step_mod.init_train_state(cfg, jax.random.key(0), plan)
        state, start, _ = ckpt.restore_checkpoint(args.ckpt, state)
        print(f"resuming from committed checkpoint at step {start}")
    else:
        state, _ = step_mod.init_train_state(cfg, jax.random.key(0), plan)
        start = 0

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, seed=0))
    trainer = Trainer(
        cfg, plan, step, state, data,
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 5, 10),
                      ckpt_dir=args.ckpt, log_every=5))
    out = trainer.run(start_step=start)
    losses = [h["loss"] for h in out["history"]]
    if losses:
        print(f"done: step {out['final_step']}, loss {losses[0]:.4f} -> "
              f"{losses[-1]:.4f}, median step time "
              f"{sorted(h['dt'] for h in out['history'])[len(losses)//2]*1e3:.0f}ms")


if __name__ == "__main__":
    main()
