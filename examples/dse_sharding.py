"""Run the full SECDA-DSE loop over a workload's execution-plan space.

This is the paper's Figure-1 loop on the TPU design space: Explorer
permutations + LLM-Stack (RAG + CoT) refinements, evaluated by the dry-run
'simulator', recorded in the cost DB, with LoRA fine-tuning of the surrogate.

    # reduced mesh (runs anywhere, ~2 min):
    PYTHONPATH=src python examples/dse_sharding.py

    # production pod mesh (what EXPERIMENTS.md §Perf uses):
    PYTHONPATH=src python -m repro.launch.dse --arch llama3-8b \
        --shape train_4k --mesh pod --iterations 4 --budget 3
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import tempfile
from pathlib import Path

from repro.core.cost_db import CostDB, featurize
from repro.core.cost_model import CostModel
from repro.core.evaluator import Evaluator
from repro.core.llm_client import MockLLM
from repro.core.llm_stack import LLMStack
from repro.core.loop import DSELoop
from repro.core.rag import CodeIndex
from repro.launch.mesh import make_mesh


def main():
    mesh = make_mesh((2, 4), ("data", "model"))
    with tempfile.TemporaryDirectory() as td:
        db = CostDB(Path(td) / "cost_db.jsonl")
        stack = LLMStack(
            client=MockLLM(), db=db,
            code_index=CodeIndex(roots=[Path(__file__).parents[1] / "src/repro/sharding"]).build())
        loop = DSELoop(evaluator=Evaluator(mesh, "small2x4", artifact_dir=td),
                       db=db, llm_stack=stack,
                       cost_model=CostModel.create(in_dim=featurize({}, {}).shape[0]))
        report = loop.run("qwen3-0.6b", "decode_32k", iterations=2, eval_budget=2)
        print(f"\nevaluated designs: {len(db.all())} "
              f"(negatives: {len([d for d in db.all() if d.negative()])})")
        print(f"improvement vs expert baseline: x{report.improvement():.3f}")


if __name__ == "__main__":
    main()
