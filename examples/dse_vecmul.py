"""Paper §4 reproduction: natural-language spec -> SECDA-native accelerator.

Feeds the paper's Appendix prompt (verbatim) through the LLM Stack, builds
the generated element-wise vecmul accelerator as a Pallas TPU kernel,
verifies it functionally (interpret mode = the 'simulation' stage), emits the
HLS-report analogs of the paper's Tables 1-2, and then runs the DSE Explorer
over the block-size design space with the analytic resource model —
recording every evaluated candidate (including infeasible negatives) into a
cost DB, exactly like the full loop.

    PYTHONPATH=src python examples/dse_vecmul.py
"""
import json
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_db import CostDB, DataPoint
from repro.core.llm_client import MockLLM
from repro.core.llm_stack import LLMStack
from repro.kernels import ops, ref
from repro.kernels.resource_model import vecmul_resources

# the paper's Appendix prompt, verbatim
APPENDIX_PROMPT = """\
I would like to create a hardware accelerator design. The accelerator should
be able to take two input vectors: X and Y, both of length L. The accelerator
should perform an element-wise multiplication operation and produce an output
vector Z. The accelerator has two AXI-Stream based interfaces for loading X
and Y data into custom X and Y buffers. The accelerator should also have a
fixed length parameter L. Once the data is loaded, the accelerator should
execute the element-wise multiplication in parallel and store the results in
buffer Z within the compute module. The loading should be performed using a
load module. Finally, the results should be written back to main memory using
a store module that outputs via an AXI-Stream interface. Create the
accelerator description using SystemC and SECDA. The compute module should be
capable of performing L operations in parallel."""


def main():
    L = 4096
    stack = LLMStack(client=MockLLM())
    design, transcript = stack.generate_accelerator(APPENDIX_PROMPT, length=L)
    print("=== LLM transcript (CoT) ===")
    print(transcript.split("FINAL ANSWER:")[0])
    print("=== generated design ===")
    print(json.dumps(design, indent=2))
    assert design["kernel"] == "vecmul", "spec translation failed"

    # ---- 'simulation' stage: functional verification in interpret mode ----
    block = design["parameters"]["block"]
    x = jax.random.normal(jax.random.key(0), (L,))
    y = jax.random.normal(jax.random.key(1), (L,))
    z = ops.vecmul(x, y, block=block)
    np.testing.assert_allclose(z, ref.vecmul_ref(x, y), rtol=1e-6)
    print(f"\nfunctional check vs ref.py oracle: OK (L={L}, block={block})")

    # ---- Tables 1-2 analogs ----
    res = vecmul_resources(L, block, itemsize=4)
    print("\nTable 1 analog — latency:")
    print(f"  send/compute/recv per-block cycles ~ {res.est_cycles_per_block:.0f}")
    print(f"  total latency estimate: {res.est_latency_us:.3f} us "
          f"({res.est_latency_us * 940:.0f} cycles @940MHz)")
    print("Table 2 analog — resources:")
    print(f"  VMEM (BRAM analog): {res.vmem_bytes/2**10:.0f} KiB "
          f"({100*res.vmem_util:.2f}% of 128 MiB)  "
          f"VPU-aligned(DSP analog)={res.vpu_aligned}")

    # ---- DSE over the block-size space (the 'compute unit dimension') ----
    print("\nDSE over block sizes (resource-model evaluated):")
    with tempfile.TemporaryDirectory() as td:
        db = CostDB(Path(td) / "vecmul_db.jsonl")
        best = None
        for blk in (128, 512, 1024, 4096, 1 << 20, 1 << 25):
            r = vecmul_resources(L, min(blk, L) if blk <= L else blk, itemsize=4)
            status = "ok" if r.feasible else "infeasible"
            db.append(DataPoint(
                arch="vecmul", shape=f"L{L}", mesh="single-chip",
                point={"block": blk}, status=status,
                metrics={"latency_us": r.est_latency_us,
                         "vmem_util": r.vmem_util,
                         "workload": {"n_params": 0, "seq_len": L}},
                reason="" if r.feasible else "VMEM overflow (negative datapoint)"))
            tag = "OK " if r.feasible else "REJ"
            print(f"  [{tag}] block={blk:>8}: latency={r.est_latency_us:8.3f}us "
                  f"vmem={100*r.vmem_util:6.2f}%")
            if r.feasible and (best is None or r.est_latency_us < best[1]):
                best = (blk, r.est_latency_us)
        print(f"best feasible block: {best[0]} ({best[1]:.3f} us); "
              f"{len(db.query(status='infeasible'))} negative datapoints recorded")


if __name__ == "__main__":
    main()
