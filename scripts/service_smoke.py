"""CI smoke for the DSE-as-a-service control plane.

Boots the real daemon (``repro.launch.service serve``) on a free port,
submits overlapping 2-cell grids from two tenants over HTTP, waits for
both queues to drain, then asserts the service contract end to end:

* the daemon never imported jax (``/healthz`` reports ``jax_loaded``);
* cross-tenant coalescing — fleet-wide ``compiles_total`` equals the
  shared dry-run cache's entry count (every design compiled exactly
  once, replays hit the cache), and the cell both tenants submitted
  holds a single compile set;
* both tenants drained with zero worker restarts;
* each tenant's streamed leaderboard is non-empty valid JSON covering
  its own grid;
* ``POST /shutdown`` stops the daemon with exit code 0.

Usage:  PYTHONPATH=src python scripts/service_smoke.py [--out DIR]
        (respects REPRO_CAMPAIGN_PRELUDE for the spawned workers)

Exit codes: 0 = every assertion held, 1 otherwise (daemon log tail is
printed on failure).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import tempfile
import time
import urllib.request
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

TENANTS = {
    # overlapping grids: (qwen3-0.6b, train_4k) is the shared cell
    "alice": {"arch": "qwen3-0.6b", "shape": "train_4k,decode_32k"},
    "bob": {"arch": "qwen3-0.6b,stablelm-3b", "shape": "train_4k"},
}
PROFILE = {"mesh": "tiny", "iterations": 1, "budget": 2}


def _get(url: str, path: str):
    with urllib.request.urlopen(url + path, timeout=30) as r:
        return json.loads(r.read())


def _post(url: str, path: str, payload=None):
    req = urllib.request.Request(
        url + path, data=json.dumps(payload or {}).encode(),
        method="POST", headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=60) as r:
        return json.loads(r.read())


def _wait_drained(url: str, timeout_s: float = 600.0):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        idx = _get(url, "/tenants")["tenants"]
        if all(t["queue"]["pending"] == 0 and t["queue"]["leased"] == 0
               and t["workers_active"] == 0 for t in idx.values()) \
                and len(idx) == len(TENANTS):
            return idx
        time.sleep(1.0)
    raise AssertionError(f"queues never drained: {_get(url, '/tenants')}")


def run(root: Path) -> None:
    """Boot, submit, drain, assert, shut down; raises on any violation."""
    log_path = root.parent / "service_smoke.log"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.service", "serve",
         "--root", str(root), "--port", "0", "--max-workers", "1",
         "--poll-interval", "0.2"],
        stdout=log_path.open("w"), stderr=subprocess.STDOUT)
    try:
        endpoint = root / "endpoint.json"
        deadline = time.time() + 30
        while not endpoint.exists():
            assert proc.poll() is None, "daemon died during startup"
            assert time.time() < deadline, "no endpoint.json after 30s"
            time.sleep(0.1)
        ep = json.loads(endpoint.read_text())
        url = f"http://{ep['host']}:{ep['port']}"
        print(f"[smoke] daemon up at {url}")

        for tenant, grid in TENANTS.items():
            rec = _post(url, "/submit",
                        {"tenant": tenant, **grid, **PROFILE})
            print(f"[smoke] {tenant}: seeded {rec['seeded']} cells")
            assert rec["seeded"] == 2, rec

        idx = _wait_drained(url)
        print("[smoke] all queues drained")

        health = _get(url, "/healthz")
        assert health["ok"] and health["jax_loaded"] is False, health

        # coalescing: one compile fleet-wide per unique design
        cache = root / "dryrun_cache"
        per_cell: dict = {}
        for f in cache.glob("*.json"):
            rec = json.loads(f.read_text())
            key = (rec["arch"], rec["shape"])
            per_cell[key] = per_cell.get(key, 0) + 1
        assert set(per_cell) == {("qwen3-0.6b", "train_4k"),
                                 ("qwen3-0.6b", "decode_32k"),
                                 ("stablelm-3b", "train_4k")}, per_cell
        designs = PROFILE["budget"] + 1  # proposals + baseline
        assert all(n == designs for n in per_cell.values()), per_cell
        compiles = 0
        for tenant in TENANTS:
            status = _get(url, f"/tenants/{tenant}")
            assert status["drained"] and status["queue"]["done"] == 2, status
            assert all(w["state"] == "done" and w["restarts"] == 0
                       for w in status["workers"]), status
            compiles += sum(w["compiles_total"] for w in status["workers"])
        assert compiles == sum(per_cell.values()), (
            f"fleet compiled {compiles} designs but the shared cache holds "
            f"{sum(per_cell.values())} — a design compiled twice")
        print(f"[smoke] dedupe holds: {compiles} compiles == "
              f"{sum(per_cell.values())} cache entries (shared cell once)")

        for tenant, grid in TENANTS.items():
            with urllib.request.urlopen(
                    f"{url}/tenants/{tenant}/leaderboard", timeout=60) as r:
                lb = json.loads(r.read())
            cells = {(row["arch"], row["shape"]) for row in lb}
            want = {(a, s) for a in grid["arch"].split(",")
                    for s in grid["shape"].split(",")}
            assert cells == want, (tenant, cells, want)
        print("[smoke] per-tenant leaderboards cover their grids")

        _post(url, "/shutdown")
        rc = proc.wait(timeout=60)
        assert rc == 0, f"daemon exited {rc}"
        print("[smoke] clean shutdown — service smoke OK")
    except BaseException:
        if log_path.exists():
            print("---- daemon log tail ----", file=sys.stderr)
            print("\n".join(
                log_path.read_text().splitlines()[-40:]), file=sys.stderr)
        raise
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


def main(argv=None) -> int:
    """CLI entry point: run the smoke in --out (default: a temp dir)."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default=None,
                    help="service root dir (default: fresh temp dir)")
    args = ap.parse_args(argv)
    if args.out:
        root = Path(args.out) / "svc"
        root.parent.mkdir(parents=True, exist_ok=True)
        run(root)
        return 0
    with tempfile.TemporaryDirectory(prefix="service_smoke_") as tmp:
        run(Path(tmp) / "svc")
    return 0


if __name__ == "__main__":
    sys.exit(main())
