"""Quickstart drift guard: documented CLI commands must actually parse.

Extracts every ``python -m repro.launch.<module> ...`` and
``python -m repro.analysis.<module> ...`` command from the
fenced code blocks of README.md and ROADMAP.md (joining ``\\``-continued
lines, stripping env-var prefixes) and validates its arguments against the
module's real ``build_parser()`` — unknown flags, removed choices, renamed
modules, or malformed values fail the run with the offending file and
command. CI runs this in the orchestrator smoke job, so the docs cannot
drift from the CLIs without breaking the build.

Usage:  PYTHONPATH=src python scripts/check_quickstart.py [files...]
        (defaults to README.md and ROADMAP.md beside the repo root)

Exit codes: 0 = every documented command parsed (and at least MIN_COMMANDS
were found — an extraction regression cannot silently pass), 1 otherwise.
No jax import, no command execution: parsers only.
"""
from __future__ import annotations

import contextlib
import io
import re
import shlex
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

MIN_COMMANDS = 3
_ENV_ASSIGN = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*=")


def parser_registry():
    """Lazy map of documented CLI modules to their parser factories.
    A documented module missing from here (or from the codebase) is drift."""
    from repro.analysis import lint as analysis_lint
    from repro.analysis import race as analysis_race
    from repro.launch import (campaign, dse, measure, merge_db, orchestrator,
                              service)

    return {
        "repro.launch.campaign": campaign.build_parser,
        "repro.launch.dse": dse.build_parser,
        "repro.launch.measure": measure.build_parser,
        "repro.launch.merge_db": merge_db.build_parser,
        "repro.launch.orchestrator": orchestrator.build_parser,
        "repro.launch.service": service.build_parser,
        "repro.analysis.lint": analysis_lint.build_parser,
        "repro.analysis.race": analysis_race.build_parser,
    }


def fenced_blocks(text: str):
    """Yield the contents of every ``` fenced code block."""
    for m in re.finditer(r"```[^\n]*\n(.*?)```", text, re.DOTALL):
        yield m.group(1)


def extract_commands(text: str):
    """``python -m repro.launch.*`` / ``-m repro.analysis.*`` command token
    lists from fenced blocks, with backslash continuations joined and env
    assignments stripped."""
    out = []
    for block in fenced_blocks(text):
        joined = re.sub(r"\\\s*\n\s*", " ", block)
        for line in joined.splitlines():
            line = line.split("#", 1)[0].strip()
            if "-m repro.launch." not in line \
                    and "-m repro.analysis." not in line:
                continue
            toks = shlex.split(line)
            while toks and _ENV_ASSIGN.match(toks[0]):
                toks.pop(0)
            out.append(toks)
    return out


def check_command(toks, registry):
    """Validate one command's argv against its module parser; returns an
    error string or None. Never executes the command."""
    if len(toks) < 3 or toks[1] != "-m":
        return f"not a `python -m` invocation: {toks}"
    module = toks[2]
    factory = registry.get(module)
    if factory is None:
        return (f"module {module} is not in the checker registry "
                f"(documented module renamed/removed, or the registry in "
                f"{__file__} needs the new module)")
    parser = factory()
    try:
        # argparse prints usage noise on failure and exits; capture both
        with contextlib.redirect_stderr(io.StringIO()) as err, \
                contextlib.redirect_stdout(io.StringIO()):
            parser.parse_args(toks[3:])
    except SystemExit:
        return f"`{' '.join(toks)}` rejected:\n    {err.getvalue().strip()}"
    return None


def main(paths):
    """Check every file; print each command's verdict; exit 1 on failure."""
    registry = parser_registry()
    failures, n = [], 0
    for path in paths:
        text = Path(path).read_text()
        for toks in extract_commands(text):
            n += 1
            err = check_command(toks, registry)
            status = "FAIL" if err else "ok"
            print(f"[{status}] {Path(path).name}: {' '.join(toks)}")
            if err:
                failures.append(f"{path}: {err}")
    if n < MIN_COMMANDS:
        failures.append(
            f"only {n} documented command(s) found across {list(paths)} — "
            f"expected >= {MIN_COMMANDS}; did the quickstart sections move "
            f"out of fenced code blocks?")
    for f in failures:
        print(f"\nDRIFT: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    files = sys.argv[1:] or [REPO / "README.md", REPO / "ROADMAP.md"]
    sys.exit(main(files))
