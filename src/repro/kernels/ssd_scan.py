"""Mamba2 SSD chunk kernel (state-space duality) for TPU.

Per grid cell (batch, chunk) the kernel computes, entirely in VMEM:
  * the intra-chunk quadratic term  Y_intra = (C·Bᵀ ⊙ decay) · (dt x)
  * the chunk's local outgoing state S_loc and total decay

The O(n_chunks) inter-chunk state recurrence is sequential by nature and is
composed outside the kernel (lax.scan over tiny [nh, dh, N] states), after
which a second pass adds the inter-chunk contribution C · S_prev. Chunk
length is the DSE-explorable tiling knob.

Oracle: ``ref.ssd_ref`` (exact sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref,
                      y_ref, state_ref, decay_ref):
    # x: [L, nh, dh]; dt: [L, nh]; A: [nh]; B, C: [L, N]
    L, nh, dh = x_ref.shape
    N = B_ref.shape[-1]
    x = x_ref[...].astype(jnp.float32)
    dt = dt_ref[...].astype(jnp.float32)
    A = A_ref[...].astype(jnp.float32)
    B = B_ref[...].astype(jnp.float32)
    C = C_ref[...].astype(jnp.float32)

    dA = dt * A[None, :]  # [L, nh], negative
    cs = jnp.cumsum(dA, axis=0)

    # intra-chunk: decay(l, s, h) = exp(cs_l - cs_s) for l >= s
    diff = cs[:, None, :] - cs[None, :, :]  # [L, S, nh]
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    causal = li >= si  # (np constants can't be captured by pallas kernels)
    decay = jnp.exp(jnp.where(causal[:, :, None], diff, -jnp.inf))
    att = jnp.einsum("ln,sn->ls", C, B)[:, :, None] * decay  # [L,S,nh]
    xdt = x * dt[:, :, None]
    y_ref[...] = jnp.einsum("lsh,shp->lhp", att, xdt).astype(y_ref.dtype)

    # local outgoing state and total chunk decay
    decay_end = jnp.exp(cs[-1:, :] - cs)  # [L, nh]
    state_ref[...] = jnp.einsum("ln,lh,lhp->hpn", B, dt * decay_end, x).astype(
        state_ref.dtype)
    decay_ref[...] = jnp.exp(cs[-1, :]).astype(decay_ref.dtype)


def _ssd_inter_kernel(C_ref, S_ref, cs_ref, y_ref):
    # C: [L, N]; S (incoming state): [nh, dh, N]; cs: [L, nh]
    C = C_ref[...].astype(jnp.float32)
    S = S_ref[...].astype(jnp.float32)
    decay_in = jnp.exp(cs_ref[...].astype(jnp.float32))  # [L, nh]
    y = jnp.einsum("ln,hpn->lhp", C, S) * decay_in[:, :, None]
    y_ref[...] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, initial_state=None,
             interpret: bool = False):
    """Full SSD over a sequence using the chunk kernel.

    x: [b, s, nh, dh]; dt: [b, s, nh] (post-softplus); A: [nh];
    B, C: [b, s, N]. Returns (y [b,s,nh,dh], final_state [b,nh,dh,N]).
    """
    b, s, nh, dh = x.shape
    N = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, nh, dh)
    dtc = dt.reshape(b, nc, chunk, nh)
    Bc = B.reshape(b, nc, chunk, N)
    Cc = C.reshape(b, nc, chunk, N)

    kern = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((None, None, chunk, nh, dh), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((None, None, chunk, nh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((nh,), lambda i, j: (0,)),
            pl.BlockSpec((None, None, chunk, N), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, chunk, N), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, nh, dh), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((None, None, nh, dh, N), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((None, None, nh), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, chunk, nh, dh), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh, dh, N), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, nh), jnp.float32),
        ],
        interpret=interpret,
    )
    y_intra, S_loc, chunk_decay = kern(xc, dtc, A, Bc, Cc)

    # ---- sequential inter-chunk recurrence (tiny state, outside kernel) ----
    S0 = (jnp.zeros((b, nh, dh, N), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(S_prev, inp):
        S_l, cd = inp
        return S_prev * cd[:, :, None, None] + S_l, S_prev

    S_final, S_prevs = jax.lax.scan(
        step, S0, (S_loc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)  # [b,nc,nh,dh,N]

    # ---- second kernel pass: inter-chunk contribution ----
    dA = dtc.astype(jnp.float32) * A[None, None, None, :]
    cs = jnp.cumsum(dA, axis=2)  # [b,nc,L,nh]
    inter = pl.pallas_call(
        _ssd_inter_kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((None, None, chunk, N), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, None, nh, dh, N), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((None, None, chunk, nh), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, chunk, nh, dh), lambda i, j: (i, j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, chunk, nh, dh), jnp.float32),
        interpret=interpret,
    )(Cc, S_prevs, cs)

    y = (y_intra + inter).reshape(b, s, nh, dh).astype(x.dtype)
    return y, S_final
