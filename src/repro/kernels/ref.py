"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def vecmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """Element-wise vector multiply: Z_i = X_i * Y_i (the paper's §4 kernel)."""
    return x * y


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return ((x32 * inv) * w.astype(jnp.float32)).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True, sm_scale: float | None = None):
    """Naive full-softmax attention. q,k,v: [b, s, h, d] (same head counts)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = np.tril(np.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, initial_state=None):
    """Exact sequential SSD recurrence (oracle for ssd_chunked + the kernel).

    x: [b, s, nh, dh]; dt: [b, s, nh] (post-softplus); A: [nh] negative;
    B, C: [b, s, N]. Returns (y [b,s,nh,dh], final_state [b,nh,dh,N]).
    """
    b, s, nh, dh = x.shape
    N = B.shape[-1]
    h = (jnp.zeros((b, nh, dh, N), jnp.float32)
         if initial_state is None else initial_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # [b,nh,dh], [b,nh], [b,N], [b,N]
        dA = jnp.exp(dtt * A[None, :])  # [b,nh]
        h = h * dA[..., None, None] + (
            (dtt[..., None] * xt.astype(jnp.float32))[..., None] * Bt[:, None, None, :]
        )
        y = jnp.einsum("bhpn,bn->bhp", h, Ct.astype(jnp.float32))
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.astype(jnp.float32).transpose(1, 0, 2),
          B.astype(jnp.float32).transpose(1, 0, 2), C.astype(jnp.float32).transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h
