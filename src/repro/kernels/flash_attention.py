"""Blockwise (flash) attention Pallas kernel for TPU.

Tiling: grid over (batch*kv_head*group, q_blocks); K/V streamed through VMEM
in ``block_k`` slices via an in-kernel ``fori_loop`` with online-softmax
accumulators held in VREGs/VMEM. Block sizes are MXU-aligned (multiples of
128 on the contracting dim) and DSE-explorable via ``plan.kernel_blocks``.

The pure-jnp oracle is ``ref.attention_ref`` (and the model-side
``layers.chunked_attention`` uses the same math — the kernel is the TPU
hot-path realization).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool,
                  sm_scale: float, q_offset: int):
    # q_ref: [block_q, d]; k_ref/v_ref: [S_k, d]; o_ref: [block_q, d]
    block_q, d = q_ref.shape
    S_k = k_ref.shape[0]
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * sm_scale
    q_pos = q_offset + qi * block_q + jax.lax.iota(jnp.int32, block_q)

    n_k = S_k // block_k

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(ki * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_new = acc * corr[:, None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, a0))
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [b, sq, h, d]
    k: jax.Array,  # [b, sk, kh, d]
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    sm_scale = 1.0 / np.sqrt(d)

    # head-major flat layouts: q [b*h, sq, d]; kv [b*kh, sk, d]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, sk, d)

    kernel = functools.partial(
        _flash_kernel, block_k=block_k, causal=causal,
        sm_scale=sm_scale, q_offset=q_offset,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
            # GQA: kv block index = query head // group size
            pl.BlockSpec((None, sk, d), lambda bh, qi, g=g: (bh // g, 0, 0)),
            pl.BlockSpec((None, sk, d), lambda bh, qi, g=g: (bh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
