"""Kernel correctness gate: run a candidate tile against its ref.py oracle.

Every kernel-cell candidate the DSE engine evaluates or measures passes
through :func:`check_candidate` before it may enter a leaderboard: the
Pallas kernel runs (interpret mode on CPU, native on TPU) on deterministic
inputs and its output is compared element-wise against the pure-jnp oracle
in ``kernels.ref``. A fast-but-wrong tile becomes a ``status="infeasible"``
row with the max error recorded — never a winner.

Tolerances are per (kernel, dtype): absolute max-|error| thresholds chosen
from the kernels' existing conformance sweeps (online-softmax reassociation
for flash attention, chunked-vs-sequential reassociation for the SSD scan,
bf16 rounding for everything).

Fault-injection hook for tests/CI: ``REPRO_KERNEL_INJECT_BAD`` holds a spec
``<kernel>:<dim>=<value>`` (e.g. ``vecmul:block=1024``); any candidate of
that kernel whose point sets that dim to that value gets its output
perturbed by +0.1 — far outside every tolerance — so the smoke arm can
assert the correctness gate actually rejects a broken variant end to end.

This module imports jax at the top level; supervisor-layer code reaches it
only through lazy imports (the evaluator's compile path, the measured tier).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.kernel_space import KernelShape
from repro.kernels import ops, ref

#: absolute max-|error| threshold per (kernel, dtype)
TOLERANCES: Dict[Tuple[str, str], float] = {
    ("vecmul", "float32"): 1e-6,
    ("vecmul", "bfloat16"): 1e-2,
    ("rmsnorm", "float32"): 1e-5,
    ("rmsnorm", "bfloat16"): 3e-2,
    ("flash_attention", "float32"): 2e-3,
    ("flash_attention", "bfloat16"): 3e-2,
    ("ssd_scan", "float32"): 3e-3,
    ("ssd_scan", "bfloat16"): 5e-2,
}

INJECT_ENV = "REPRO_KERNEL_INJECT_BAD"


def tolerance(kernel: str, dtype: str) -> float:
    """The gate threshold for one (kernel, dtype) pair."""
    return TOLERANCES[(kernel, dtype)]


def make_inputs(shape: KernelShape, seed: int = 0) -> Tuple[Any, ...]:
    """Deterministic inputs for one kernel shape (numpy RNG -> jnp arrays,
    scaled small so softmax/scan accumulations stay well-conditioned)."""
    rng = np.random.default_rng(seed)
    dt_ = shape.dtype
    p = shape.params

    def arr(*dims):
        return jnp.asarray(0.3 * rng.standard_normal(dims), dtype=dt_)

    if shape.kernel == "vecmul":
        return arr(p["L"]), arr(p["L"])
    if shape.kernel == "rmsnorm":
        return arr(p["rows"], p["d"]), arr(p["d"])
    if shape.kernel == "flash_attention":
        return (arr(p["b"], p["sq"], p["h"], p["d"]),
                arr(p["b"], p["sk"], p["kh"], p["d"]),
                arr(p["b"], p["sk"], p["kh"], p["d"]))
    if shape.kernel == "ssd_scan":
        x = arr(p["b"], p["s"], p["nh"], p["dh"])
        dt = jnp.asarray(0.1 + 0.2 * rng.random((p["b"], p["s"], p["nh"])),
                         dtype=dt_)
        A = jnp.asarray(-(0.5 + rng.random(p["nh"])), dtype=jnp.float32)
        B = arr(p["b"], p["s"], p["N"])
        C = arr(p["b"], p["s"], p["N"])
        return x, dt, A, B, C
    raise KeyError(f"unknown kernel {shape.kernel!r}")


def _parse_inject_spec(spec: str) -> Optional[Tuple[str, str, Any]]:
    """``kernel:dim=value`` -> (kernel, dim, typed value); None if malformed."""
    try:
        kernel, assign = spec.split(":", 1)
        dim, raw = assign.split("=", 1)
    except ValueError:
        return None
    raw = raw.strip()
    if raw.lower() in ("true", "false"):
        val: Any = raw.lower() == "true"
    else:
        try:
            val = int(raw)
        except ValueError:
            val = raw
    return kernel.strip(), dim.strip(), val


def _maybe_inject_bad(kernel: str, dims: Mapping[str, Any], out):
    """Apply the REPRO_KERNEL_INJECT_BAD perturbation if this candidate
    matches the spec (test/CI hook — inert in production runs)."""
    spec = os.environ.get(INJECT_ENV)
    if not spec:
        return out
    parsed = _parse_inject_spec(spec)
    if parsed is None:
        return out
    want_kernel, dim, val = parsed
    if kernel != want_kernel or dims.get(dim) != val:
        return out
    return out + jnp.asarray(0.1, out.dtype)


def run_candidate(shape: KernelShape, dims: Mapping[str, Any],
                  inputs: Tuple[Any, ...], *, interpret: Optional[bool] = True):
    """Execute the Pallas kernel with the candidate's tile dims. Returns
    the primary output array (flash/rmsnorm/vecmul) — for ssd_scan, the
    ``(y, final_state)`` pair with the injection applied to ``y``."""
    if shape.kernel == "vecmul":
        out = ops.vecmul(*inputs, block=int(dims["block"]),
                         interpret=interpret)
    elif shape.kernel == "rmsnorm":
        out = ops.rmsnorm(*inputs, block_rows=int(dims["block_rows"]),
                          interpret=interpret)
    elif shape.kernel == "flash_attention":
        out = ops.flash_attention(*inputs, causal=bool(dims["causal"]),
                                  block_q=int(dims["block_q"]),
                                  block_k=int(dims["block_k"]),
                                  interpret=interpret)
    elif shape.kernel == "ssd_scan":
        y, state = ops.ssd_scan(*inputs, chunk=int(dims["chunk"]),
                                interpret=interpret)
        return _maybe_inject_bad(shape.kernel, dims, y), state
    else:
        raise KeyError(f"unknown kernel {shape.kernel!r}")
    return _maybe_inject_bad(shape.kernel, dims, out)


def run_reference(shape: KernelShape, dims: Mapping[str, Any],
                  inputs: Tuple[Any, ...]):
    """The ref.py oracle on the same inputs (GQA K/V heads repeated up to
    the query head count; causal flag threaded through for attention)."""
    if shape.kernel == "vecmul":
        return ref.vecmul_ref(*inputs)
    if shape.kernel == "rmsnorm":
        return ref.rmsnorm_ref(*inputs)
    if shape.kernel == "flash_attention":
        q, k, v = inputs
        g = q.shape[2] // k.shape[2]
        if g > 1:
            k = jnp.repeat(k, g, axis=2)
            v = jnp.repeat(v, g, axis=2)
        return ref.attention_ref(q, k, v, causal=bool(dims["causal"]))
    if shape.kernel == "ssd_scan":
        return ref.ssd_ref(*inputs)
    raise KeyError(f"unknown kernel {shape.kernel!r}")


def max_abs_error(got, want) -> float:
    """Max element-wise |got - want| in float32, tuple-aware (ssd returns
    (y, final_state) and both must match)."""
    if isinstance(got, tuple) or isinstance(want, tuple):
        return max(max_abs_error(g, w) for g, w in zip(got, want))
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    return float(np.max(np.abs(g - w))) if g.size else 0.0


def check_candidate(shape: KernelShape, dims: Mapping[str, Any], *,
                    interpret: Optional[bool] = True,
                    inputs: Optional[Tuple[Any, ...]] = None,
                    seed: int = 0) -> Dict[str, Any]:
    """The correctness gate: run candidate and oracle, compare.

    Returns ``{"max_abs_err", "tol", "passed"}``; callers turn a failed
    check into a ``status="infeasible"`` DataPoint.
    """
    if inputs is None:
        inputs = make_inputs(shape, seed=seed)
    got = run_candidate(shape, dims, inputs, interpret=interpret)
    want = run_reference(shape, dims, inputs)
    err = max_abs_error(got, want)
    tol = tolerance(shape.kernel, shape.dtype)
    return {"max_abs_err": err, "tol": tol, "passed": bool(err <= tol)}
