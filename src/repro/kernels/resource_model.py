"""Analytic kernel resource model — the HLS resource-report analog.

For each Pallas kernel candidate the DSE evaluates:
  * VMEM footprint of the BlockSpec working set (x2 for the double-buffered
    HBM->VMEM pipeline) against the 128 MiB budget — BRAM utilization analog;
  * MXU tile alignment of the matmul dims (128x128 systolic) — DSP analog;
  * VPU lane alignment (8x128) for elementwise kernels;
  * estimated latency (cycles) from the roofline of bytes/flops per block —
    the paper's Table 1 latency/II analog.

Infeasible candidates (VMEM overflow) are rejected before compilation and
logged as negative hardware data points (paper §3.2.2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.device import DeviceModel, TPU_V5E


@dataclass(frozen=True)
class KernelResources:
    name: str
    vmem_bytes: int
    vmem_util: float  # fraction of VMEM budget
    mxu_aligned: bool
    vpu_aligned: bool
    est_cycles_per_block: float
    est_latency_us: float  # whole-kernel latency estimate
    feasible: bool
    notes: str = ""

    def to_dict(self):
        import dataclasses

        return dataclasses.asdict(self)


def _mk(name, vmem, flops_per_block, bytes_per_block, n_blocks, aligned_mxu,
        aligned_vpu, dev: DeviceModel, notes="") -> KernelResources:
    vmem_db = 2 * vmem  # double-buffered streaming
    feasible = vmem_db <= dev.vmem_bytes
    # per-block latency = max(compute, stream) — load-compute-store pipeline
    t_compute = flops_per_block / dev.peak_flops_bf16
    t_stream = bytes_per_block / dev.hbm_bw
    t_block = max(t_compute, t_stream)
    clock_hz = 940e6  # v5e clock
    return KernelResources(
        name=name,
        vmem_bytes=vmem_db,
        vmem_util=vmem_db / dev.vmem_bytes,
        mxu_aligned=aligned_mxu,
        vpu_aligned=aligned_vpu,
        est_cycles_per_block=t_block * clock_hz,
        est_latency_us=t_block * n_blocks * 1e6,
        feasible=feasible,
        notes=notes,
    )


def vecmul_resources(L: int, block: int, itemsize: int = 4,
                     dev: DeviceModel = TPU_V5E) -> KernelResources:
    vmem = 3 * block * itemsize  # X, Y, Z buffers
    n_blocks = max((L + block - 1) // block, 1)
    return _mk(
        "vecmul", vmem,
        flops_per_block=block,
        bytes_per_block=3 * block * itemsize,
        n_blocks=n_blocks,
        aligned_mxu=True,  # no MXU use
        aligned_vpu=block % (8 * 128) == 0,
        dev=dev,
        notes=f"L={L} block={block}",
    )


def rmsnorm_resources(rows: int, d: int, block_rows: int, itemsize: int = 2,
                      dev: DeviceModel = TPU_V5E) -> KernelResources:
    vmem = (2 * block_rows * d + d) * itemsize + block_rows * 4
    n_blocks = max((rows + block_rows - 1) // block_rows, 1)
    return _mk(
        "rmsnorm", vmem,
        flops_per_block=3 * block_rows * d,
        bytes_per_block=2 * block_rows * d * itemsize,
        n_blocks=n_blocks,
        aligned_mxu=True,
        aligned_vpu=d % 128 == 0,
        dev=dev,
        notes=f"rows={rows} d={d} block_rows={block_rows}",
    )


def flash_attention_resources(b: int, sq: int, sk: int, h: int, kh: int, d: int,
                              block_q: int, block_k: int, itemsize: int = 2,
                              dev: DeviceModel = TPU_V5E) -> KernelResources:
    # per-block working set: q block + full K/V stream window + accumulators
    vmem = (block_q * d + 2 * block_k * d) * itemsize \
        + block_q * d * 4 + 2 * block_q * 4 + block_q * block_k * 4
    n_blocks = b * h * max(sq // max(block_q, 1), 1)
    flops_per_block = 2 * 2 * block_q * d * sk  # QK^T + PV over all kv blocks
    bytes_per_block = (block_q * d + 2 * sk * d) * itemsize
    return _mk(
        "flash_attention", vmem,
        flops_per_block=flops_per_block,
        bytes_per_block=bytes_per_block,
        n_blocks=n_blocks,
        aligned_mxu=(d % 128 == 0 and block_q % 128 == 0 and block_k % 128 == 0),
        aligned_vpu=True,
        dev=dev,
        notes=f"bq={block_q} bk={block_k} d={d} sk={sk}",
    )


def ssd_scan_resources(b: int, s: int, nh: int, dh: int, N: int, chunk: int,
                       itemsize: int = 2, dev: DeviceModel = TPU_V5E) -> KernelResources:
    # x, dt, B, C blocks + decay LxLxnh f32 + y + state
    vmem = (chunk * nh * dh + chunk * nh + 2 * chunk * N) * itemsize \
        + chunk * chunk * nh * 4 + chunk * nh * dh * 4 + nh * dh * N * 4
    n_blocks = b * max(s // max(chunk, 1), 1)
    flops_per_block = (2 * chunk * chunk * N + 2 * chunk * chunk * nh * dh
                       + 2 * chunk * nh * dh * N)
    bytes_per_block = (chunk * (nh * dh + nh + 2 * N)) * itemsize + nh * dh * N * 4
    return _mk(
        "ssd_scan", vmem,
        flops_per_block=flops_per_block,
        bytes_per_block=bytes_per_block,
        n_blocks=n_blocks,
        aligned_mxu=(chunk % 128 == 0 and N % 128 == 0),
        aligned_vpu=dh % 8 == 0,
        dev=dev,
        notes=f"chunk={chunk} nh={nh} dh={dh} N={N}",
    )


RESOURCE_FNS = {
    "vecmul": vecmul_resources,
    "rmsnorm": rmsnorm_resources,
    "flash_attention": flash_attention_resources,
    "ssd_scan": ssd_scan_resources,
}
