"""Element-wise vector-multiply accelerator — TPU-native port of the design
SECDA-DSE generates in the paper's §4 / Appendix.

Paper (FPGA)                         | here (TPU)
-------------------------------------|------------------------------------
AXI-Stream load of X, Y              | HBM -> VMEM streaming via BlockSpec grid
on-chip X/Y/Z BRAM buffers           | VMEM blocks (one per operand + result)
"L operations in parallel" compute   | 8x128 VPU lanes per block
store module -> AXI-Stream out       | VMEM -> HBM write of the Z block

The block length is the DSE-explorable "compute unit dimension": the
resource model in ``resource_model.py`` reports the VMEM footprint
(BRAM-utilization analog) and lane alignment (DSP analog) per candidate.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vecmul_kernel(x_ref, y_ref, z_ref):
    # load (VMEM block) -> compute (VPU elementwise) -> store (VMEM block)
    z_ref[...] = x_ref[...] * y_ref[...]


def vecmul(x: jax.Array, y: jax.Array, *, block: int = 1024,
           interpret: bool = False) -> jax.Array:
    """Z = X ⊙ Y with explicit HBM->VMEM block streaming."""
    assert x.shape == y.shape and x.ndim == 1
    L = x.shape[0]
    pad = (-L) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    n = x.shape[0] // block
    z = pl.pallas_call(
        _vecmul_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), x.dtype),
        interpret=interpret,
    )(x, y)
    return z[:L]
