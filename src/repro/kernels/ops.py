"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode; on TPU the
same BlockSpecs lower natively. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd
from repro.kernels import vecmul as _vm


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block", "interpret"))
def vecmul(x, y, *, block: int = 1024, interpret: Optional[bool] = None):
    return _vm.vecmul(x, y, block=block, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps: float = 1e-5, block_rows: int = 128,
            interpret: Optional[bool] = None):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _rn.rmsnorm(x2, w, eps=eps, block_rows=block_rows,
                      interpret=_auto_interpret(interpret))
    return out.reshape(shape)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "q_offset", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, q_offset: int = 0,
                    interpret: Optional[bool] = None):
    return _fa.flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        q_offset=q_offset, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, initial_state=None,
             interpret: Optional[bool] = None):
    return _ssd.ssd_scan(x, dt, A, B, C, chunk=chunk,
                         initial_state=initial_state,
                         interpret=_auto_interpret(interpret))
