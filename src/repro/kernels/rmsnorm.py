"""Fused RMSNorm Pallas kernel: one pass over rows, f32 statistics in VMEM."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = ((x * inv) * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 128, interpret: bool = False) -> jax.Array:
    """x: [rows, d] (callers flatten leading dims), w: [d]."""
    rows, d = x.shape
    pad = (-rows) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n = x.shape[0] // block_rows
    out = pl.pallas_call(
        lambda x_ref, w_ref, o_ref: _rmsnorm_kernel(x_ref, w_ref, o_ref, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], d), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:rows]
