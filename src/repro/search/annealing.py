"""Simulated annealing over the plan template (iDSE-style policy diversity).

Keeps one walker. Proposal radius (number of mutated dimensions) scales with
temperature: hot walkers take multi-dimension jumps, cold walkers settle into
single-dimension polishing (the greedy limit). Acceptance is Metropolis on
``log10(bound_s)`` — a worse design is adopted with probability
``exp(-delta_decades / T)`` — so early iterations can cross roofline valleys
the greedy policy cannot. Fully deterministic given ``seed``.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.cost_db import DataPoint
from repro.core.design_space import PlanPoint
from repro.search.base import (Candidate, SearchState, bound_of, mutate,
                               point_of, weighted_objective)


@dataclass
class SimulatedAnnealing:
    """Single-walker Metropolis search over the plan template (see module
    docstring). Temperatures are in decades of log10(bound_s); cooling is
    geometric per :meth:`observe` call. Deterministic given ``seed``."""

    name: str = "anneal"
    seed: int = 0
    t0: float = 0.5       # initial temperature, in log10-bound decades
    alpha: float = 0.85   # geometric cooling per observe()
    t_min: float = 0.02
    # Pareto scalarization arm (see base.WEIGHT_ARMS): None keeps the
    # classic bound_s walker bit-for-bit; a weight dict makes the walker
    # descend the weighted log-scale objective instead, same Metropolis
    # rule (weighted scores are already in decades, so deltas subtract
    # directly where the scalar path takes log10 of raw bounds).
    weights: Optional[Dict[str, float]] = None

    _temp: float = field(init=False)
    _current: Optional[Tuple[PlanPoint, float]] = field(default=None, init=False)
    _proposed: Set[str] = field(default_factory=set, init=False)
    _rng: random.Random = field(init=False)

    def __post_init__(self):
        """Initialise the walker temperature and the acceptance RNG."""
        self._temp = self.t0
        self._rng = random.Random(self.seed * 7919 + 17)

    @property
    def temperature(self) -> float:
        """Current walker temperature in log10(bound_s) decades; cools
        geometrically toward ``t_min`` with every observed iteration."""
        return self._temp

    def propose(self, state: SearchState) -> List[Candidate]:
        """``budget`` mutations of the walker position (adopted from the
        incumbent on first call): hot walkers mutate up to 3 dimensions,
        cold walkers exactly 1. Falls back to random template samples when
        the cell has no incumbent yet. Deterministic per iteration."""
        if self._current is None:
            inc_b = self._score(state.incumbent)
            if state.incumbent is not None and inc_b is not None:
                self._current = (point_of(state.incumbent), inc_b)
        base = (self._current[0] if self._current is not None
                else point_of(state.incumbent) if state.incumbent is not None
                else None)
        rng = random.Random(self.seed * 7919 + state.iteration)
        out: List[Candidate] = []
        for _ in range(max(state.budget, 1)):
            if base is None:
                p = state.template.random_points(rng, 1)[0]
            else:
                # hot -> up to 3 mutated dims, cold -> exactly 1
                n_dims = 1 + sum(rng.random() < self._temp / self.t0
                                 for _ in range(2))
                p = mutate(state.template, base, rng, n_dims)
            self._proposed.add(p.key())
            out.append(Candidate(p, f"search:{self.name}"))
        return out

    def _score(self, dp: Optional[DataPoint]) -> Optional[float]:
        """The walker's objective for a row: raw ``bound_s`` seconds in
        scalar mode (acceptance takes log10 at delta time, as always), or
        the weighted log-scale objective when a Pareto weight arm is set."""
        if not self.weights:
            return bound_of(dp)
        return weighted_objective(dp, self.weights)

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """Metropolis step on the fastest own-proposed feasible result — a
        better design always moves the walker, a worse one moves it with
        probability ``exp(-delta_decades / T)`` — then cool one step.
        Results this walker never proposed are ignored."""
        mine = [d for d in datapoints
                if d.point.get("__key__") in self._proposed
                and d.status == "ok" and d.metrics.get("bound_s")]
        if mine and not self.weights:
            cand = min(mine, key=lambda d: d.metrics["bound_s"])
            b = cand.metrics["bound_s"]
            if self._current is None:
                self._current = (point_of(cand), b)
            else:
                delta = math.log10(b) - math.log10(self._current[1])
                if delta <= 0 or self._rng.random() < math.exp(-delta / max(self._temp, 1e-9)):
                    self._current = (point_of(cand), b)
        elif mine:
            scored = [(s, d) for d in mine
                      if (s := self._score(d)) is not None]
            if scored:
                s, cand = min(scored, key=lambda t: t[0])
                if self._current is None:
                    self._current = (point_of(cand), s)
                else:
                    # weighted scores are already log-scale decades
                    delta = s - self._current[1]
                    if delta <= 0 or self._rng.random() < math.exp(
                            -delta / max(self._temp, 1e-9)):
                        self._current = (point_of(cand), s)
        self._temp = max(self._temp * self.alpha, self.t_min)
