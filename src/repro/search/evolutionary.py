"""Evolutionary search: tournament selection + uniform crossover over
``PlanPoint.dims`` with per-dimension mutation.

The population is every feasible design the strategy has observed (seeded
from the cost DB, so a resumed campaign inherits its gene pool), truncated
to the ``pop_size`` fittest (lowest roofline bound). Crossover recombines
dimensions from two tournament-selected parents — the operator the greedy
single-mutation neighborhood structurally lacks. Deterministic given
``seed``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost_db import DataPoint
from repro.core.design_space import PlanPoint
from repro.search.base import (Candidate, SearchState, mutate, point_of,
                               repair, weighted_objective)


@dataclass
class Evolutionary:
    """Tournament-selection + uniform-crossover search (see module
    docstring). Fitness is measured ``bound_s`` (seconds, lower is
    fitter); deterministic given ``seed`` and the iteration index."""

    name: str = "evolve"
    seed: int = 0
    pop_size: int = 8
    tournament: int = 2
    p_mutate: float = 0.3
    # Pareto scalarization arm (see base.WEIGHT_ARMS): None keeps bound_s
    # fitness bit-for-bit; a weight dict breeds toward the weighted
    # log-scale objective instead (scores can be negative — log10 of
    # sub-second bounds — so weighted mode tests ``is not None``, never
    # truthiness).
    weights: Optional[Dict[str, float]] = None

    # key -> (fitness, point); fittest = lowest score
    _pop: Dict[str, Tuple[float, PlanPoint]] = field(default_factory=dict,
                                                     init=False)

    def population(self) -> List[Tuple[float, PlanPoint]]:
        """The ``pop_size`` fittest observed ``(bound_s, point)`` pairs,
        fastest first; empty until a feasible design has been observed or
        seeded from the DB."""
        return sorted(self._pop.values(), key=lambda t: t[0])[: self.pop_size]

    def _fitness(self, d: DataPoint) -> Optional[float]:
        """Fitness score (lower is fitter): raw ``bound_s`` in scalar mode,
        the weighted log-scale objective under a Pareto weight arm."""
        if not self.weights:
            b = d.metrics.get("bound_s")
            return b if b else None
        return weighted_objective(d, self.weights)

    def _seed_population(self, state: SearchState) -> None:
        for d in state.db.query(state.arch, state.shape, "ok"):
            f = self._fitness(d)
            if f is not None:
                self._pop.setdefault(d.point.get("__key__", ""), (f, point_of(d)))

    def _pick(self, pop: List[Tuple[float, PlanPoint]],
              rng: random.Random) -> PlanPoint:
        contenders = [pop[rng.randrange(len(pop))]
                      for _ in range(min(self.tournament, len(pop)))]
        return min(contenders, key=lambda t: t[0])[1]

    def propose(self, state: SearchState) -> List[Candidate]:
        """``budget`` children bred by tournament + uniform crossover (with
        ``p_mutate`` single-dimension mutation), falling back to mutating
        the incumbent or a random sample while the gene pool holds fewer
        than two designs. The population self-seeds from the cell's
        feasible DB rows on first call (resume inherits the gene pool)."""
        if not self._pop:
            self._seed_population(state)
        rng = random.Random(self.seed * 6007 + state.iteration)
        pop = self.population()
        out: List[Candidate] = []
        for _ in range(max(state.budget, 1)):
            if len(pop) < 2:
                # gene pool too thin to cross: fall back to mutating whatever
                # exists (incumbent or a random template sample)
                base = (pop[0][1] if pop else
                        point_of(state.incumbent) if state.incumbent is not None
                        else state.template.random_points(rng, 1)[0])
                child = mutate(state.template, base, rng, 1)
            else:
                p1, p2 = self._pick(pop, rng), self._pick(pop, rng)
                dims = {k: (p1.dims.get(k) if rng.random() < 0.5
                            else p2.dims.get(k, p1.dims.get(k)))
                        for k in p1.dims}
                child = repair(state.template, PlanPoint(dims=dims))
                if rng.random() < self.p_mutate:
                    child = mutate(state.template, child, rng, 1)
            out.append(Candidate(child, f"search:{self.name}"))
        return out

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """Add every feasible result to the gene pool (negatives never
        breed); compact the pool when it outgrows 4x ``pop_size``."""
        for d in datapoints:
            if d.status != "ok":
                continue
            f = self._fitness(d)
            if f is not None:
                self._pop[d.point.get("__key__", "")] = (f, point_of(d))
        if len(self._pop) > 4 * self.pop_size:  # bound memory on long runs
            keep = self.population()
            self._pop = {p.key(): (b, p) for b, p in keep}
