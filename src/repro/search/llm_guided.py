"""LLM-guided refinement as a SearchStrategy — wraps the LLM Stack.

Chains from the incumbent AND (paper §3.2.2) the fastest *infeasible* prior
design, so memory-violating near-winners seed memory-fixing refinements.
Unparseable or template-violating responses become ``rejected`` negative
data points appended straight to the DB (never silently dropped).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

from repro.core.cost_db import DataPoint
from repro.core.llm_stack import LLMStack
from repro.search.base import (Candidate, SearchState, best_negative,
                               point_of)


@dataclass
class LLMGuided:
    """LLM-Stack-backed proposal engine (see module docstring). Determinism
    follows the client: exact with the mock LLM, best-effort with a live
    model. Failure mode: an unreachable/garbled LLM yields ``rejected``
    negative data points and an empty candidate list, never an exception."""

    llm_stack: LLMStack
    name: str = "llm"

    def propose(self, state: SearchState) -> List[Candidate]:
        """Ask the stack for refinements of the incumbent and (when one
        exists) the fastest infeasible near-winner; unparseable or
        template-violating responses are appended to the DB as ``rejected``
        rows. Empty until the cell has an incumbent."""
        if state.incumbent is None:
            return []
        seeds = [(point_of(state.incumbent), state.incumbent)]
        neg = best_negative(state.db, state.arch, state.shape, state.incumbent)
        if neg is not None:
            seeds.append((point_of(neg), neg))
        out: List[Candidate] = []
        for pt, dp in seeds:
            valid, rejected, _raw = self.llm_stack.propose(
                state.arch, state.shape, state.cfg, state.cell,
                state.template, pt, dp.metrics, k=max(state.budget, 1))
            state.db.append_many(rejected)
            out += [Candidate(p, f"search:{self.name}") for p in valid]
        return out

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """No-op: the stack re-reads the DB (RAG context) on every propose."""
