"""Surrogate-gated evaluation: skip compiles the cost model rules out.

LLM-DSE's "amortize expensive evaluations" lever: before a candidate reaches
a dry-run compile, predict its roofline bound with the learned surrogate and
prune it when the prediction is more than ``factor``x off the incumbent.
Pruned candidates are recorded as ``pruned`` data points carrying the
prediction (so RAG retrieval still surfaces them and later analysis can
audit the gate) — they are *not* used as fine-tuning targets, since they
have no measured outcome (see ``CostDB.training_set``).

Calibration guard: the gate stays disabled until the surrogate's validation
RMSE on held-out DB rows (a deterministic ~20% key-hash split the model
never trains on) drops below ``max_val_rmse`` decades of log10(bound).
``require_calibration=False`` bypasses the guard — benchmarks/tests only.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_db import CostDB, featurize
from repro.core.design_space import PlanPoint


@dataclass
class SurrogateGate:
    """Calibration-guarded pre-compile filter (see module docstring).
    ``factor`` is the prune threshold as a multiple of the incumbent's
    measured ``bound_s``; ``max_val_rmse`` is in decades of log10(bound_s).
    Fails safe: an untrained or badly-calibrated surrogate leaves the gate
    inactive and every candidate passes through to evaluation."""

    cost_model: object  # CostModel (typed loosely: jax import stays deferred)
    factor: float = 4.0
    max_val_rmse: float = 0.35   # decades of log10(bound_s)
    min_val_points: int = 4
    require_calibration: bool = True

    last_rmse: float = field(default=float("nan"), init=False)
    last_val_n: int = field(default=0, init=False)
    pruned_total: int = field(default=0, init=False)
    _active: bool = field(default=False, init=False)

    @property
    def active(self) -> bool:
        """Whether the last :meth:`calibrate` call armed the gate."""
        return self._active

    def calibrate(self, db: CostDB) -> bool:
        """(Re)measure held-out validation error; enable/disable the gate."""
        cm = self.cost_model
        if cm is None or not getattr(cm, "trained", False):
            self._active = False
            return False
        if not self.require_calibration:
            self._active = True
            return True
        rmse, n = cm.validation_error(db)
        self.last_rmse, self.last_val_n = rmse, n
        self._active = bool(n >= self.min_val_points and rmse <= self.max_val_rmse)
        return self._active

    def prune_verdicts(self, points: Sequence[PlanPoint], workload: dict,
                       incumbent_bound: Optional[float],
                       ) -> List[Optional[Tuple[float, float]]]:
        """Per-point verdict: ``None`` = evaluate; ``(predicted_bound_s,
        p_feasible)`` = prune. Inactive gate / no incumbent = all pass."""
        if not self._active or incumbent_bound is None or not points:
            return [None] * len(points)
        feats = np.stack([featurize(dict(p.dims), workload) for p in points])
        b, pf = self.cost_model.predict(feats)
        out: List[Optional[Tuple[float, float]]] = []
        for bi, pfi in zip(b, pf):
            pred = float(10.0 ** float(bi))
            out.append((pred, float(pfi))
                       if pred > self.factor * incumbent_bound else None)
        self.pruned_total += sum(v is not None for v in out)
        return out
