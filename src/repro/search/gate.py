"""Surrogate-gated evaluation: skip compiles the cost model rules out.

LLM-DSE's "amortize expensive evaluations" lever: before a candidate reaches
a dry-run compile, predict its roofline bound with the learned surrogate and
prune it when the prediction is more than the gate threshold times the
incumbent. Pruned candidates are recorded as ``pruned`` data points carrying
the prediction (so RAG retrieval still surfaces them and later analysis can
audit the gate) — they are *not* used as fine-tuning targets, since they
have no measured outcome (see ``CostDB.training_set``).

Calibration guard: the gate stays disabled until the surrogate's validation
RMSE on held-out DB rows (a deterministic ~20% key-hash split the model
never trains on) drops below ``max_val_rmse`` decades of log10(bound).
Calibration is **per-cell when possible**: when the current ``(arch, shape,
mesh)`` cell holds at least ``min_val_points`` held-out rows, the guard
trusts the cell-local RMSE (a surrogate can be sharp on one workload and
useless on another); otherwise it falls back to the global validation set.
``require_calibration=False`` bypasses the guard — benchmarks/tests only.

Factor annealing: with ``min_factor`` set, the prune threshold tightens as
calibration improves — a linear map from validation RMSE to the effective
factor, ``factor`` (loose) at the guard limit down to ``min_factor``
(aggressive) at RMSE 0 — so a freshly-trusted surrogate prunes timidly and
a well-calibrated one prunes hard. ``min_factor=None`` (default) keeps the
threshold fixed at ``factor``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_db import CostDB, featurize
from repro.core.design_space import PlanPoint


@dataclass
class SurrogateGate:
    """Calibration-guarded pre-compile filter (see module docstring).
    ``factor`` is the loosest prune threshold as a multiple of the
    incumbent's measured ``bound_s``; ``min_factor`` (optional, must be in
    ``(1, factor]``) is the annealing target the threshold approaches as
    validation RMSE falls to 0; ``max_val_rmse`` is in decades of
    log10(bound_s). Fails safe: an untrained or badly-calibrated surrogate
    leaves the gate inactive and every candidate passes through to
    evaluation."""

    cost_model: object  # CostModel (typed loosely: jax import stays deferred)
    factor: float = 4.0
    min_factor: Optional[float] = None
    max_val_rmse: float = 0.35   # decades of log10(bound_s)
    min_val_points: int = 4
    require_calibration: bool = True

    last_rmse: float = field(default=float("nan"), init=False)
    last_val_n: int = field(default=0, init=False)
    last_scope: str = field(default="global", init=False)  # cell | global
    pruned_total: int = field(default=0, init=False)
    _active: bool = field(default=False, init=False)
    _annealed: Optional[float] = field(default=None, init=False)

    def __post_init__(self):
        """Reject an annealing target outside ``(1, factor]``."""
        if self.min_factor is not None and not (1.0 < self.min_factor
                                                <= self.factor):
            raise ValueError(f"min_factor must be in (1, factor={self.factor}"
                             f"], got {self.min_factor}")

    @property
    def active(self) -> bool:
        """Whether the last :meth:`calibrate` call armed the gate."""
        return self._active

    @property
    def effective_factor(self) -> float:
        """The prune threshold currently in force: the annealed factor from
        the last calibration when ``min_factor`` is set and the gate is
        active, else the configured ``factor``.

        Part of the gate **protocol contract**: the evaluator reads this
        property (no ``getattr`` fallback) when recording why a candidate
        was pruned, so every gate implementation — subclasses like
        :class:`~repro.search.ladder.PromotionLadder` included — must keep
        it equal to the threshold ``prune_verdicts`` actually applies."""
        return self.factor if self._annealed is None else self._annealed

    def calibrate(self, db: CostDB, *, arch: Optional[str] = None,
                  shape: Optional[str] = None,
                  mesh: Optional[str] = None) -> bool:
        """(Re)measure held-out validation error; enable/disable the gate
        and anneal the effective factor. With ``arch``/``shape`` given, the
        cell-local validation split is preferred whenever it holds at least
        ``min_val_points`` rows (``last_scope`` records which one decided);
        without them, or for a data-poor cell, the global split guards."""
        cm = self.cost_model
        if cm is None or not getattr(cm, "trained", False):
            self._active, self._annealed = False, None
            return False
        if not self.require_calibration:
            # guard bypassed (benchmarks/tests) — but annealing can still
            # track whatever validation error IS measurable, so
            # --gate-min-factor has an effect on the bypass path too
            self._active = True
            rmse, n = cm.validation_error(db)
            self.last_rmse, self.last_val_n, self.last_scope = rmse, n, "global"
            self._annealed = self._anneal(rmse)
            return True
        rmse, n, scope = float("nan"), 0, "global"
        # cheap pre-check off the incremental key index: a cell with fewer
        # measured designs than min_val_points cannot have enough held-out
        # rows, so skip the full cell-local validation scan entirely
        if (arch is not None and shape is not None
                and len(db.keys(arch, shape, include_pruned=False))
                >= self.min_val_points):
            c_rmse, c_n = cm.validation_error(db, arch=arch, shape=shape,
                                              mesh=mesh)
            if c_n >= self.min_val_points:
                rmse, n, scope = c_rmse, c_n, "cell"
        if scope == "global":
            rmse, n = cm.validation_error(db)
        self.last_rmse, self.last_val_n, self.last_scope = rmse, n, scope
        self._active = bool(n >= self.min_val_points and rmse <= self.max_val_rmse)
        self._annealed = self._anneal(rmse) if self._active else None
        return self._active

    def _anneal(self, rmse: float) -> Optional[float]:
        """The annealed threshold for a validation RMSE: a linear map from
        ``factor`` (at ``max_val_rmse`` or worse) down to ``min_factor``
        (at RMSE 0). ``None`` — meaning "use ``factor`` unchanged" — when
        annealing is off or the RMSE is unmeasurable (NaN)."""
        if self.min_factor is None or rmse != rmse:
            return None
        frac = min(max(rmse / self.max_val_rmse, 0.0), 1.0)
        return self.min_factor + (self.factor - self.min_factor) * frac

    def prune_verdicts(self, points: Sequence[PlanPoint], workload: dict,
                       incumbent_bound: Optional[float],
                       ) -> List[Optional[Tuple[float, float]]]:
        """Per-point verdict: ``None`` = evaluate; ``(predicted_bound_s,
        p_feasible)`` = prune (prediction beyond :attr:`effective_factor` x
        the incumbent). Inactive gate / no incumbent = all pass."""
        if not self._active or incumbent_bound is None or not points:
            return [None] * len(points)
        threshold = self.effective_factor * incumbent_bound
        feats = np.stack([featurize(dict(p.dims), workload) for p in points])
        b, pf = self.cost_model.predict(feats)
        out: List[Optional[Tuple[float, float]]] = []
        for bi, pfi in zip(b, pf):
            pred = float(10.0 ** float(bi))
            out.append((pred, float(pfi)) if pred > threshold else None)
        self.pruned_total += sum(v is not None for v in out)
        return out
