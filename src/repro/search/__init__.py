"""Pluggable search-strategy subsystem for the SECDA-DSE loop.

``DSELoop`` orchestrates (seed -> propose -> gate -> evaluate -> observe ->
fine-tune); the strategies here decide where to look. ``make_strategy``
builds any registered strategy by name — ``--strategy`` on the ``dse`` and
``campaign`` CLIs resolves through it.
"""
from __future__ import annotations

from typing import Optional

from repro.search.annealing import SimulatedAnnealing
from repro.search.base import (Candidate, SearchState, SearchStrategy,
                               best_negative, bound_of, point_of,
                               rank_candidates, select_candidates)
from repro.search.ensemble import Ensemble
from repro.search.evolutionary import Evolutionary
from repro.search.gate import SurrogateGate
from repro.search.greedy import GreedyNeighborhood
from repro.search.llm_guided import LLMGuided

STRATEGIES = ("greedy", "llm", "anneal", "evolve", "ensemble")


def make_strategy(name: str, *, llm_stack=None, seed: int = 0) -> SearchStrategy:
    """Build a fresh strategy instance (strategies carry per-cell state —
    campaigns must construct one per (arch, shape, mesh) cell)."""
    if name == "greedy":
        return GreedyNeighborhood(seed=seed)
    if name == "llm":
        if llm_stack is None:
            raise ValueError("strategy 'llm' needs llm_stack=")
        return LLMGuided(llm_stack)
    if name == "anneal":
        return SimulatedAnnealing(seed=seed)
    if name == "evolve":
        return Evolutionary(seed=seed)
    if name == "ensemble":
        members: list = [GreedyNeighborhood(seed=seed)]
        if llm_stack is not None:
            members.append(LLMGuided(llm_stack))
        members += [SimulatedAnnealing(seed=seed), Evolutionary(seed=seed)]
        return Ensemble(members)
    raise ValueError(f"unknown strategy {name!r}; have {STRATEGIES}")


__all__ = [
    "Candidate", "SearchState", "SearchStrategy", "STRATEGIES",
    "GreedyNeighborhood", "LLMGuided", "SimulatedAnnealing", "Evolutionary",
    "Ensemble", "SurrogateGate", "make_strategy",
    "best_negative", "bound_of", "point_of", "rank_candidates",
    "select_candidates",
]
