"""Pluggable search-strategy subsystem for the SECDA-DSE loop.

``DSELoop`` orchestrates (seed -> propose -> gate -> evaluate -> observe ->
fine-tune); the strategies here decide where to look. ``make_strategy``
builds any registered strategy by name — ``--strategy`` on the ``dse``,
``campaign``, and ``orchestrator`` CLIs resolves through it.
"""
from __future__ import annotations

from repro.search.annealing import SimulatedAnnealing
from repro.search.base import (WEIGHT_ARMS, Candidate, SearchState,
                               SearchStrategy, best_negative, bound_of,
                               point_of, rank_candidates, select_candidates,
                               weighted_objective)
from repro.search.ensemble import Ensemble
from repro.search.evolutionary import Evolutionary
from repro.search.gate import SurrogateGate
from repro.search.greedy import GreedyNeighborhood
from repro.search.ladder import (PromotionLadder, plan_promotions,
                                 select_measured_row)
from repro.search.llm_guided import LLMGuided
from repro.search.transfer import TransferSeeded

STRATEGIES = ("greedy", "llm", "anneal", "evolve", "transfer", "ensemble",
              "ensemble+transfer")


def make_strategy(name: str, *, llm_stack=None, seed: int = 0,
                  objective: str = "bound_s") -> SearchStrategy:
    """Build a fresh strategy instance (strategies carry per-cell state —
    campaigns must construct one per (arch, shape, mesh) cell).

    ``"ensemble"`` is the transfer-free bandit portfolio whose sharded
    campaigns merge byte-for-byte; ``"ensemble+transfer"`` adds the
    cross-workload :class:`~repro.search.transfer.TransferSeeded` member,
    trading that byte-reproducibility for warm starts from similar cells.

    ``objective="pareto"`` makes proposals cover the front instead of
    chasing one scalar head: the single-walker strategies (``anneal``,
    ``evolve``) scalarize through the ``balanced``
    :data:`~repro.search.base.WEIGHT_ARMS` vector, and the ensembles gain
    weight-armed members (``anneal@memory``, ``evolve@latency``, ...) so
    the bandit learns *which region of the front* pays — each arm's name
    rides into DB provenance (``search:anneal@memory``), keeping credit
    reconstruction offline-exact. ``objective="bound_s"`` (default) is
    bit-for-bit today's behavior. Raises ``ValueError`` for an unknown
    name or for ``"llm"`` / ``"ensemble*"``-with-LLM without an
    ``llm_stack``."""
    if objective not in ("bound_s", "pareto"):
        raise ValueError(f"unknown objective {objective!r}; "
                         f"have ('bound_s', 'pareto')")
    pareto = objective == "pareto"
    balanced = WEIGHT_ARMS["balanced"] if pareto else None
    if name == "greedy":
        return GreedyNeighborhood(seed=seed)
    if name == "llm":
        if llm_stack is None:
            raise ValueError("strategy 'llm' needs llm_stack=")
        return LLMGuided(llm_stack)
    if name == "anneal":
        return SimulatedAnnealing(seed=seed, weights=balanced)
    if name == "evolve":
        return Evolutionary(seed=seed, weights=balanced)
    if name == "transfer":
        return TransferSeeded(seed=seed)
    if name in ("ensemble", "ensemble+transfer"):
        members: list = [GreedyNeighborhood(seed=seed)]
        if llm_stack is not None:
            members.append(LLMGuided(llm_stack))
        members += [SimulatedAnnealing(seed=seed, weights=balanced),
                    Evolutionary(seed=seed, weights=balanced)]
        if pareto:
            # weight-armed walkers: distinct deterministic seed offsets so
            # each arm explores its own trajectory; names carry the arm
            # into provenance for the bandit's offline credit rebuild
            members += [
                SimulatedAnnealing(name="anneal@latency", seed=seed + 11,
                                   weights=WEIGHT_ARMS["latency"]),
                SimulatedAnnealing(name="anneal@memory", seed=seed + 12,
                                   weights=WEIGHT_ARMS["memory"]),
                Evolutionary(name="evolve@latency", seed=seed + 13,
                             weights=WEIGHT_ARMS["latency"]),
                Evolutionary(name="evolve@memory", seed=seed + 14,
                             weights=WEIGHT_ARMS["memory"]),
            ]
        if name == "ensemble+transfer":
            members.append(TransferSeeded(seed=seed))
        return Ensemble(members)
    raise ValueError(f"unknown strategy {name!r}; have {STRATEGIES}")


__all__ = [
    "Candidate", "SearchState", "SearchStrategy", "STRATEGIES",
    "WEIGHT_ARMS", "GreedyNeighborhood", "LLMGuided", "SimulatedAnnealing",
    "Evolutionary", "TransferSeeded", "Ensemble", "SurrogateGate",
    "PromotionLadder", "plan_promotions", "select_measured_row",
    "make_strategy", "best_negative", "bound_of", "point_of",
    "rank_candidates", "select_candidates", "weighted_objective",
]
