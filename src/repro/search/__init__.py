"""Pluggable search-strategy subsystem for the SECDA-DSE loop.

``DSELoop`` orchestrates (seed -> propose -> gate -> evaluate -> observe ->
fine-tune); the strategies here decide where to look. ``make_strategy``
builds any registered strategy by name — ``--strategy`` on the ``dse``,
``campaign``, and ``orchestrator`` CLIs resolves through it.
"""
from __future__ import annotations

from repro.search.annealing import SimulatedAnnealing
from repro.search.base import (Candidate, SearchState, SearchStrategy,
                               best_negative, bound_of, point_of,
                               rank_candidates, select_candidates)
from repro.search.ensemble import Ensemble
from repro.search.evolutionary import Evolutionary
from repro.search.gate import SurrogateGate
from repro.search.greedy import GreedyNeighborhood
from repro.search.ladder import (PromotionLadder, plan_promotions,
                                 select_measured_row)
from repro.search.llm_guided import LLMGuided
from repro.search.transfer import TransferSeeded

STRATEGIES = ("greedy", "llm", "anneal", "evolve", "transfer", "ensemble",
              "ensemble+transfer")


def make_strategy(name: str, *, llm_stack=None, seed: int = 0) -> SearchStrategy:
    """Build a fresh strategy instance (strategies carry per-cell state —
    campaigns must construct one per (arch, shape, mesh) cell).

    ``"ensemble"`` is the transfer-free bandit portfolio whose sharded
    campaigns merge byte-for-byte; ``"ensemble+transfer"`` adds the
    cross-workload :class:`~repro.search.transfer.TransferSeeded` member,
    trading that byte-reproducibility for warm starts from similar cells.
    Raises ``ValueError`` for an unknown name or for ``"llm"`` /
    ``"ensemble*"``-with-LLM without an ``llm_stack``."""
    if name == "greedy":
        return GreedyNeighborhood(seed=seed)
    if name == "llm":
        if llm_stack is None:
            raise ValueError("strategy 'llm' needs llm_stack=")
        return LLMGuided(llm_stack)
    if name == "anneal":
        return SimulatedAnnealing(seed=seed)
    if name == "evolve":
        return Evolutionary(seed=seed)
    if name == "transfer":
        return TransferSeeded(seed=seed)
    if name in ("ensemble", "ensemble+transfer"):
        members: list = [GreedyNeighborhood(seed=seed)]
        if llm_stack is not None:
            members.append(LLMGuided(llm_stack))
        members += [SimulatedAnnealing(seed=seed), Evolutionary(seed=seed)]
        if name == "ensemble+transfer":
            members.append(TransferSeeded(seed=seed))
        return Ensemble(members)
    raise ValueError(f"unknown strategy {name!r}; have {STRATEGIES}")


__all__ = [
    "Candidate", "SearchState", "SearchStrategy", "STRATEGIES",
    "GreedyNeighborhood", "LLMGuided", "SimulatedAnnealing", "Evolutionary",
    "TransferSeeded", "Ensemble", "SurrogateGate", "PromotionLadder",
    "plan_promotions", "select_measured_row", "make_strategy",
    "best_negative", "bound_of", "point_of", "rank_candidates",
    "select_candidates",
]
