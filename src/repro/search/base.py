"""Pluggable search-strategy protocol (SECDA-DSE's interchangeable engines).

The paper's pitch is that the DSE Explorer and the LLM Stack are
*interchangeable proposal engines* feeding one evaluation loop. This module
makes that literal: a :class:`SearchStrategy` is anything with

    propose(state)  -> candidates to evaluate this iteration
    observe(dps)    -> ingest the evaluated results (positive AND negative)

``DSELoop`` owns the rest (dedupe, surrogate ranking, the surrogate gate,
batch evaluation, DB appends, periodic fine-tuning); strategies only decide
*where to look next*. Every candidate carries a provenance ``source`` tag
that lands in the cost DB's ``source`` field, so credit assignment (see
:class:`~repro.search.ensemble.Ensemble`) is reconstructable from the DB
alone.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.cost_db import (MAXIMIZE_OBJECTIVES, CostDB, DataPoint,
                                featurize, objectives_of)
from repro.core.design_space import PlanPoint, PlanTemplate

# Named scalarization-weight vectors for Pareto campaigns: each arm turns
# the objective vector into one weighted log-scale score, so the existing
# single-score walkers (anneal, evolve) can sweep different regions of the
# front without learning a new acceptance rule. Keys index into a row's
# ``objectives`` dict; keys a row lacks (plan vs kernel vectors differ) are
# simply skipped and the weights renormalized, so one arm table serves both
# design spaces. Under ``--objective pareto`` the Ensemble runs these as
# extra bandit members (``anneal@memory`` etc.), and the arm name lands in
# DB provenance via the member name.
WEIGHT_ARMS: Dict[str, Dict[str, float]] = {
    "latency": {"bound_s": 1.0},
    "memory": {"bound_s": 1.0, "hbm_bytes": 1.0, "vmem_bytes": 1.0,
               "vmem_util": 1.0},
    "balanced": {"bound_s": 1.0, "hbm_bytes": 0.5, "vmem_bytes": 0.5,
                 "vmem_util": 0.5, "flops_util": 0.5},
}


def weighted_objective(dp: Optional[DataPoint],
                       weights: Optional[Dict[str, float]],
                       ) -> Optional[float]:
    """One weighted scalar score (lower is better) for a feasible row's
    objective vector: the weight-normalized sum of ``log10`` objective
    values, maximize-sense objectives negated. Log scale keeps objectives
    of wildly different magnitudes (seconds vs bytes) commensurable — a
    weight point buys a *decade* in any objective. ``None``/empty weights,
    or a row whose objectives carry none of the weighted keys, fall back
    to :func:`bound_of`; missing/failed rows return ``None``."""
    if dp is None or dp.status != "ok":
        return None
    if not weights:
        return bound_of(dp)
    objs = objectives_of(dp)
    total = wsum = 0.0
    for k in sorted(weights):
        v = objs.get(k)
        if v is None or not v > 0:
            continue
        term = math.log10(v)
        if k in MAXIMIZE_OBJECTIVES:
            term = -term
        total += weights[k] * term
        wsum += weights[k]
    if wsum == 0.0:
        return bound_of(dp)
    return total / wsum


@dataclass(frozen=True)
class Candidate:
    """A proposed design plus its provenance (recorded as DB ``source``)."""

    point: PlanPoint
    source: str


@dataclass
class SearchState:
    """Read-only view of the loop's state handed to strategies each iteration."""

    arch: str
    shape: str
    cfg: Any
    cell: Any
    template: PlanTemplate
    db: CostDB
    iteration: int
    budget: int
    incumbent: Optional[DataPoint]
    pool: List[DataPoint] = field(default_factory=list)
    cost_model: Any = None  # Optional[CostModel]; avoids a jax import here
    workload: Dict[str, float] = field(default_factory=dict)
    # the evaluator's mesh name; mesh-scoped DB lookups (credit rebuild,
    # transfer donors) use it so a DB holding the same (arch, shape) on two
    # meshes never mixes measurements. None = unscoped (legacy/tests).
    mesh: Optional[str] = None


@runtime_checkable
class SearchStrategy(Protocol):
    """propose(state) -> candidates; observe(datapoints) -> None."""

    name: str

    def propose(self, state: SearchState) -> List[Candidate]:
        """Return candidate designs for this iteration. May over-propose:
        the loop dedupes against measured DB keys, surrogate-ranks, and
        truncates to ``state.budget``. Must be deterministic given the
        strategy's seed, the state, and the DB contents; must never raise
        on an empty DB or missing incumbent. Each candidate carries its
        provenance ``source`` tag (``search:<name>``) for the DB."""
        ...

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """Ingest every evaluated result of the iteration — positive,
        negative (infeasible/error/rejected), and gate-``pruned`` rows
        alike; strategies self-filter. Called exactly once per loop
        iteration, after the batch lands in the DB."""
        ...


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def point_of(dp: DataPoint) -> PlanPoint:
    """A DataPoint's design, stripped of the derived ``__key__`` entry."""
    return PlanPoint(dims={k: v for k, v in dp.point.items() if k != "__key__"})


def bound_of(dp: Optional[DataPoint]) -> Optional[float]:
    """The measured roofline bound in seconds, or ``None`` for a missing,
    failed, or infeasible data point."""
    if dp is None or dp.status != "ok":
        return None
    return dp.metrics.get("bound_s")


def best_negative(db: CostDB, arch: str, shape: str,
                  incumbent: DataPoint) -> Optional[DataPoint]:
    """Fastest *infeasible* design that beats the incumbent's bound — the
    paper's §3.2.2 negative-datapoint chaining seed."""
    inc = incumbent.metrics.get("bound_s") or float("inf")
    neg = [d for d in db.query(arch, shape, "infeasible")
           if d.metrics.get("bound_s") and d.metrics["bound_s"] < 0.9 * inc]
    return min(neg, key=lambda d: d.metrics["bound_s"]) if neg else None


def rank_candidates(state: SearchState,
                    cands: Sequence[Candidate]) -> List[Candidate]:
    """Surrogate pre-ranking (cheapest-predicted-bound first); insertion
    order when the model is absent/untrained — exactly the old Explorer
    behavior, now shared by the loop and the Ensemble's per-member cuts."""
    cm = state.cost_model
    if cm is None or not getattr(cm, "trained", False) or not cands:
        return list(cands)
    feats = np.stack([featurize(dict(c.point.dims), state.workload)
                      for c in cands])
    order = cm.rank_candidates(feats)
    return [cands[i] for i in order]


def select_candidates(state: SearchState, cands: Sequence[Candidate],
                      ) -> List[Candidate]:
    """The shared selection pipeline (DSELoop, Explorer): dedupe against the
    cell's *measured* design keys (gate-pruned designs stay proposable) and
    in-batch, surrogate-rank, truncate to the iteration budget."""
    seen = state.db.keys(state.arch, state.shape, include_pruned=False)
    uniq: Dict[str, Candidate] = {}
    for c in cands:
        k = c.point.key()
        if k not in seen and k not in uniq:
            uniq[k] = c
    return rank_candidates(state, list(uniq.values()))[: state.budget]


def repair(template: PlanTemplate, point: PlanPoint) -> PlanPoint:
    """Template-delegated candidate repair: each design space owns its own
    cross-dimension fixes (``PlanTemplate.repair`` drops a clashing
    microbatch count to 1; ``KernelTemplate.repair`` shrinks tile dims to
    VMEM feasibility), so strategies stay design-space-agnostic."""
    return template.repair(point)


def mutate(template: PlanTemplate, point: PlanPoint, rng: random.Random,
           n_dims: int = 1) -> PlanPoint:
    """Mutate ``n_dims`` randomly-chosen dimensions to random legal values."""
    legal = template.dims()
    keys = sorted(legal)
    dims = dict(point.dims)
    for k in rng.sample(keys, min(n_dims, len(keys))):
        pool = [v for v in legal[k] if v != dims.get(k)] or list(legal[k])
        dims[k] = pool[rng.randrange(len(pool))]
    return repair(template, PlanPoint(dims=dims))
