"""Greedy incumbent-neighborhood search — the old Explorer policy, extracted.

Proposes all single-dimension mutations of the incumbent (the template's
device-aware permutation set) plus a few random template samples for
diversity (paper §3.2.2). Stateless: the loop's incumbent pool IS its state.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List, Sequence

from repro.core.cost_db import DataPoint
from repro.search.base import Candidate, SearchState, point_of


@dataclass
class GreedyNeighborhood:
    """The extracted Explorer policy: exhaustive single-dimension mutations
    of the incumbent plus ``n_random`` random template samples. Stateless
    and deterministic given ``seed`` and the iteration index."""

    name: str = "greedy"
    seed: int = 0
    n_random: int = 1

    def propose(self, state: SearchState) -> List[Candidate]:
        """The incumbent's full device-legal neighborhood (empty when the
        cell has no incumbent yet) plus ``n_random`` repaired random
        samples; typically far more candidates than the budget — the loop's
        surrogate ranking decides which survive the cut."""
        rng = random.Random(self.seed + state.iteration)
        out: List[Candidate] = []
        if state.incumbent is not None:
            out += [Candidate(p, f"search:{self.name}")
                    for p in state.template.neighbors(point_of(state.incumbent))]
        out += [Candidate(p, f"search:{self.name}")
                for p in state.template.random_points(rng, self.n_random)]
        return out

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """No-op: greedy state lives in the loop's incumbent pool."""
