"""Cross-workload transfer search: seed a cell from similar finished cells.

LLM-DSE (arXiv:2505.12188) and iDSE (arXiv:2505.22086) both attribute their
edge over blind search to *reusing prior-design context*. This strategy makes
that a first-class proposal engine: a new cell's initial population is
transplanted from the **winners** of the most similar already-explored cells
in the shared cost DB, ranked by the same featurized cosine similarity RAG
retrieval uses (:mod:`repro.core.rag`). Donor designs are *adapted* into the
target cell's device-aware template — dimensions whose donor value is illegal
here snap to the expert baseline preference — so a transplant is always a
valid candidate, never a template rejection.

After the transplants are spent, the strategy polishes: it mutates around the
best design it has personally produced (or the loop incumbent), so it keeps
earning budget in an :class:`~repro.search.ensemble.Ensemble` portfolio after
the seeding phase.

Determinism: given a fixed DB file, seed, and iteration, proposals are fully
deterministic (donor ties break lexicographically, mutations use a seeded
RNG). Note the caveat this implies for sharded campaigns: the *shared DB* a
cell sees depends on which cells ran before it in the same process, so a
sharded run with transfer enabled may legitimately explore differently than a
single-process run — byte-identical shard/merge reproduction is only
guaranteed for the transfer-free strategies (see docs/architecture.md).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.cost_db import DataPoint, featurize
from repro.core.design_space import PlanPoint, PlanTemplate, baseline_point
from repro.search.base import (Candidate, SearchState, mutate, point_of,
                               repair)


def adapt_point(template: PlanTemplate, point: PlanPoint,
                fallback: Optional[PlanPoint] = None) -> Optional[PlanPoint]:
    """Project a donor-cell design into ``template``'s legal ranges.

    Every template dimension takes the donor's value when legal here,
    otherwise the ``fallback`` (expert baseline) value, otherwise the first
    legal value; donor-only dimensions are dropped. The result is repaired
    for cross-dimension constraints and re-validated — returns ``None`` if
    even the repaired point is illegal (the caller must skip it), so this
    function never emits a template rejection."""
    legal = template.dims()
    fb = fallback.dims if fallback is not None else {}
    dims = {}
    for k, vals in legal.items():
        v = point.dims.get(k)
        if v not in vals:
            v = fb.get(k) if fb.get(k) in vals else vals[0]
        dims[k] = v
    p = repair(template, PlanPoint(dims=dims))
    ok, _ = template.validate(p)
    return p if ok else None


@dataclass
class TransferSeeded:
    """Transfer-seeded search over the shared campaign DB.

    ``k_donor_cells`` similar cells each contribute their ``per_donor``
    fastest feasible designs as the initial population; later iterations
    mutate around the best own result. Stateful per cell (donor scouting
    happens once, on first :meth:`propose`) — campaigns must construct a
    fresh instance per cell, like every other strategy."""

    name: str = "transfer"
    seed: int = 0
    k_donor_cells: int = 3
    per_donor: int = 2

    _seeds: List[PlanPoint] = field(default_factory=list, init=False)
    _scouted: bool = field(default=False, init=False)
    _proposed: Set[str] = field(default_factory=set, init=False)
    _best_own: Optional[Tuple[PlanPoint, float]] = field(default=None,
                                                         init=False)

    # ------------------------------------------------------------------
    def donor_cells(self, state: SearchState) -> List[Tuple[float, str, str]]:
        """Similarity-ranked ``(cosine, arch, shape)`` donor cells.

        A donor is any *other* cell in the DB holding at least one feasible
        measured design on this cell's mesh (unscoped when ``state.mesh`` is
        None — a cross-mesh bound is not comparable). Similarity is cosine
        over the shared featurization of the cells' workload context (the
        vector RAG retrieval uses), so e.g. decode cells prefer decode
        donors. Ties break lexicographically by (arch, shape) —
        deterministic for a fixed DB."""
        me = (state.arch, state.shape)
        q = featurize({}, state.workload)
        qn = float(np.linalg.norm(q)) or 1.0
        donors = {}
        for d in state.db.all():
            cell = (d.arch, d.shape)
            if cell == me or cell in donors or d.status != "ok":
                continue
            if state.mesh is not None and d.mesh != state.mesh:
                continue
            wl = d.metrics.get("workload")
            if wl and d.metrics.get("bound_s"):
                donors[cell] = wl
        scored = []
        for (a, s), wl in donors.items():
            v = featurize({}, wl)
            sim = float(v @ q) / ((float(np.linalg.norm(v)) or 1.0) * qn)
            scored.append((sim, a, s))
        scored.sort(key=lambda t: (-t[0], t[1], t[2]))
        return scored[: self.k_donor_cells]

    def _transplants(self, state: SearchState) -> List[PlanPoint]:
        """Adapted winner designs from the donor cells, best donors first,
        deduplicated by design key (donors often share a winning plan)."""
        fb = baseline_point(state.cell, state.template)
        out: List[PlanPoint] = []
        seen: Set[str] = set()
        for _sim, a, s in self.donor_cells(state):
            for w in state.db.winners(a, s, k=self.per_donor,
                                      mesh=state.mesh):
                p = adapt_point(state.template, point_of(w), fb)
                if p is not None and p.key() not in seen:
                    seen.add(p.key())
                    out.append(p)
        return out

    # ------------------------------------------------------------------
    def propose(self, state: SearchState) -> List[Candidate]:
        """Un-spent transplants first, then seeded mutations around the best
        own result (or the incumbent; random template samples when neither
        exists). Always returns exactly ``max(state.budget, 1)`` candidates;
        with an empty DB it degrades to deterministic random exploration."""
        if not self._scouted:
            self._scouted = True
            self._seeds = self._transplants(state)
        budget = max(state.budget, 1)
        out: List[Candidate] = []
        while self._seeds and len(out) < budget:
            out.append(Candidate(self._seeds.pop(0), f"search:{self.name}"))
        rng = random.Random(self.seed * 9173 + state.iteration)
        base = (self._best_own[0] if self._best_own is not None
                else point_of(state.incumbent)
                if state.incumbent is not None else None)
        for _ in range(budget - len(out)):
            p = (mutate(state.template, base, rng, 1) if base is not None
                 else state.template.random_points(rng, 1)[0])
            out.append(Candidate(p, f"search:{self.name}"))
        for c in out:
            self._proposed.add(c.point.key())
        return out

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """Adopt the fastest feasible *own-proposed* result as the next
        mutation base. Results from other strategies are ignored — the
        transplanted lineage is what this engine is credited for."""
        mine = [d for d in datapoints
                if d.point.get("__key__") in self._proposed
                and d.status == "ok" and d.metrics.get("bound_s")]
        if not mine:
            return
        best = min(mine, key=lambda d: d.metrics["bound_s"])
        b = best.metrics["bound_s"]
        if self._best_own is None or b < self._best_own[1]:
            self._best_own = (point_of(best), b)
