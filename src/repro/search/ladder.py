"""Multi-fidelity promotion ladder: surrogate → dry-run → measured.

Three tiers, each an order of magnitude more expensive than the last:

* **tier 0 — surrogate** (free): the learned :class:`CostModel` predicts
  log10(bound) per candidate; the inherited :class:`SurrogateGate` logic
  prunes hopeless designs before they cost anything.
* **tier 1 — dry-run** (seconds): ``launch/dryrun.run_cell`` compiles the
  survivor and reads the analytical roofline bound off the HLO (cached,
  content-addressed).
* **tier 2 — measured** (the real thing): only leaderboard *heads* are
  promoted — ``launch/measure.measure_cell`` executes the compiled step and
  times it, and the wall clock lands in the cost DB as a
  ``fidelity="measured"`` row.

The feedback loop is what makes the ladder a perf optimisation rather than
an extra expense: :meth:`PromotionLadder.calibrate` folds prediction-vs-
measured error (offset-corrected, see ``CostModel.measured_calibration``)
into the factor annealing, so wall-clock confirmation *tightens* tier-0
pruning — better calibration ⇒ more aggressive surrogate gate ⇒ fewer tier-1
compiles per incumbent improvement (the ``bench_dse_throughput --ladder``
headline number).

The two decision functions — which heads to promote, which duplicate
measured row is canonical — are module-level **pure functions** (RPR003
registry): same inputs, same promotions, on every shard and every replay.
They live in the jax-free ``repro.core.promotion`` (the supervisor-side
leaderboard rebuild needs them without paying a jax import) and are
re-exported here for the search-facing API.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.promotion import plan_promotions, select_measured_row
from repro.search.gate import SurrogateGate

__all__ = ["PromotionLadder", "plan_promotions", "select_measured_row"]


@dataclass
class PromotionLadder(SurrogateGate):
    """A :class:`SurrogateGate` whose annealing also listens to tier-2.

    Inherits the whole gate protocol (``calibrate`` / ``prune_verdicts`` /
    ``effective_factor`` / ``active``) so the evaluator and DSE loop use it
    unchanged. The one behavioural extension: once at least
    ``min_measured_points`` measured rows exist, the offset-corrected
    prediction-vs-measured RMSE joins the annealing signal — the effective
    factor anneals on the *better* (smaller) of validation RMSE and
    measured RMSE, and only ever moves the threshold tighter than the
    validation-only gate would. Wall-clock agreement is strictly stronger
    evidence than held-out-bound agreement, never weaker: a noisy measured
    RMSE cannot loosen a gate the validation split already earned."""

    min_measured_points: int = 3

    last_measured_rmse: float = field(default=float("nan"), init=False)
    last_measured_n: int = field(default=0, init=False)
    measured_offset: float = field(default=float("nan"), init=False)

    def calibrate(self, db, *, arch: Optional[str] = None,
                  shape: Optional[str] = None,
                  mesh: Optional[str] = None) -> bool:
        """Run the inherited validation-split calibration, then fold in the
        measured-row calibration (see class docstring). ``last_measured_*``
        and ``measured_offset`` always reflect the latest scan, whether or
        not they moved the threshold."""
        active = super().calibrate(db, arch=arch, shape=shape, mesh=mesh)
        cm = self.cost_model
        if cm is None or not getattr(cm, "trained", False):
            return active
        m_rmse, m_n, m_off = cm.measured_calibration(db, arch=arch,
                                                     shape=shape, mesh=mesh)
        self.last_measured_rmse = m_rmse
        self.last_measured_n = m_n
        self.measured_offset = m_off
        if not active or m_n < self.min_measured_points or m_rmse != m_rmse:
            return active
        v_rmse = self.last_rmse
        joint = m_rmse if v_rmse != v_rmse else min(v_rmse, m_rmse)
        cand = self._anneal(joint)
        if cand is not None and (self._annealed is None
                                 or cand < self._annealed):
            self._annealed = cand
        return active
