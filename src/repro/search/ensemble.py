"""Budget-splitting ensemble with bandit-style credit assignment.

Each iteration the per-iteration evaluation budget is divided among member
strategies in proportion to their credit — an exponentially-decayed count of
incumbent improvements their candidates produced. Because every candidate's
provenance is recorded in the cost DB ``source`` field (``search:<member>``),
the credit ledger is reconstructable offline from the DB alone.

Allocation uses largest-remainder rounding and, when the budget allows,
guarantees every member at least one slot — a standing exploration floor so
a cold strategy can always earn credit back (the classic bandit tension).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence

from repro.core.cost_db import CostDB, DataPoint
from repro.search.base import (Candidate, SearchState, SearchStrategy,
                               bound_of, rank_candidates)


@dataclass
class Ensemble:
    """Bandit portfolio over ``members``: per-iteration budget split in
    proportion to exponentially-decayed improvement credit (see module
    docstring). ``warm_start`` rebuilds the ledger from the cell's DB rows
    on first propose, so a resumed campaign keeps its learned allocation.
    Deterministic given deterministic members and a fixed DB."""

    members: List[SearchStrategy]
    name: str = "ensemble"
    decay: float = 0.8    # credit half-life ~3 iterations
    credit: Dict[str, float] = field(default_factory=dict)
    warm_start: bool = True

    _best_seen: Optional[float] = field(default=None, init=False)
    _warmed: bool = field(default=False, init=False)

    def __post_init__(self):
        """Seed a zero-credit ledger entry for every member."""
        for m in self.members:
            self.credit.setdefault(m.name, 0.0)

    # ------------------------------------------------------------------
    def rebuild_credit(self, db: CostDB, arch: str, shape: str,
                       mesh: Optional[str] = None) -> None:
        """Reconstruct the bandit ledger from the cell's DB ``source`` rows.

        Replays :meth:`CostDB.iteration_batches` in order: each recorded
        loop iteration (index >= 1) applies one decay step per iteration
        *gap* (an iteration that recorded no rows still decayed in-memory),
        then every feasible row that improved on the running best credits
        the member named by its ``search:<member>`` provenance tag. The
        first best (the iteration-0 expert seed) earns no credit, matching
        the live allocator. No-op on a cell with no rows. The replayed
        ledger matches the in-memory one exactly when the recorded
        iteration indices are contiguous per attempt; after a mid-cell
        crash the two attempts' same-numbered iterations merge, which
        preserves the learned *allocation* if not bit-exact credit.
        ``mesh`` scopes the replay to one mesh's measurements (a DB re-run
        under a different ``--mesh`` holds both); ``None`` = unscoped."""
        batches = db.iteration_batches(arch, shape, mesh=mesh)
        if not batches:
            return
        credit = {m.name: 0.0 for m in self.members}
        best: Optional[float] = None
        prev_it: Optional[int] = None
        for it, rows in batches:
            if it >= 1:
                steps = 1 if prev_it is None else max(it - prev_it, 1)
                for n in credit:
                    credit[n] *= self.decay ** steps
                prev_it = it
            for d in rows:
                if d.status != "ok" or not d.metrics.get("bound_s"):
                    continue
                b = d.metrics["bound_s"]
                if best is None or b < best:
                    if best is not None:
                        name = d.source.split(":", 1)[-1]
                        if name in credit:
                            credit[name] += 1.0
                    best = b
        self.credit.update(credit)
        if best is not None and (self._best_seen is None
                                 or best < self._best_seen):
            self._best_seen = best

    # ------------------------------------------------------------------
    def allocation(self, budget: int) -> Dict[str, int]:
        """Split ``budget`` proportionally to (1 + credit), largest remainder."""
        if budget <= 0 or not self.members:
            return {m.name: 0 for m in self.members}
        weights = {m.name: 1.0 + self.credit.get(m.name, 0.0) for m in self.members}
        total = sum(weights.values())
        floor = 1 if budget >= len(self.members) else 0
        spendable = budget - floor * len(self.members)
        exact = {n: spendable * w / total for n, w in weights.items()}
        alloc = {n: floor + int(exact[n]) for n in weights}
        # largest remainder, ties broken by member order (deterministic)
        remainders = sorted(weights, key=lambda n: (-(exact[n] - int(exact[n])),
                                                    [m.name for m in self.members].index(n)))
        for n in remainders[: budget - sum(alloc.values())]:
            alloc[n] += 1
        return alloc

    def propose(self, state: SearchState) -> List[Candidate]:
        """Collect each member's share of the iteration budget (allocation
        by credit), deduped against the cell's measured designs and
        surrogate-ranked per member; a member out of novel designs forfeits
        its slots to the others' surplus. On the first call, ``warm_start``
        rebuilds credit from the cell's existing DB rows (resume path)."""
        if not self._warmed:
            self._warmed = True
            if self.warm_start:
                self.rebuild_credit(state.db, state.arch, state.shape,
                                    mesh=state.mesh)
        # credit baseline = the loop's actual incumbent (which includes the
        # expert seed the members never proposed) — beating a stale
        # internal best-seen is not an improvement worth budget
        inc_b = bound_of(state.incumbent)
        if inc_b is not None and (self._best_seen is None
                                  or inc_b < self._best_seen):
            self._best_seen = inc_b
        alloc = self.allocation(state.budget)
        # dedupe against the DB *before* cutting each member to its share —
        # otherwise a member re-proposing already-evaluated designs (greedy
        # around an unchanged incumbent) silently shrinks the iteration.
        # Measured keys only: gate-pruned designs remain proposable.
        seen = set(state.db.keys(state.arch, state.shape,
                                 include_pruned=False))
        out: List[Candidate] = []
        surplus: List[Candidate] = []
        for m in self.members:
            share = alloc.get(m.name, 0)
            if share <= 0:
                continue
            sub = replace(state, budget=share)
            # each member's cut is surrogate-ranked before truncation so a
            # wide proposer (greedy's full neighborhood) spends its share well
            taken = 0
            for c in rank_candidates(sub, m.propose(sub)):
                k = c.point.key()
                if k in seen:
                    continue
                seen.add(k)
                if taken < share:
                    out.append(c)
                    taken += 1
                else:
                    surplus.append(c)
        # a member that ran out of novel designs forfeits its slots to the
        # others' surplus, keeping the evaluation budget fully spent
        out += surplus[: state.budget - len(out)]
        return out

    def observe(self, datapoints: Sequence[DataPoint]) -> None:
        """Decay every member's credit one step, then award +1 to the
        provenance member of each result that improved the best-seen bound;
        finally fan the full batch out to every member (they self-filter).
        The very first best-seen (the expert seed) earns no credit."""
        for name in self.credit:
            self.credit[name] *= self.decay
        for d in datapoints:
            if d.status != "ok" or not d.metrics.get("bound_s"):
                continue
            b = d.metrics["bound_s"]
            if self._best_seen is None or b < self._best_seen:
                if self._best_seen is not None:  # an actual improvement
                    name = d.source.split(":", 1)[-1]
                    if name in self.credit:
                        self.credit[name] += 1.0
                self._best_seen = b
        for m in self.members:
            m.observe(datapoints)
