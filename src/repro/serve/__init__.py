"""repro subpackage."""
