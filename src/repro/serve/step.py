"""Serving steps: prefill and single-token decode, jit-able with plan
shardings. ``decode_attn="sp_shardmap"`` swaps the GSPMD decode attention for
the explicit sequence-parallel shard_map kernel (flash-decoding style)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.serve.sp_attention import make_sp_decode


class ServeCtx:
    """Callable constrain hook that also carries the sp-decode kernel."""

    def __init__(self, constrain_fn, sp_decode=None):
        self._fn = constrain_fn
        self.attn_impl = getattr(constrain_fn, "attn_impl", "chunked")
        if sp_decode is not None:
            self.sp_decode = sp_decode

    def __call__(self, x, kind):
        return self._fn(x, kind)


def make_ctx(cfg, plan, mesh: Optional[Mesh], *, decode: bool = False) -> ServeCtx:
    constrain = plan.make_constrain(mesh)
    sp = None
    if decode and mesh is not None and plan.decode_attn == "sp_shardmap":
        sp = make_sp_decode(mesh, plan)
    return ServeCtx(constrain, sp)


def make_prefill_step(cfg, plan, mesh: Optional[Mesh] = None):
    ctx = make_ctx(cfg, plan, mesh, decode=False)

    def prefill_step(params, batch, cache):
        return M.prefill_fn(cfg, params, batch, cache, ctx)

    return prefill_step


def make_decode_step(cfg, plan, mesh: Optional[Mesh] = None):
    ctx = make_ctx(cfg, plan, mesh, decode=True)

    def decode_step(params, batch, cache):
        logits, new_cache = M.decode_fn(cfg, params, batch, cache, ctx)
        return logits, new_cache

    return decode_step


def serve_shardings(cfg, plan, mesh: Mesh, specs_inputs):
    """NamedShardings for (params, batch, cache) of a serve step."""
    values, logical = M.abstract_params(cfg)
    pshard = plan.param_shardings(mesh, values, logical)
    bspec = plan.batch_specs(mesh, specs_inputs["batch"])
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)
    cshard = None
    if "cache" in specs_inputs:
        cspec = plan.cache_specs(mesh, specs_inputs["cache"])
        cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec)
    return pshard, bshard, cshard
