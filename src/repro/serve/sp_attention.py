"""Sequence-parallel decode attention via shard_map.

KV caches for long contexts are sharded on the *sequence* dim over the
``model`` axis (GQA head counts rarely divide a 16-way TP axis). The naive
GSPMD lowering all-gathers the whole cache every layer; this explicit
shard_map version keeps KV local and combines per-shard softmax statistics
with two tiny collectives (flash-decoding style):

    m_g   = pmax(m_local)                  [b, kh, g]
    l_g   = psum(l_local * exp(m_l - m_g))
    acc_g = psum(acc_local * exp(m_l - m_g))

It also performs the new-token cache insert locally on the owning shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

NEG_INF = -1e30


def make_sp_decode(mesh: Mesh, plan, *, axis: str = "model"):
    """Returns sp_decode(q, k_new, v_new, kc, vc, slot, kv_len) -> (o, kc, vc).

    q:[b,1,h,d] k_new/v_new:[b,1,kh,d] kc/vc:[b,S,kh,d] slot/kv_len:[b].
    """
    if axis not in mesh.shape:
        return None
    n_shards = mesh.shape[axis]

    def inner(q, k_new, v_new, kc, vc, slot, kv_len):
        b, _, h, d = q.shape
        S_l = kc.shape[1]
        kh = kc.shape[2]
        g = h // kh
        i = jax.lax.axis_index(axis)
        start = i * S_l

        # ---- local cache insert on the owning shard ----
        local_slot = slot - start
        in_range = (local_slot >= 0) & (local_slot < S_l)
        idx = jnp.clip(local_slot, 0, S_l - 1)
        bidx = jnp.arange(b)
        upd_k = jnp.where(in_range[:, None, None], k_new[:, 0], kc[bidx, idx])
        upd_v = jnp.where(in_range[:, None, None], v_new[:, 0], vc[bidx, idx])
        kc = kc.at[bidx, idx].set(upd_k)
        vc = vc.at[bidx, idx].set(upd_v)

        # ---- local partial attention ----
        qg = q.reshape(b, kh, g, d)
        s = jnp.einsum("bkgd,bskd->bkgs", qg, kc, preferred_element_type=jnp.float32)
        s = s / np.sqrt(d)
        valid = (start + jnp.arange(S_l))[None, :] < kv_len[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_l = s.max(-1)
        l_partial = jnp.exp(s - m_l[..., None])
        acc_l = jnp.einsum("bkgs,bskd->bkgd", l_partial.astype(vc.dtype), vc,
                           preferred_element_type=jnp.float32)
        l_l = l_partial.sum(-1)

        # ---- cross-shard softmax-stat combine (tiny collectives) ----
        m_g = jax.lax.pmax(m_l, axis)
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, axis)
        acc_g = jax.lax.psum(acc_l * corr[..., None], axis)
        l_g = jnp.where(l_g == 0.0, 1.0, l_g)
        o = (acc_g / l_g[..., None]).reshape(b, 1, h, d).astype(q.dtype)
        return o, kc, vc

    def sp_decode(q, k_new, v_new, kc, vc, slot, kv_len):
        b = q.shape[0]
        bspec = plan.resolve(mesh, (b,), ("batch",))
        batch_ax = bspec[0] if len(bspec) else None
        q_spec = P(batch_ax, None, None, None)
        kv_new_spec = P(batch_ax, None, None, None)
        cache_spec = P(batch_ax, axis, None, None)
        vec_spec = P(batch_ax)
        f = shard_map(
            inner, mesh=mesh,
            in_specs=(q_spec, kv_new_spec, kv_new_spec, cache_spec, cache_spec,
                      vec_spec, vec_spec),
            out_specs=(q_spec, cache_spec, cache_spec),
        )
        return f(q, k_new, v_new, kc, vc, slot, kv_len)

    return sp_decode
