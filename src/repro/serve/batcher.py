"""Request batching for serving (continuous-batching style).

Requests arrive with prompts of varying length; the batcher packs up to
``max_batch`` active sequences, pads prompts for a shared prefill, then
decodes in lock-step, retiring finished sequences and admitting queued ones
into freed slots. Admission mid-decode prefills the admitted group on its
own (so survivors' caches are untouched) and splices the new rows into the
freed batch slots; decode then continues lock-step over the refreshed batch.
On the dry-run meshes this logic is exercised with the reduced configs; the
step functions are the same jit artifacts the pod runs.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [len] int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = field(default_factory=time.time)
    t_done: Optional[float] = None


def _splice_rows(cache: Any, sub: Any, rows: Sequence[int]) -> Any:
    """Write ``sub``'s batch rows into ``cache`` at batch indices ``rows``.

    Cache leaves put the batch axis in different positions (dense k/v are
    ``[layers, batch, ...]`` while ``len`` is ``[batch]``), so the axis is
    recovered per leaf as the single dimension where the full-batch and
    sub-batch shapes disagree. Callers must ensure the sub-batch is strictly
    smaller than the full batch (equal sizes mean "replace the cache").
    """
    ids = jnp.asarray(list(rows))

    def put(full, part):
        axis = next(a for a, (m, s) in enumerate(zip(full.shape, part.shape))
                    if m != s)
        index = (slice(None),) * axis + (ids,)
        return full.at[index].set(part)

    return jax.tree.map(put, cache, sub)


@dataclass
class Batcher:
    cfg: Any
    params: Any
    prefill_step: Callable
    decode_step: Callable
    init_cache: Callable  # (batch_size, max_len) -> cache
    max_batch: int = 4
    max_len: int = 256
    eos: int = -1  # synthetic: no real EOS; stop at max_new

    queue: "collections.deque[Request]" = field(default_factory=collections.deque)
    stats: Dict[str, float] = field(default_factory=dict)
    _next_rid: int = 0

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        req = Request(rid=self._next_rid, prompt=np.asarray(prompt, np.int32),
                      max_new=max_new)
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _prefill_group(self, group: List[Request]) -> Tuple[Any, np.ndarray]:
        """Left-pad + prefill ``group`` as one batch; returns (cache, first
        sampled token per row)."""
        b = len(group)
        plen = max(len(r.prompt) for r in group)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(group):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        cache = self.init_cache(b, self.max_len)
        logits, cache = self.prefill_step(
            self.params, {"tokens": jnp.asarray(toks)}, cache)
        # np.array (not asarray): slots mutate cur in place as they retire
        cur = np.array(jnp.argmax(logits[:, -1], -1), np.int32)
        return cache, cur

    @staticmethod
    def _take(queue: "collections.deque[Request]", n: int) -> List[Request]:
        return [queue.popleft() for _ in range(min(n, len(queue)))]

    def _note_token(self, r: Request, tok: int,
                    finished: List[Request]) -> bool:
        """Record one sampled token; retire the request the moment it hits
        ``max_new``/EOS, stamping ``t_done`` at actual completion. Returns
        True when the request retired."""
        r.out.append(tok)
        if len(r.out) >= r.max_new or tok == self.eos:
            r.done, r.t_done = True, time.time()
            finished.append(r)
            return True
        return False

    def run(self) -> List[Request]:
        finished: List[Request] = []
        n_decode_steps = 0
        n_prefills = 0
        t0 = time.time()
        while self.queue:
            batch = self._take(self.queue, self.max_batch)
            b = len(batch)
            slots: List[Request] = list(batch)
            cache, cur = self._prefill_group(batch)
            n_prefills += 1
            active = np.ones(b, bool)
            for i, r in enumerate(batch):
                if self._note_token(r, int(cur[i]), finished):
                    active[i] = False
            while active.any():
                free = [i for i in range(b) if not active[i]]
                if free and self.queue:
                    admit = self._take(self.queue, len(free))
                    sub_cache, sub_cur = self._prefill_group(admit)
                    n_prefills += 1
                    rows = free[: len(admit)]
                    cache = (sub_cache if len(admit) == b
                             else _splice_rows(cache, sub_cache, rows))
                    for j, (row, r) in enumerate(zip(rows, admit)):
                        slots[row] = r
                        cur[row] = sub_cur[j]
                        active[row] = not self._note_token(
                            r, int(sub_cur[j]), finished)
                    if not active.any():
                        continue
                logits, cache = self.decode_step(
                    self.params, {"tokens": jnp.asarray(cur[:, None])}, cache)
                nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
                n_decode_steps += 1
                for i in range(b):
                    if active[i]:
                        cur[i] = nxt[i]
                        if self._note_token(slots[i], int(nxt[i]), finished):
                            active[i] = False
        dt = time.time() - t0
        ntok = sum(len(r.out) for r in finished)
        self.stats = {"requests": len(finished), "tokens": ntok,
                      "wall_s": dt, "tok_per_s": ntok / dt if dt else 0.0,
                      "decode_steps": n_decode_steps,
                      "prefills": n_prefills}
        return finished
