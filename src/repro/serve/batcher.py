"""Request batching for serving (continuous-batching style).

Requests arrive with prompts of varying length; the batcher packs up to
``max_batch`` active sequences, pads prompts for a shared prefill, then
decodes in lock-step, retiring finished sequences and admitting queued ones
into freed slots. On the dry-run meshes this logic is exercised with the
reduced configs; the step functions are the same jit artifacts the pod runs.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [len] int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = field(default_factory=time.time)
    t_done: Optional[float] = None


@dataclass
class Batcher:
    cfg: Any
    params: Any
    prefill_step: Callable
    decode_step: Callable
    init_cache: Callable  # (batch_size, max_len) -> cache
    max_batch: int = 4
    max_len: int = 256
    eos: int = -1  # synthetic: no real EOS; stop at max_new

    queue: "collections.deque[Request]" = field(default_factory=collections.deque)
    stats: Dict[str, float] = field(default_factory=dict)

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> Request:
        req = Request(rid=len(self.queue), prompt=np.asarray(prompt, np.int32),
                      max_new=max_new)
        self.queue.append(req)
        return req

    def run(self) -> List[Request]:
        finished: List[Request] = []
        n_decode_steps = 0
        t0 = time.time()
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.max_batch, len(self.queue)))]
            b = len(batch)
            plen = max(len(r.prompt) for r in batch)
            toks = np.zeros((b, plen), np.int32)
            for i, r in enumerate(batch):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            cache = self.init_cache(b, self.max_len)
            logits, cache = self.prefill_step(
                self.params, {"tokens": jnp.asarray(toks)}, cache)
            cur = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
            for i, r in enumerate(batch):
                r.out.append(int(cur[i]))

            active = np.ones(b, bool)
            steps = 0
            while active.any() and steps < max(r.max_new for r in batch) - 1:
                logits, cache = self.decode_step(
                    self.params, {"tokens": jnp.asarray(cur[:, None])}, cache)
                cur = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
                steps += 1
                n_decode_steps += 1
                for i, r in enumerate(batch):
                    if active[i]:
                        r.out.append(int(cur[i]))
                        if len(r.out) >= r.max_new or int(cur[i]) == self.eos:
                            active[i] = False
                            r.done, r.t_done = True, time.time()
            for r in batch:
                r.done, r.t_done = True, r.t_done or time.time()
                finished.append(r)
        dt = time.time() - t0
        ntok = sum(len(r.out) for r in finished)
        self.stats = {"requests": len(finished), "tokens": ntok,
                      "wall_s": dt, "tok_per_s": ntok / dt if dt else 0.0,
                      "decode_steps": n_decode_steps}
        return finished
