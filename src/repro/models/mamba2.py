"""Mamba2 (SSD — state-space duality) blocks.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic term +
inter-chunk state recurrence via ``lax.scan``); decode is the O(1) recurrent
update. This jnp implementation is also the oracle for ``kernels/ssd_scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.layers import Param, keygen, ones, par, zeros


def init_mamba_layer(keys, cfg, dtype):
    d = cfg.d_model
    s = cfg.ssm
    di, N, nh = s.d_inner(d), s.d_state, s.n_heads(d)
    return {
        "ln": ones((d,), ("embed",), dtype),
        # in_proj -> [z(di), x(di), B(N), C(N), dt(nh)]
        "in_proj": par(next(keys), (d, 2 * di + 2 * N + nh), ("embed", "ssm_inner"), dtype),
        "conv_w": par(next(keys), (s.conv_width, di + 2 * N), ("conv", "ssm_inner"), dtype, scale=0.1),
        "conv_b": zeros((di + 2 * N,), ("ssm_inner",), dtype),
        "A_log": Param(jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32), ("ssm_heads",)),
        "D": ones((nh,), ("ssm_heads",), jnp.float32),
        "dt_bias": zeros((nh,), ("ssm_heads",), jnp.float32),
        "out_norm": ones((di,), ("ssm_inner",), dtype),
        "out_proj": par(next(keys), (di, d), ("ssm_inner", "embed"), dtype),
    }


def _split_proj(cfg, proj):
    d = cfg.d_model
    s = cfg.ssm
    di, N, nh = s.d_inner(d), s.d_state, s.n_heads(d)
    z, x, B, C, dt = jnp.split(proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv, width W. state: [b, W-1, ch] carry for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(xBC.dtype), xBC], axis=1)
    out = sum(pad[:, i : i + xBC.shape[1]] * w[i] for i in range(W))
    new_state = pad[:, -(W - 1) :] if xBC.shape[1] >= 1 else state
    return jax.nn.silu(out + b), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """SSD over a full sequence.

    x: [b, s, nh, dh]; dt: [b, s, nh] (post-softplus); A: [nh] (negative);
    B, C: [b, s, N]. Returns (y [b,s,nh,dh], final_state [b,nh,dh,N]).
    """
    b, s, nh, dh = x.shape
    N = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)) if False else ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    T = x.shape[1]
    nc, Lc = T // chunk, chunk
    xc = x.reshape(b, nc, Lc, nh, dh)
    dtc = dt.reshape(b, nc, Lc, nh).astype(jnp.float32)
    Bc = B.reshape(b, nc, Lc, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, Lc, N).astype(jnp.float32)

    dA = dtc * A[None, None, None, :]  # [b,nc,L,nh], negative
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative decay

    # --- intra-chunk (quadratic within the chunk) ---
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # [b,nc,L,S,nh]
    causal = np.tril(np.ones((Lc, Lc), bool))
    # mask inside the exponent: exp of masked (l<s) entries would overflow
    decay = jnp.exp(jnp.where(causal[None, None, :, :, None], diff, -np.inf))
    att = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)[..., None] * decay
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # [b,nc,L,nh,dh]
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", att, xdt)

    # --- per-chunk local final state ---
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)  # [b,nc,L,nh]
    S_loc = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, dtc * decay_end, xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # [b,nc,nh]

    # --- inter-chunk recurrence ---
    def step(S_prev, inputs):
        S_l, cd = inputs  # [b,nh,dh,N], [b,nh]
        S_new = S_prev * cd[:, :, None, None] + S_l
        return S_new, S_prev

    S0 = (
        jnp.zeros((b, nh, dh, N), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    S_final, S_prevs = jax.lax.scan(
        step, S0, (S_loc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)  # [b,nc,nh,dh,N]

    # --- inter-chunk contribution ---
    decay_in = jnp.exp(cs)  # decay from chunk start to position l
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, S_prevs, decay_in)

    y = (y_intra + y_inter).reshape(b, T, nh, dh)[:, :s]
    return y.astype(x.dtype), S_final


def mamba_block(p, x, cfg, *, cache=None, constrain=lambda a, k: a):
    """One Mamba2 block. cache: {"conv": [b,W-1,di+2N], "ssm": [b,nh,dh,N]}."""
    s_cfg = cfg.ssm
    d = cfg.d_model
    di, N, nh, dh = s_cfg.d_inner(d), s_cfg.d_state, s_cfg.n_heads(d), s_cfg.head_dim
    xin = L.rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = xin @ p["in_proj"]
    z, xi, B, C, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([xi, B, C], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xi, B, C = jnp.split(xBC, [di, di + N], axis=-1)
    xi = constrain(xi, "ssm_inner")

    A = -jnp.exp(p["A_log"])  # [nh]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [b,s,nh]
    xh = xi.reshape(*xi.shape[:2], nh, dh)

    if cache is None or x.shape[1] > 1:
        init_state = cache["ssm"] if cache is not None else None
        y, S_final = ssd_chunked(xh, dt, A, B, C, s_cfg.chunk, init_state)
    else:
        # recurrent decode: h = h * exp(dt A) + dt * x ⊗ B ; y = C · h
        h = cache["ssm"].astype(jnp.float32)  # [b,nh,dh,N]
        dt1 = dt[:, 0]  # [b,nh]
        dA = jnp.exp(dt1 * A[None, :])  # [b,nh]
        xb = (dt1[..., None] * xh[:, 0].astype(jnp.float32))[..., None] * B[:, 0, None, None, :]
        h = h * dA[..., None, None] + xb
        y = jnp.einsum("bhpn,bn->bhp", h, C[:, 0].astype(jnp.float32))[:, None]
        S_final = h

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:2], di).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": S_final}
    return constrain(x + out, "hidden"), new_cache


def init_mamba_cache(cfg, batch_size: int, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di, N, nh, dh = s.d_inner(d), s.d_state, s.n_heads(d), s.head_dim
    return {
        "conv": jnp.zeros((batch_size, s.conv_width - 1, di + 2 * N), dtype),
        "ssm": jnp.zeros((batch_size, nh, dh, N), jnp.float32),
    }
