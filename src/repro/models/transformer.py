"""Dense decoder-only transformer (llama3 / qwen3 / stablelm / llava backbone).

Layers are stacked on a leading axis and consumed with ``jax.lax.scan`` so the
lowered HLO is depth-independent (critical for 94-layer dry-run compiles).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import Param, keygen, ones, par


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def stack_layers(init_one, key, n_layers: int):
    """vmap an init over layer keys, then tag the leading axis as 'layers'."""
    ks = jax.random.split(key, n_layers)
    stacked = jax.vmap(init_one)(ks)
    return jax.tree.map(
        lambda p: Param(p.value, ("layers", *p.axes)), stacked, is_leaf=L._is_param
    )


def init_dense(cfg, key):
    dt = _dtype(cfg)
    keys = keygen(key)
    d = cfg.d_model

    def one_layer(k):
        lk = keygen(k)
        if cfg.moe is not None:
            from repro.models.moe import init_moe_mlp

            mlp = init_moe_mlp(lk, d, cfg.moe, dt)
        else:
            mlp = L.init_mlp(lk, d, cfg.d_ff, dt)
        return {
            "ln1": ones((d,), ("embed",), dt),
            "attn": L.init_attention(lk, cfg, dt),
            "ln2": ones((d,), ("embed",), dt),
            "mlp": mlp,
        }

    params = {
        "embed": par(next(keys), (cfg.vocab, d), ("vocab", "embed"), dt),
        "blocks": stack_layers(one_layer, next(keys), cfg.n_layers),
        "ln_f": ones((d,), ("embed",), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = par(next(keys), (d, cfg.vocab), ("embed", "vocab"), dt)
    if cfg.frontend:
        params["frontend_proj"] = par(next(keys), (1024, d), (None, "embed"), dt)
    return params


def _embed_inputs(cfg, params, batch, constrain):
    """Token (+ frontend stub) embedding. Returns (x [b,s,d], positions [b,s])."""
    tok = batch["tokens"]
    x = jnp.take(params["embed"], tok, axis=0)
    if cfg.frontend:
        fe = batch["frontend"].astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([fe, x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return constrain(x, "hidden"), positions


def _logits(cfg, params, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))


def _layer_body(cfg, constrain, x, lp, lcache, positions, window):
    """Returns (out, aux_loss, new_cache)."""
    a, new_cache = L.attention_block(
        lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg,
        positions=positions, causal=True, window=window,
        cache=lcache, constrain=constrain,
    )
    h = x + a
    hn = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        from repro.models.moe import moe_block

        m, aux = moe_block(lp["mlp"], hn, cfg.moe, constrain)
    else:
        m, aux = L.mlp_block(lp["mlp"], hn, constrain), jnp.float32(0.0)
    out = h + m
    return constrain(out, "hidden"), aux, new_cache


def dense_forward(
    cfg,
    params,
    batch,
    *,
    cache=None,  # {"k": [L,b,S,kh,dh], "v": ..., "len": [b]} or None
    constrain=lambda a, k: a,
    remat: str = "none",
):
    """Returns (hidden [b,s,d], new_cache)."""
    if cache is None:
        x, positions = _embed_inputs(cfg, params, batch, constrain)
    else:
        # decode: single new token at position cache["len"]
        tok = batch["tokens"]  # [b, 1]
        x = jnp.take(params["embed"], tok, axis=0)
        positions = cache["len"][:, None] + jnp.zeros_like(tok)
        x = constrain(x, "hidden")

    def body(carry, xs):
        x, aux = carry
        lp, lc = xs
        out, a, nc = _layer_body(cfg, constrain, x, lp, lc, positions, cfg.swa_window)
        return (out, aux + a), nc

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )

    aux0 = jnp.float32(0.0)
    if cache is None:
        (x, aux), _ = jax.lax.scan(
            lambda c, lp: body(c, (lp, None)), (x, aux0), params["blocks"]
        )
        new_cache = None
    else:
        lcaches = {"k": cache["k"], "v": cache["v"],
                   "len": jnp.broadcast_to(cache["len"], (cfg.n_layers, *cache["len"].shape))}
        (x, aux), new_lc = jax.lax.scan(body, (x, aux0), (params["blocks"], lcaches))
        new_cache = {"k": new_lc["k"], "v": new_lc["v"], "len": cache["len"] + 1}
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return x, aux / cfg.n_layers, new_cache


AUX_LOSS_COEF = 0.01


def ce_loss(cfg, params, x, tgt, constrain, loss_chunk: int = 0):
    """Cross-entropy on hidden states. ``loss_chunk`` > 0 scans the sequence in
    chunks so the [B, S, vocab] logits tensor is never materialised (a DSE
    memory-term knob)."""

    def one(xc, tc):
        logits = constrain(_logits(cfg, params, xc), "logits")
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        mask = (tc >= 0).astype(jnp.float32)
        return (nll * mask).sum(), mask.sum()

    b, s, _ = x.shape
    if loss_chunk and s > loss_chunk and s % loss_chunk == 0:
        n = s // loss_chunk
        xs = x.reshape(b, n, loss_chunk, -1).transpose(1, 0, 2, 3)
        ts = tgt.reshape(b, n, loss_chunk).transpose(1, 0, 2)

        def body(carry, xt):
            tot, cnt = carry
            nll, m = one(*xt)
            return (tot + nll, cnt + m), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), (xs, ts))
    else:
        tot, cnt = one(x, tgt)
    return tot / jnp.maximum(cnt, 1.0), cnt


def dense_loss(cfg, params, batch, constrain=lambda a, k: a, remat: str = "none",
               loss_chunk: int = 0):
    x, aux, _ = dense_forward(cfg, params, batch, constrain=constrain, remat=remat)
    if cfg.frontend:
        x = x[:, -batch["tokens"].shape[1]:]  # loss only on text positions
    ce, tokens = ce_loss(cfg, params, x, batch["targets"], constrain, loss_chunk)
    loss = ce + (AUX_LOSS_COEF * aux if cfg.moe is not None else 0.0)
    return loss, {"loss": ce, "aux": aux, "tokens": tokens}


def init_dense_cache(cfg, batch_size: int, max_len: int, dtype):
    kh, dh = cfg.n_kv_heads, cfg.head_dim()
    S = min(max_len, cfg.swa_window) if cfg.swa_window else max_len
    return {
        "k": jnp.zeros((cfg.n_layers, batch_size, S, kh, dh), dtype),
        "v": jnp.zeros((cfg.n_layers, batch_size, S, kh, dh), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def dense_prefill(cfg, params, batch, cache, constrain=lambda a, k: a):
    """Populate the cache from a prompt; returns (last-token logits, cache)."""
    x, positions = _embed_inputs(cfg, params, batch, constrain)
    s = x.shape[1]

    def body(carry, xs):
        x, aux = carry
        lp, lc = xs
        out, a, nc = _layer_body(cfg, constrain, x, lp, lc, positions, cfg.swa_window)
        return (out, aux + a), nc

    lcaches = {"k": cache["k"], "v": cache["v"],
               "len": jnp.broadcast_to(cache["len"], (cfg.n_layers, *cache["len"].shape))}
    (x, _), new_lc = jax.lax.scan(body, (x, jnp.float32(0.0)), (params["blocks"], lcaches))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = _logits(cfg, params, x[:, -1:])
    return logits, {"k": new_lc["k"], "v": new_lc["v"], "len": cache["len"] + s}


def dense_decode(cfg, params, batch, cache, constrain=lambda a, k: a):
    x, _, new_cache = dense_forward(cfg, params, batch, cache=cache, constrain=constrain)
    return _logits(cfg, params, x), new_cache
