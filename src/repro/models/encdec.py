"""Encoder-decoder transformer (seamless-m4t backbone).

The audio frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings [b, F, 1024]; the encoder consumes them through a
learned projection. The decoder is a standard causal transformer with
cross-attention; decode-time caches hold self-KV and precomputed cross-KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import keygen, ones, par
from repro.models.transformer import stack_layers, _logits


def init_encdec(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    keys = keygen(key)
    d = cfg.d_model

    def enc_layer(k):
        lk = keygen(k)
        return {
            "ln1": ones((d,), ("embed",), dt),
            "attn": L.init_attention(lk, cfg, dt),
            "ln2": ones((d,), ("embed",), dt),
            "mlp": L.init_mlp(lk, d, cfg.d_ff, dt),
        }

    def dec_layer(k):
        lk = keygen(k)
        return {
            "ln1": ones((d,), ("embed",), dt),
            "attn": L.init_attention(lk, cfg, dt),
            "ln_x": ones((d,), ("embed",), dt),
            "xattn": L.init_attention(lk, cfg, dt),
            "ln2": ones((d,), ("embed",), dt),
            "mlp": L.init_mlp(lk, d, cfg.d_ff, dt),
        }

    return {
        "frontend_proj": par(next(keys), (1024, d), (None, "embed"), dt),
        "embed": par(next(keys), (cfg.vocab, d), ("vocab", "embed"), dt),
        "enc_blocks": stack_layers(enc_layer, next(keys), cfg.n_enc_layers),
        "dec_blocks": stack_layers(dec_layer, next(keys), cfg.n_layers),
        "ln_enc": ones((d,), ("embed",), dt),
        "ln_f": ones((d,), ("embed",), dt),
        "lm_head": par(next(keys), (d, cfg.vocab), ("embed", "vocab"), dt),
    }


def _cross_kv(p, enc_out, cfg):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


def _cross_attend(p, x, ck, cv, cfg, constrain):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q = constrain(q, "heads")
    if s == 1:
        o = L.decode_attention(q, ck, cv, jnp.full((b,), ck.shape[1]))
    else:
        o = L.chunked_attention(q, ck, cv, causal=False)
    return jnp.einsum("bshk,hkd->bsd", constrain(o, "heads"), p["wo"])


def encode(cfg, params, frames, constrain=lambda a, k: a, remat="none"):
    x = frames.astype(jnp.dtype(cfg.dtype)) @ params["frontend_proj"]
    x = constrain(x, "hidden")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        a, _ = L.attention_block(
            lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=False, constrain=constrain,
        )
        h = x + a
        out = h + L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps), constrain)
        return constrain(out, "hidden"), None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def decode_stack(cfg, params, tokens, enc_out, *, cache=None, constrain=lambda a, k: a, remat="none"):
    """cache: {"k","v" self-KV [L,b,S,kh,dh], "ck","cv" cross-KV [L,b,F,kh,dh], "len": [b]}."""
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "hidden")
    b, s, _ = x.shape
    if cache is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        positions = cache["len"][:, None] + jnp.zeros((b, s), jnp.int32)

    def body(x, xs):
        lp, lc = xs
        a, nc = L.attention_block(
            lp["attn"], L.rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg,
            positions=positions, causal=True,
            cache=None if lc is None else {"k": lc["k"], "v": lc["v"], "len": lc["len"]},
            constrain=constrain,
        )
        h = x + a
        if lc is None:
            ck, cv = _cross_kv(lp["xattn"], enc_out, cfg)
        else:
            ck, cv = lc["ck"], lc["cv"]
        h = h + _cross_attend(lp["xattn"], L.rmsnorm(h, lp["ln_x"], cfg.norm_eps), ck, cv, cfg, constrain)
        out = h + L.mlp_block(lp["mlp"], L.rmsnorm(h, lp["ln2"], cfg.norm_eps), constrain)
        new_lc = None if lc is None else {"k": nc["k"], "v": nc["v"]}
        return constrain(out, "hidden"), new_lc

    if remat == "full":
        body = jax.checkpoint(body)

    if cache is None:
        x, _ = jax.lax.scan(lambda c, lp: body(c, (lp, None)), x, params["dec_blocks"])
        new_cache = None
    else:
        lcaches = {
            "k": cache["k"], "v": cache["v"], "ck": cache["ck"], "cv": cache["cv"],
            "len": jnp.broadcast_to(cache["len"], (cfg.n_layers, b)),
        }
        x, new_kv = jax.lax.scan(body, x, (params["dec_blocks"], lcaches))
        new_cache = {
            "k": new_kv["k"], "v": new_kv["v"], "ck": cache["ck"], "cv": cache["cv"],
            "len": cache["len"] + s,
        }
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), new_cache


def encdec_loss(cfg, params, batch, constrain=lambda a, k: a, remat="none",
                loss_chunk: int = 0):
    from repro.models.transformer import ce_loss

    enc_out = encode(cfg, params, batch["frontend"], constrain, remat)
    x, _ = decode_stack(cfg, params, batch["tokens"], enc_out, constrain=constrain, remat=remat)
    loss, tokens = ce_loss(cfg, params, x, batch["targets"], constrain, loss_chunk)
    return loss, {"loss": loss, "tokens": tokens}


def init_encdec_cache(cfg, batch_size: int, max_len: int, dtype):
    kh, dh = cfg.n_kv_heads, cfg.head_dim()
    F = cfg.frontend_len
    return {
        "k": jnp.zeros((cfg.n_layers, batch_size, max_len, kh, dh), dtype),
        "v": jnp.zeros((cfg.n_layers, batch_size, max_len, kh, dh), dtype),
        "ck": jnp.zeros((cfg.n_layers, batch_size, F, kh, dh), dtype),
        "cv": jnp.zeros((cfg.n_layers, batch_size, F, kh, dh), dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def encdec_prefill(cfg, params, batch, cache, constrain=lambda a, k: a):
    """Encode the source and prefill the decoder with the target prompt."""
    enc_out = encode(cfg, params, batch["frontend"], constrain)
    ck = jax.vmap(lambda lp: _cross_kv(lp["xattn"], enc_out, cfg)[0])(params["dec_blocks"])
    cv = jax.vmap(lambda lp: _cross_kv(lp["xattn"], enc_out, cfg)[1])(params["dec_blocks"])
    cache = {**cache, "ck": ck, "cv": cv}
    x, new_cache = decode_stack(cfg, params, batch["tokens"], enc_out, cache=cache, constrain=constrain)
    return _logits(cfg, params, x[:, -1:]), new_cache


def encdec_decode(cfg, params, batch, cache, constrain=lambda a, k: a):
    x, new_cache = decode_stack(cfg, params, batch["tokens"], None, cache=cache, constrain=constrain)
    return _logits(cfg, params, x), new_cache
