"""Shared neural-net layers (pure functional JAX).

Conventions
-----------
* Params are built as :class:`Param` leaves carrying ``(value, logical_axes)``;
  ``split_params`` separates them into a value tree + spec tree. Logical axes
  are resolved to mesh ``PartitionSpec`` s by ``repro.sharding.plan``.
* Layer-stacked params carry a leading ``"layers"`` axis and are consumed by
  ``jax.lax.scan`` so the HLO is depth-independent.
* Attention is *chunked flash-style* (two-level scan, online softmax, f32
  accumulators) so 32k-token prefill never materialises an S x S matrix.
  This jnp implementation is also the oracle for ``kernels/flash_attention``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Param plumbing
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Param:
    value: jax.Array
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), tuple(p.axes)),
    lambda aux, ch: Param(ch[0], aux),
)


def _is_param(x) -> bool:
    return isinstance(x, Param)


def split_params(tree):
    """(tree of Param) -> (tree of arrays, tree of logical-axis tuples)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_param)
    specs = jax.tree.map(lambda p: tuple(p.axes), tree, is_leaf=_is_param)
    return values, specs


def par(key, shape, axes, dtype, scale: float = 0.02) -> Param:
    assert len(shape) == len(axes), (shape, axes)
    v = scale * jax.random.normal(key, shape, dtype=jnp.float32)
    return Param(v.astype(dtype), tuple(axes))


def ones(shape, axes, dtype) -> Param:
    return Param(jnp.ones(shape, dtype=dtype), tuple(axes))


def zeros(shape, axes, dtype) -> Param:
    return Param(jnp.zeros(shape, dtype=dtype), tuple(axes))


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv).astype(dt) * w


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., s, h, d]; positions: [..., s] (absolute token positions)."""
    d = x.shape[-1]
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # [..., s, d/2]
    ang = ang[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked flash-style attention (GQA-aware, causal / sliding-window)
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _block_mask(qpos, kpos, causal: bool, window: Optional[int]):
    """[qc, kc] additive mask in f32."""
    m = jnp.zeros((qpos.shape[0], kpos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(qpos[:, None] >= kpos[None, :], m, NEG_INF)
    if window is not None:
        m = jnp.where(qpos[:, None] - kpos[None, :] < window, m, NEG_INF)
    return m


def chunked_attention(
    q: jax.Array,  # [b, sq, h, d]
    k: jax.Array,  # [b, sk, kh, d]
    v: jax.Array,  # [b, sk, kh, d]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,  # absolute position of q[0] (decode: cache length)
    q_chunk: int = 512,
    k_chunk: int = 512,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    g = h // kh
    q_chunk = min(q_chunk, sq)
    k_chunk = min(k_chunk, sk)
    # pad to multiples
    def pad_to(x, c, axis):
        s = x.shape[axis]
        r = (-s) % c
        if r == 0:
            return x, s
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, r)
        return jnp.pad(x, pad), s

    q_, _ = pad_to(q, q_chunk, 1)
    k_, _ = pad_to(k, k_chunk, 1)
    v_, _ = pad_to(v, k_chunk, 1)
    nq, nk = q_.shape[1] // q_chunk, k_.shape[1] // k_chunk
    scale = 1.0 / np.sqrt(d)

    # [b, kh, g, s, d] grouped layout (no kv repeat materialised)
    qg = q_.reshape(b, nq, q_chunk, kh, g, d).transpose(1, 0, 3, 4, 2, 5)  # [nq,b,kh,g,qc,d]
    kg = k_.reshape(b, nk, k_chunk, kh, d).transpose(1, 0, 3, 2, 4)  # [nk,b,kh,kc,d]
    vg = v_.reshape(b, nk, k_chunk, kh, d).transpose(1, 0, 3, 2, 4)

    kpos_all = jnp.arange(nk * k_chunk).reshape(nk, k_chunk)
    kvalid = kpos_all < sk  # padded keys are invalid

    def q_step(_, qi):
        qc, qidx = qi
        qpos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint  # flash bwd: recompute P per kv block, never stack it
        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, kpos, kval = ki
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qc, kc, preferred_element_type=jnp.float32)
            s = s * scale
            mask = _block_mask(qpos, kpos, causal, window)
            mask = jnp.where(kval[None, :], mask, NEG_INF)
            s = s + mask  # [b,kh,g,qc,kc] + [qc,kc]
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgqc,bkcd->bkgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kg, vg, kpos_all, kvalid))
        l = jnp.where(l == 0.0, 1.0, l)
        o = (acc / l[..., None]).astype(q.dtype)  # [b,kh,g,qc,d]
        return None, o

    # flash-style memory: recompute the kv scan in backward instead of saving
    # per-block probabilities (otherwise AD stores the full S x S matrix)
    q_step = jax.checkpoint(q_step)
    _, out = jax.lax.scan(q_step, None, (qg, jnp.arange(nq)))
    # out: [nq, b, kh, g, qc, d] -> [b, sq, h, d]
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq]


def chunked_attention_tri(
    q: jax.Array,  # [b, s, h, d]
    k: jax.Array,  # [b, s, kh, d]
    v: jax.Array,
    *,
    window: Optional[int] = None,
    chunk: int = 512,
) -> jax.Array:
    """Causal self-attention over a *static lower-triangular pair list*.

    The plain two-level scan computes all nq x nk blocks and masks half of
    them; here the scan runs over exactly the (qi, ki<=qi) block pairs (a
    static Python list), so fully-masked blocks are never computed:
    ~0.5x attention FLOPs for causal, O(s*w) for sliding-window (band pairs).
    Rows are qi-major; each step updates the row's online-softmax state and
    (re)writes the row output — the final write per row wins.
    """
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = q.shape[1] // chunk
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, n, chunk, kh, g, d).transpose(1, 0, 3, 4, 2, 5)  # [n,b,kh,g,C,d]
    kg = k.reshape(b, n, chunk, kh, d).transpose(1, 0, 3, 2, 4)  # [n,b,kh,C,d]
    vg = v.reshape(b, n, chunk, kh, d).transpose(1, 0, 3, 2, 4)

    w_chunks = None if window is None else (window + chunk - 1) // chunk
    pairs = [(qi, ki) for qi in range(n) for ki in range(n)
             if ki <= qi and (w_chunks is None or qi - ki <= w_chunks)]
    qi_a = jnp.asarray([p[0] for p in pairs], jnp.int32)
    ki_a = jnp.asarray([p[1] for p in pairs], jnp.int32)
    first_a = jnp.asarray([i == 0 or pairs[i][0] != pairs[i - 1][0]
                           for i in range(len(pairs))])

    m0 = jnp.full((b, kh, g, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, chunk), jnp.float32)
    a0 = jnp.zeros((b, kh, g, chunk, d), jnp.float32)
    out0 = jnp.zeros((n, b, kh, g, chunk, d), q.dtype)

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc, out = carry
        qi, ki, first = xs
        m = jnp.where(first, m0, m)
        l = jnp.where(first, l0, l)
        acc = jnp.where(first, a0, acc)
        qc = jax.lax.dynamic_index_in_dim(qg, qi, 0, keepdims=False)
        kc = jax.lax.dynamic_index_in_dim(kg, ki, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vg, ki, 0, keepdims=False)
        sco = jnp.einsum("bkgqd,bkcd->bkgqc", qc, kc,
                         preferred_element_type=jnp.float32) * scale
        qpos = qi * chunk + jnp.arange(chunk)
        kpos = ki * chunk + jnp.arange(chunk)
        mask = jnp.where(qpos[:, None] >= kpos[None, :], 0.0, NEG_INF)
        if window is not None:
            mask = jnp.where(qpos[:, None] - kpos[None, :] < window, mask, NEG_INF)
        mask = jnp.where(kpos[None, :] < s, mask, NEG_INF)  # padded keys
        sco = sco + mask
        m_new = jnp.maximum(m, sco.max(-1))
        p = jnp.exp(sco - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        l_safe = jnp.where(l_new == 0.0, 1.0, l_new)
        o_row = (acc_new / l_safe[..., None]).astype(q.dtype)
        out = jax.lax.dynamic_update_index_in_dim(out, o_row, qi, 0)
        return (m_new, l_new, acc_new, out), None

    (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, a0, out0),
                                     (qi_a, ki_a, first_a))
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, n * chunk, h, d)
    return out[:, :s]


def decode_attention(
    q: jax.Array,  # [b, 1, h, d]
    k: jax.Array,  # [b, S, kh, d]  (cache, possibly partially filled)
    v: jax.Array,
    kv_len: jax.Array,  # [b] number of valid cache entries
) -> jax.Array:
    """Single-token attention over a cache. f32 softmax, no S x S anything."""
    b, _, h, d = q.shape
    _, S, kh, _ = k.shape
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32)
    s = s / np.sqrt(d)
    valid = jnp.arange(S)[None, :] < kv_len[:, None]  # [b,S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block params + apply
# ---------------------------------------------------------------------------
def init_attention(keys, cfg, dtype, lora_rank: int = 0):
    """Params for one attention block (optionally with LoRA adapter slots)."""
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim()
    p = {
        "wq": par(next(keys), (d, h, dh), ("embed", "heads", "head_dim"), dtype),
        "wk": par(next(keys), (d, kh, dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": par(next(keys), (d, kh, dh), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": par(next(keys), (h, dh, d), ("heads", "head_dim", "embed"), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones((dh,), ("head_dim",), dtype)
        p["k_norm"] = ones((dh,), ("head_dim",), dtype)
    if lora_rank:
        r = lora_rank
        for nm, (fi, fo, ax) in {
            "wq": (d, h * dh, ("heads",)),
            "wk": (d, kh * dh, ("kv_heads",)),
            "wv": (d, kh * dh, ("kv_heads",)),
            "wo": (h * dh, d, ("embed",)),
        }.items():
            p[f"{nm}_lora_a"] = par(next(keys), (fi, r), (ax[0] if nm == "wo" else "embed", "lora_rank"), dtype)
            p[f"{nm}_lora_b"] = zeros((r, fo), ("lora_rank", ax[0] if nm != "wo" else "embed"), dtype)
    return p


def _proj_lora(x, w3, la, lb):
    """y = x @ w3 (+ LoRA delta); w3: [d, heads, head_dim] input projection."""
    y = jnp.einsum("bsd,dhk->bshk", x, w3)
    if la is not None:
        delta = (x @ la) @ lb
        y = y + delta.reshape(y.shape)
    return y


def attention_block(
    p,
    x: jax.Array,  # [b, s, d]
    cfg,
    *,
    positions: jax.Array,  # [b, s] absolute positions (or [s])
    causal: bool = True,
    window: Optional[int] = None,
    cache=None,  # dict(k, v, len) for decode; None for full attention
    constrain=lambda a, kind: a,
    use_lora: bool = False,
):
    """Returns (out [b,s,d], new_cache)."""
    b, s, d = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim()

    def la(nm):
        return (p.get(f"{nm}_lora_a"), p.get(f"{nm}_lora_b")) if use_lora else (None, None)

    q = _proj_lora(x, p["wq"], *la("wq"))
    k = _proj_lora(x, p["wk"], *la("wk"))
    v = _proj_lora(x, p["wv"], *la("wv"))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (b, s))
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "heads")
    k = constrain(k, "kv")
    v = constrain(v, "kv")

    new_cache = None
    if cache is None:
        if causal and getattr(constrain, "attn_impl", "chunked") == "tri":
            o = chunked_attention_tri(q, k, v, window=window)
        else:
            o = chunked_attention(q, k, v, causal=causal, window=window)
    else:
        kc, vc, ln = cache["k"], cache["v"], cache["len"]
        if s == 1:
            # single-token decode: insert then attend (SWA uses a ring buffer)
            S = kc.shape[1]
            if window is not None and S <= window:
                slot = ln % S
            else:
                slot = jnp.minimum(ln, S - 1)
            kv_len = jnp.minimum(ln + 1, S)
            sp = getattr(constrain, "sp_decode", None)
            if sp is not None:
                o, kc, vc = sp(q, k, v, kc, vc, slot, kv_len)
            else:
                idx = slot[:, None]
                bidx = jnp.arange(b)[:, None]
                kc = kc.at[bidx, idx].set(k)
                vc = vc.at[bidx, idx].set(v)
                o = decode_attention(q, kc, vc, kv_len)
            new_cache = {"k": kc, "v": vc, "len": ln + 1}
        else:
            # prefill: write cache (ring-rotated when SWA window < prompt) and
            # run chunked attention over the full prompt
            S = kc.shape[1]
            if s > S:  # SWA: keep only the last S keys, at t % S slots
                idx = np.arange(s - S, s) % S
                kc = kc.at[:, idx].set(k[:, -S:])
                vc = vc.at[:, idx].set(v[:, -S:])
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
            o = chunked_attention(q, k, v, causal=causal, window=window)
            new_cache = {"k": kc, "v": vc, "len": cache["len"] + s}
    o = constrain(o, "heads")
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    lao, lbo = la("wo")
    if lao is not None:
        out = out + (o.reshape(b, s, -1) @ lao) @ lbo
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(keys, d: int, ff: int, dtype, lora_rank: int = 0):
    p = {
        "wi": par(next(keys), (d, ff), ("embed", "ffn"), dtype),
        "wg": par(next(keys), (d, ff), ("embed", "ffn"), dtype),
        "wo": par(next(keys), (ff, d), ("ffn", "embed"), dtype),
    }
    if lora_rank:
        r = lora_rank
        p["wi_lora_a"] = par(next(keys), (d, r), ("embed", "lora_rank"), dtype)
        p["wi_lora_b"] = zeros((r, ff), ("lora_rank", "ffn"), dtype)
        p["wo_lora_a"] = par(next(keys), (ff, r), ("ffn", "lora_rank"), dtype)
        p["wo_lora_b"] = zeros((r, d), ("lora_rank", "embed"), dtype)
    return p


def mlp_block(p, x, constrain=lambda a, k: a, use_lora: bool = False):
    hpre = x @ p["wi"]
    if use_lora and "wi_lora_a" in p:
        hpre = hpre + (x @ p["wi_lora_a"]) @ p["wi_lora_b"]
    hid = jax.nn.silu(x @ p["wg"]) * hpre
    hid = constrain(hid, "ffn")
    out = hid @ p["wo"]
    if use_lora and "wo_lora_a" in p:
        out = out + (hid @ p["wo_lora_a"]) @ p["wo_lora_b"]
    return out
