"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention+MLP block
applied every k layers, specialised per invocation with LoRA deltas
(arXiv:2411.15242 — the same LoRA mechanism the paper uses for its LLM Stack).

Layer loop structure: outer ``lax.scan`` over n_uses groups; inner scan over
the ``every`` Mamba layers of the group; then the shared block with that
group's LoRA adapters.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.layers import Param, keygen, ones, par, zeros
from repro.models.transformer import stack_layers, _logits


def _init_lora(keys, cfg, dtype):
    """Per-invocation LoRA adapters for the shared attention + MLP block."""
    d, h, kh, dh, ff = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim(), cfg.d_ff
    r = cfg.hybrid_lora_rank
    p = {}
    for nm, (fi, fo) in {
        "wq": (d, h * dh), "wk": (d, kh * dh), "wv": (d, kh * dh), "wo": (h * dh, d),
    }.items():
        p[f"{nm}_lora_a"] = par(next(keys), (fi, r), (None, "lora_rank"), dtype)
        p[f"{nm}_lora_b"] = zeros((r, fo), ("lora_rank", None), dtype)
    mlp = {
        "wi_lora_a": par(next(keys), (d, r), ("embed", "lora_rank"), dtype),
        "wi_lora_b": zeros((r, ff), ("lora_rank", "ffn"), dtype),
        "wo_lora_a": par(next(keys), (ff, r), ("ffn", "lora_rank"), dtype),
        "wo_lora_b": zeros((r, d), ("lora_rank", "embed"), dtype),
    }
    return {"attn": p, "mlp": mlp}


def init_hybrid(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    keys = keygen(key)
    d = cfg.d_model
    every = cfg.hybrid_attn_every
    n_uses = cfg.n_layers // every
    shared_keys = keygen(next(keys))
    params = {
        "embed": par(next(keys), (cfg.vocab, d), ("vocab", "embed"), dt),
        "blocks": stack_layers(
            lambda k: M.init_mamba_layer(keygen(k), cfg, dt), next(keys), cfg.n_layers
        ),
        "shared": {
            "in_proj": par(next(shared_keys), (2 * d, d), (None, "embed"), dt),
            "ln1": ones((d,), ("embed",), dt),
            "attn": L.init_attention(shared_keys, cfg, dt),
            "ln2": ones((d,), ("embed",), dt),
            "mlp": L.init_mlp(shared_keys, d, cfg.d_ff, dt),
        },
        "lora": stack_layers(lambda k: _init_lora(keygen(k), cfg, dt), next(keys), n_uses),
        "ln_f": ones((d,), ("embed",), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = par(next(keys), (d, cfg.vocab), ("embed", "vocab"), dt)
    return params


def _group_tree(tree, n_groups: int):
    return jax.tree.map(lambda a: a.reshape(n_groups, a.shape[0] // n_groups, *a.shape[1:]), tree)


def hybrid_forward(cfg, params, batch, *, cache=None, constrain=lambda a, k: a, remat="none"):
    """cache: {"mamba": stacked [L,...], "attn": {"k","v" [n_uses,...]}, "len": [b]}"""
    every = cfg.hybrid_attn_every
    n_uses = cfg.n_layers // every
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = constrain(x, "hidden")
    x0 = x  # original embedding, concatenated into every shared-block input
    b, s, d = x.shape
    if cache is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        positions = cache["len"][:, None] + jnp.zeros((b, s), jnp.int32)

    mamba_groups = _group_tree(params["blocks"], n_uses)

    def inner(x, xs):
        lp, lc = xs
        return M.mamba_block(lp, x, cfg, cache=lc, constrain=constrain)

    def group_body(carry, xs):
        x, = carry
        gp, lora, mcache, acache = xs
        if mcache is None:
            x, _ = jax.lax.scan(lambda c, lp: inner(c, (lp, None)), x, gp)
            new_mc = None
        else:
            x, new_mc = jax.lax.scan(inner, x, (gp, mcache))
        # shared attention + MLP block with this group's LoRA
        sp = params["shared"]
        inp = jnp.concatenate([x, x0], axis=-1) @ sp["in_proj"]
        h = L.rmsnorm(inp, sp["ln1"], cfg.norm_eps)
        a, new_ac = L.attention_block(
            {**sp["attn"], **lora["attn"]}, h, cfg,
            positions=positions, causal=True, cache=acache,
            constrain=constrain, use_lora=True,
        )
        h2 = x + a
        m = L.mlp_block({**sp["mlp"], **lora["mlp"]}, L.rmsnorm(h2, sp["ln2"], cfg.norm_eps),
                        constrain, use_lora=True)
        return (constrain(h2 + m, "hidden"),), (new_mc, new_ac)

    if remat == "full":
        group_body = jax.checkpoint(group_body)

    if cache is None:
        (x,), _ = jax.lax.scan(
            lambda c, xs: group_body(c, (xs[0], xs[1], None, None)),
            (x,), (mamba_groups, params["lora"]),
        )
        new_cache = None
    else:
        mcaches = _group_tree(cache["mamba"], n_uses)
        acaches = {
            "k": cache["attn"]["k"], "v": cache["attn"]["v"],
            "len": jnp.broadcast_to(cache["len"], (n_uses, b)),
        }
        (x,), (new_mc, new_ac) = jax.lax.scan(
            group_body, (x,), (mamba_groups, params["lora"], mcaches, acaches)
        )
        new_cache = {
            "mamba": jax.tree.map(lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_mc),
            "attn": {"k": new_ac["k"], "v": new_ac["v"]},
            "len": cache["len"] + s,
        }
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), new_cache


def hybrid_loss(cfg, params, batch, constrain=lambda a, k: a, remat="none",
             loss_chunk: int = 0):
    from repro.models.transformer import ce_loss

    x, _ = hybrid_forward(cfg, params, batch, constrain=constrain, remat=remat)
    loss, tokens = ce_loss(cfg, params, x, batch["targets"], constrain, loss_chunk)
    return loss, {"loss": loss, "tokens": tokens}


def init_hybrid_cache(cfg, batch_size: int, max_len: int, dtype):
    from repro.models.ssm_lm import init_ssm_cache

    n_uses = cfg.n_layers // cfg.hybrid_attn_every
    kh, dh = cfg.n_kv_heads, cfg.head_dim()
    return {
        "mamba": init_ssm_cache(cfg, batch_size, dtype),
        "attn": {
            "k": jnp.zeros((n_uses, batch_size, max_len, kh, dh), dtype),
            "v": jnp.zeros((n_uses, batch_size, max_len, kh, dh), dtype),
        },
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def hybrid_prefill(cfg, params, batch, cache, constrain=lambda a, k: a):
    x, new_cache = hybrid_forward(cfg, params, batch, cache=cache, constrain=constrain)
    return _logits(cfg, params, x[:, -1:]), new_cache


def hybrid_decode(cfg, params, batch, cache, constrain=lambda a, k: a):
    x, new_cache = hybrid_forward(cfg, params, batch, cache=cache, constrain=constrain)
    return _logits(cfg, params, x), new_cache
