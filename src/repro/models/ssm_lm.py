"""Pure-SSM language model (mamba2-780m)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models.layers import keygen, ones, par
from repro.models.transformer import stack_layers, _logits


def init_ssm_lm(cfg, key):
    dt = jnp.dtype(cfg.dtype)
    keys = keygen(key)
    params = {
        "embed": par(next(keys), (cfg.vocab, cfg.d_model), ("vocab", "embed"), dt),
        "blocks": stack_layers(lambda k: M.init_mamba_layer(keygen(k), cfg, dt), next(keys), cfg.n_layers),
        "ln_f": ones((cfg.d_model,), ("embed",), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = par(next(keys), (cfg.d_model, cfg.vocab), ("embed", "vocab"), dt)
    return params


def ssm_forward(cfg, params, batch, *, cache=None, constrain=lambda a, k: a, remat="none"):
    x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = constrain(x, "hidden")

    def body(x, xs):
        lp, lc = xs
        return M.mamba_block(lp, x, cfg, cache=lc, constrain=constrain)

    if remat == "full":
        body = jax.checkpoint(body)

    if cache is None:
        x, _ = jax.lax.scan(lambda c, lp: body(c, (lp, None)), x, params["blocks"])
        new_cache = None
    else:
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
    return L.rmsnorm(x, params["ln_f"], cfg.norm_eps), new_cache


def ssm_loss(cfg, params, batch, constrain=lambda a, k: a, remat="none",
             loss_chunk: int = 0):
    from repro.models.transformer import ce_loss

    x, _ = ssm_forward(cfg, params, batch, constrain=constrain, remat=remat)
    loss, tokens = ce_loss(cfg, params, x, batch["targets"], constrain, loss_chunk)
    return loss, {"loss": loss, "tokens": tokens}


def init_ssm_cache(cfg, batch_size: int, dtype):
    one = M.init_mamba_cache(cfg, batch_size, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers, *a.shape)), one
    )


def ssm_prefill(cfg, params, batch, cache, constrain=lambda a, k: a):
    x, new_cache = ssm_forward(cfg, params, batch, cache=cache, constrain=constrain)
    return _logits(cfg, params, x[:, -1:]), new_cache


def ssm_decode(cfg, params, batch, cache, constrain=lambda a, k: a):
    x, new_cache = ssm_forward(cfg, params, batch, cache=cache, constrain=constrain)
    return _logits(cfg, params, x), new_cache
