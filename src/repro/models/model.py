"""Unified model API: family dispatch + input specs for every shape cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, never allocated) — the dry-run lowers against these.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models.layers import split_params

FRONTEND_DIM = 1024  # stub patch/frame embedding width


# ---------------------------------------------------------------------------
# init / loss / prefill / decode dispatch
# ---------------------------------------------------------------------------
def init_params(cfg: ArchConfig, key) -> Any:
    """Returns a tree of Param(value, logical_axes)."""
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.init_dense(cfg, key)
    if fam == "ssm":
        return ssm_lm.init_ssm_lm(cfg, key)
    if fam == "hybrid":
        return hybrid.init_hybrid(cfg, key)
    if fam == "audio":
        return encdec.init_encdec(cfg, key)
    raise ValueError(fam)


def abstract_params(cfg: ArchConfig):
    """(value ShapeDtypeStructs, logical specs) without allocating anything."""
    tree = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    return split_params(tree)


def materialize_params(cfg: ArchConfig, key):
    values, specs = split_params(init_params(cfg, key))
    return values, specs


def loss_fn(cfg: ArchConfig, params, batch, constrain=lambda a, k: a, remat="none",
            loss_chunk: int = 0):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.dense_loss(cfg, params, batch, constrain, remat, loss_chunk)
    if fam == "ssm":
        return ssm_lm.ssm_loss(cfg, params, batch, constrain, remat, loss_chunk)
    if fam == "hybrid":
        return hybrid.hybrid_loss(cfg, params, batch, constrain, remat, loss_chunk)
    if fam == "audio":
        return encdec.encdec_loss(cfg, params, batch, constrain, remat, loss_chunk)
    raise ValueError(fam)


def prefill_fn(cfg: ArchConfig, params, batch, cache, constrain=lambda a, k: a):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.dense_prefill(cfg, params, batch, cache, constrain)
    if fam == "ssm":
        return ssm_lm.ssm_prefill(cfg, params, batch, cache, constrain)
    if fam == "hybrid":
        return hybrid.hybrid_prefill(cfg, params, batch, cache, constrain)
    if fam == "audio":
        return encdec.encdec_prefill(cfg, params, batch, cache, constrain)
    raise ValueError(fam)


def decode_fn(cfg: ArchConfig, params, batch, cache, constrain=lambda a, k: a):
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.dense_decode(cfg, params, batch, cache, constrain)
    if fam == "ssm":
        return ssm_lm.ssm_decode(cfg, params, batch, cache, constrain)
    if fam == "hybrid":
        return hybrid.hybrid_decode(cfg, params, batch, cache, constrain)
    if fam == "audio":
        return encdec.encdec_decode(cfg, params, batch, cache, constrain)
    raise ValueError(fam)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int):
    dt = jnp.dtype(cfg.dtype)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return transformer.init_dense_cache(cfg, batch_size, max_len, dt)
    if fam == "ssm":
        return ssm_lm.init_ssm_cache(cfg, batch_size, dt)
    if fam == "hybrid":
        return hybrid.init_hybrid_cache(cfg, batch_size, max_len, dt)
    if fam == "audio":
        return encdec.init_encdec_cache(cfg, batch_size, max_len, dt)
    raise ValueError(fam)


def abstract_cache(cfg: ArchConfig, batch_size: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch_size, max_len))


# ---------------------------------------------------------------------------
# input specs per shape cell
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def cell_supported(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.sub_quadratic():
        return False, "full attention is quadratic at 524k ctx (see DESIGN.md §4)"
    return True, ""


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train   -> {"batch": {...}}
    prefill -> {"batch": {...}, "cache": {...}}
    decode  -> {"batch": {"tokens": (B,1)}, "cache": {...}}
    """
    B, S = cell.global_batch, cell.seq_len
    i32, f32 = jnp.int32, jnp.float32
    fam = cfg.family

    if cell.kind == "train":
        if fam == "vlm":
            F = cfg.frontend_len
            text = S - F
            batch = {
                "tokens": _sds((B, text), i32),
                "targets": _sds((B, text), i32),
                "frontend": _sds((B, F, FRONTEND_DIM), f32),
            }
        elif fam == "audio":
            batch = {
                "tokens": _sds((B, S), i32),
                "targets": _sds((B, S), i32),
                "frontend": _sds((B, cfg.frontend_len, FRONTEND_DIM), f32),
            }
        else:
            batch = {"tokens": _sds((B, S), i32), "targets": _sds((B, S), i32)}
        return {"batch": batch}

    if cell.kind == "prefill":
        cache = abstract_cache(cfg, B, S)
        if fam == "vlm":
            F = cfg.frontend_len
            batch = {
                "tokens": _sds((B, S - F), i32),
                "frontend": _sds((B, F, FRONTEND_DIM), f32),
            }
        elif fam == "audio":
            batch = {
                "tokens": _sds((B, S), i32),
                "frontend": _sds((B, cfg.frontend_len, FRONTEND_DIM), f32),
            }
        else:
            batch = {"tokens": _sds((B, S), i32)}
        return {"batch": batch, "cache": cache}

    if cell.kind == "decode":
        cache = abstract_cache(cfg, B, S)
        return {"batch": {"tokens": _sds((B, 1), i32)}, "cache": cache}

    raise ValueError(cell.kind)
