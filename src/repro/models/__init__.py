"""repro subpackage."""
