"""Token-choice top-k Mixture-of-Experts with grouped capacity dispatch.

Tokens are partitioned into groups of ``group_size``; each expert has a
per-group capacity ``C ~ top_k * group_size * cf / E``. The dispatch one-hot
is therefore bounded by ``tokens * group_size * top_k * cf`` elements
(independent of E), and the dispatched activation tensor by
``tokens * top_k * cf * d`` — both shardable over the expert axis (EP).

This is the MaxText/Mesh-TF "dropping" formulation: overflow tokens beyond
capacity are dropped (their combine weight is 0), which keeps every shape
static for XLA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import keygen, par


def init_moe_mlp(keys, d: int, spec, dtype):
    E, f = spec.n_experts, spec.d_ff_expert
    return {
        "router": par(next(keys), (d, E), ("embed", "experts"), dtype),
        "wi": par(next(keys), (E, d, f), ("experts", "embed", "expert_ffn"), dtype),
        "wg": par(next(keys), (E, d, f), ("experts", "embed", "expert_ffn"), dtype),
        "wo": par(next(keys), (E, f, d), ("experts", "expert_ffn", "embed"), dtype),
    }


def moe_block(p, x, spec, constrain=lambda a, k: a):
    """x: [b, s, d] -> ([b, s, d], aux_loss scalar)."""
    b, s, d = x.shape
    E, K = spec.n_experts, spec.top_k
    T = b * s
    g = min(spec.group_size, T)
    pad = (-T) % g
    C = max(int(K * g * spec.capacity_factor) // E, 1)
    xf = x.reshape(T, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    G = xf.shape[0] // g
    xg = xf.reshape(G, g, d)

    logits = jnp.einsum("Ggd,dE->GgE", xg, p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)  # [G,g,E]
    topw, topi = jax.lax.top_k(gates, K)  # [G,g,K]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)  # renormalise

    # load-balancing aux loss (Switch-style): E * mean(f_e * P_e)
    me = gates.mean(axis=(0, 1))  # mean router prob per expert
    ce = jax.nn.one_hot(topi[..., 0], E).mean(axis=(0, 1))  # top-1 assignment frac
    aux = E * jnp.sum(me * ce)

    # position-in-expert bookkeeping across the K choices
    dispatch = jnp.zeros((G, g, E, C), jnp.bool_)
    combine = jnp.zeros((G, g, E, C), jnp.float32)
    counts = jnp.zeros((G, E), jnp.int32)
    for kk in range(K):
        oh = jax.nn.one_hot(topi[..., kk], E, dtype=jnp.int32)  # [G,g,E]
        pos = jnp.cumsum(oh, axis=1) - oh + counts[:, None, :]  # [G,g,E]
        keep = (pos < C) & (oh > 0)
        slot = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]  # [G,g,E,C]
        dispatch = dispatch | (slot > 0)
        combine = combine + slot * topw[..., kk][..., None, None]
        counts = counts + oh.sum(axis=1)

    dt = x.dtype
    xe = jnp.einsum("Ggd,GgEC->GECd", xg, dispatch.astype(dt))
    xe = constrain(xe, "experts_in")
    hid = jax.nn.silu(jnp.einsum("GECd,Edf->GECf", xe, p["wg"])) * jnp.einsum(
        "GECd,Edf->GECf", xe, p["wi"]
    )
    hid = constrain(hid, "expert_hidden")
    out_e = jnp.einsum("GECf,Efd->GECd", hid, p["wo"])
    out_e = constrain(out_e, "experts_in")
    y = jnp.einsum("GECd,GgEC->Ggd", out_e, combine.astype(dt))
    y = y.reshape(-1, d)[:T] if pad else y.reshape(T, d)
    return y.reshape(b, s, d), aux
