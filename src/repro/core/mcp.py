"""MCP-style tool registry (paper §5.1: 'full system integration through
MCP-based automation').

Each SECDA-DSE component exposes an API endpoint for data interchange; the
LLM Stack drives exploration by calling these tools. This is an in-process
registry with JSON-schema'd tools — the transport is a function call here,
but the contract (named tools, typed args, JSON results) matches MCP so a
real server can wrap ``Registry.call`` 1:1.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


@dataclass
class Tool:
    name: str
    description: str
    schema: Dict[str, Any]
    fn: Callable[..., Any]


@dataclass
class Registry:
    tools: Dict[str, Tool] = field(default_factory=dict)
    log: List[Dict[str, Any]] = field(default_factory=list)

    def register(self, name: str, description: str, schema: Dict[str, Any]):
        def deco(fn):
            self.tools[name] = Tool(name, description, schema, fn)
            return fn

        return deco

    def list_tools(self) -> List[Dict[str, Any]]:
        return [{"name": t.name, "description": t.description,
                 "inputSchema": t.schema} for t in self.tools.values()]

    def call(self, name: str, **kwargs) -> Any:
        if name not in self.tools:
            raise KeyError(f"unknown tool {name!r}; have {sorted(self.tools)}")
        t = self.tools[name]
        required = t.schema.get("required", [])
        missing = [r for r in required if r not in kwargs]
        if missing:
            raise TypeError(f"tool {name}: missing required args {missing}")
        result = t.fn(**kwargs)
        self.log.append({"tool": name, "args": {k: str(v)[:120] for k, v in kwargs.items()}})
        return result


def build_registry(*, evaluator, db, llm_stack, cost_model=None) -> Registry:
    """Wire the SECDA-DSE components into the tool registry."""
    reg = Registry()

    @reg.register("simulate", "Dry-run compile + roofline evaluation of a plan",
                  {"type": "object",
                   "properties": {"arch": {"type": "string"},
                                  "shape": {"type": "string"},
                                  "point": {"type": "object"}},
                   "required": ["arch", "shape", "point"]})
    def _simulate(arch: str, shape: str, point: Dict, iteration: int = -1,
                  source: str = "mcp"):
        from repro.core.design_space import PlanPoint

        dp = evaluator.evaluate(arch, shape, PlanPoint(dims=point),
                                source=source, iteration=iteration)
        db.append(dp)
        return dp

    @reg.register("query_cost_db", "Query prior hardware data points",
                  {"type": "object",
                   "properties": {"arch": {"type": "string"},
                                  "shape": {"type": "string"},
                                  "status": {"type": "string"}},
                   "required": []})
    def _query(arch: Optional[str] = None, shape: Optional[str] = None,
               status: Optional[str] = None):
        return db.query(arch=arch, shape=shape, status=status)

    @reg.register("best_design", "Best known design for a workload",
                  {"type": "object",
                   "properties": {"arch": {"type": "string"},
                                  "shape": {"type": "string"}},
                   "required": ["arch", "shape"]})
    def _best(arch: str, shape: str):
        return db.best(arch, shape)

    @reg.register("propose", "LLM-stack reasoning-guided plan refinement",
                  {"type": "object",
                   "properties": {"arch": {"type": "string"},
                                  "shape": {"type": "string"},
                                  "point": {"type": "object"},
                                  "metrics": {"type": "object"}},
                   "required": ["arch", "shape", "point", "metrics"]})
    def _propose(arch: str, shape: str, point: Dict, metrics: Dict, k: int = 4):
        from repro.configs import SHAPE_BY_NAME, get_config
        from repro.core.design_space import PlanPoint, PlanTemplate

        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(evaluator.mesh.shape))
        pts, rejected, raw = llm_stack.propose(
            arch, shape, cfg, cell, template, PlanPoint(dims=point), metrics, k=k)
        for dp in rejected:
            db.append(dp)
        return {"proposals": pts, "rejected": len(rejected), "transcript": raw}

    @reg.register("finetune_cost_model", "LoRA-finetune the surrogate on the DB",
                  {"type": "object", "properties": {"rank": {"type": "integer"}},
                   "required": []})
    def _finetune(rank: int = 4, steps: int = 200):
        if cost_model is None:
            return {"status": "no cost model attached"}
        if not cost_model.trained:
            loss = cost_model.pretrain(db)
            return {"status": "pretrained", "loss": loss}
        loss = cost_model.finetune_lora(db, rank=rank, steps=steps)
        return {"status": "lora-finetuned", "loss": loss,
                "adapter_params": _lora_size(cost_model)}

    return reg


def _lora_size(cost_model) -> int:
    from repro.core import lora as lora_mod

    return 0 if cost_model.lora is None else lora_mod.lora_param_count(cost_model.lora)
