"""The SECDA-DSE iterative loop (paper Fig. 1):

    DSE Explorer permutations  ─┐
                                ├─> Evaluation module (dry-run 'simulation')
    LLM Stack refinements      ─┘        │
          ▲                              ▼
          │   RAG over cost DB    cost-model DB  ──>  LoRA fine-tuning
          └──────────────────────────────┘

Per iteration: the Explorer proposes parameter permutations around the
incumbent(s); the LLM Stack consumes the summarized hardware data points +
retrieved context and proposes reasoning-guided refinements; everything is
evaluated through the simulator; results (positive AND negative) land in the
DB; the surrogate cost model is periodically (LoRA-)fine-tuned; diversity is
maintained by keeping a small incumbent pool plus random template samples.

The optional human gate (``approve_fn``) mirrors §3.2.2's human-in-the-loop;
the default auto-approves (the paper's stated end state once the DB grows).

Each iteration's ranked budget is submitted as ONE ``evaluate_batch`` call:
cache hits return instantly, the rest fan out over the evaluator's process
pool, and the gate/negative-datapoint semantics apply to the returned batch
exactly as they did to the old serial loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint
from repro.core.cost_model import CostModel
from repro.core.design_space import PlanPoint, PlanTemplate, baseline_point
from repro.core.evaluator import Evaluator
from repro.core.explorer import Explorer
from repro.core.llm_stack import LLMStack
from repro.core.mcp import Registry, build_registry


@dataclass
class LoopReport:
    arch: str
    shape: str
    iterations: List[Dict] = field(default_factory=list)
    best: Optional[DataPoint] = None
    baseline: Optional[DataPoint] = None

    def improvement(self) -> float:
        if not (self.best and self.baseline):
            return 1.0
        b0 = self.baseline.metrics.get("bound_s")
        b1 = self.best.metrics.get("bound_s")
        return (b1 / b0) if (b0 and b1) else 1.0


@dataclass
class DSELoop:
    evaluator: Evaluator
    db: CostDB
    llm_stack: LLMStack
    cost_model: Optional[CostModel] = None
    registry: Optional[Registry] = None
    approve_fn: Optional[Callable[[DataPoint], bool]] = None  # human gate
    pool_size: int = 2  # incumbent diversity pool
    finetune_every: int = 2

    def __post_init__(self):
        if self.registry is None:
            self.registry = build_registry(
                evaluator=self.evaluator, db=self.db,
                llm_stack=self.llm_stack, cost_model=self.cost_model)

    # ------------------------------------------------------------------
    def run(self, arch: str, shape: str, *, iterations: int = 4,
            eval_budget: int = 3, seed_point: Optional[PlanPoint] = None,
            verbose: bool = True) -> LoopReport:
        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(self.evaluator.mesh.shape))
        report = LoopReport(arch=arch, shape=shape)

        def log(msg):
            if verbose:
                print(f"[dse {arch}/{shape}] {msg}", flush=True)

        # iteration 0: the expert initial design (paper: DSE takes an
        # accelerator design with pre-defined parameters as its input)
        seed = seed_point or baseline_point(cell, template)
        t0 = time.time()
        base_dp = self.registry.call("simulate", arch=arch, shape=shape,
                                     point=dict(seed.dims), iteration=0,
                                     source="expert")
        report.baseline = base_dp
        log(f"baseline: {base_dp.status} bound={base_dp.metrics.get('bound_s')}s "
            f"dom={base_dp.metrics.get('dominant')} ({time.time()-t0:.0f}s)")

        pool: List[DataPoint] = [base_dp]
        explorer = Explorer(self.evaluator, self.db, self.cost_model)

        for it in range(1, iterations + 1):
            incumbent = _best_of(pool) or base_dp
            inc_point = PlanPoint(dims={k: v for k, v in incumbent.point.items()
                                        if k != "__key__"})

            # paper §3.2.2: refine from unsuccessful data points too — the
            # fastest *infeasible* design seeds memory-fixing refinements
            reason_from = [(inc_point, incumbent)]
            neg = _best_negative(self.db, arch, shape, incumbent)
            if neg is not None:
                neg_point = PlanPoint(dims={k: v for k, v in neg.point.items()
                                            if k != "__key__"})
                reason_from.append((neg_point, neg))
                log(f"iter {it}: chaining from negative datapoint "
                    f"(bound={neg.metrics.get('bound_s'):.2f}s, "
                    f"{neg.metrics.get('per_device_gib', 0):.1f}GiB)")

            # --- LLM Stack reasoning-guided refinement ---
            llm_props: List[PlanPoint] = []
            n_rej = 0
            for pt, dp in reason_from:
                res = self.registry.call(
                    "propose", arch=arch, shape=shape,
                    point=dict(pt.dims), metrics=dp.metrics, k=eval_budget)
                llm_props.extend(res["proposals"])
                n_rej += res["rejected"]
            log(f"iter {it}: LLM proposed {len(llm_props)} (rejected {n_rej})")

            # --- Explorer: permutations + LLM candidates, cost-model ranked,
            # submitted as ONE evaluate_batch (pool + dry-run cache) ---
            cache = self.evaluator.cache
            hits0 = cache.hits if cache is not None else 0
            compiles0 = self.evaluator.compile_count
            new_dps = explorer.explore(
                arch, shape, [inc_point], budget=eval_budget, iteration=it,
                extra_candidates=llm_props)
            for dp in new_dps:
                if self.approve_fn is not None and dp.status == "ok":
                    if not self.approve_fn(dp):
                        dp.status = "rejected"
                        dp.reason = "human-in-the-loop veto"
                log(f"  {dp.status:10s} bound={dp.metrics.get('bound_s')} "
                    f"dom={dp.metrics.get('dominant')} mem="
                    f"{dp.metrics.get('per_device_gib', float('nan')):.1f}GiB "
                    f"{_delta_str(dp, incumbent)}")
            pool = _select_pool(pool + new_dps, self.pool_size)

            # --- periodic surrogate (LoRA) fine-tuning on the grown DB ---
            if self.cost_model is not None and it % self.finetune_every == 0:
                r = self.registry.call("finetune_cost_model")
                log(f"  cost model: {r['status']} loss={r.get('loss'):.4f}"
                    if r.get("loss") == r.get("loss") else f"  cost model: {r['status']}")

            report.iterations.append({
                "iteration": it,
                "evaluated": len(new_dps),
                "compiled": self.evaluator.compile_count - compiles0,
                "cache_hits": (cache.hits - hits0) if cache is not None else 0,
                "best_bound": (_best_of(pool).metrics.get("bound_s")
                               if _best_of(pool) else None),
            })

        report.best = _best_of(pool) or self.db.best(arch, shape)
        if report.best:
            log(f"best: bound={report.best.metrics.get('bound_s')}s "
                f"({report.improvement():.2%} of baseline) "
                f"plan={ {k: v for k, v in report.best.point.items() if k != '__key__'} }")
        return report


def _best_of(pool: Sequence[DataPoint]) -> Optional[DataPoint]:
    ok = [d for d in pool if d.status == "ok" and d.metrics.get("bound_s")]
    return min(ok, key=lambda d: d.metrics["bound_s"]) if ok else None


def _best_negative(db: CostDB, arch: str, shape: str,
                   incumbent: DataPoint) -> Optional[DataPoint]:
    """Fastest infeasible design that beats the incumbent's bound."""
    inc = incumbent.metrics.get("bound_s") or float("inf")
    neg = [d for d in db.query(arch, shape, "infeasible")
           if d.metrics.get("bound_s") and d.metrics["bound_s"] < 0.9 * inc]
    return min(neg, key=lambda d: d.metrics["bound_s"]) if neg else None


def _select_pool(dps: Sequence[DataPoint], k: int) -> List[DataPoint]:
    ok = sorted((d for d in dps if d.status == "ok" and d.metrics.get("bound_s")),
                key=lambda d: d.metrics["bound_s"])
    # diversity: keep the best k-1 plus the most-different remaining design
    return list(ok[:k]) if len(ok) <= k else list(ok[: k - 1]) + [ok[-1]]


def _delta_str(dp: DataPoint, incumbent: DataPoint) -> str:
    a, b = dp.metrics.get("bound_s"), incumbent.metrics.get("bound_s")
    if not (a and b):
        return ""
    changed = {k: v for k, v in dp.point.items()
               if k != "__key__" and incumbent.point.get(k) != v}
    return f"x{a/b:.3f} vs incumbent (changed {changed})"
