"""The SECDA-DSE iterative loop (paper Fig. 1):

    search strategies propose    ─┐  (greedy / LLM stack / annealing /
                                  ├─> surrogate gate ─> Evaluation module
    Ensemble budget allocation   ─┘        │             (dry-run 'simulation')
          ▲                                ▼
          │   RAG over cost DB    cost-model DB  ──>  LoRA fine-tuning
          └────────────────────────────────┘

``DSELoop`` is pure orchestration: seed the expert design, let the pluggable
:class:`~repro.search.base.SearchStrategy` propose candidates, dedupe against
the DB's key index, surrogate-rank, pass the batch through the optional
:class:`~repro.search.gate.SurrogateGate` (predicted-hopeless candidates are
recorded as ``pruned`` data points instead of compiled), batch-evaluate the
survivors, feed every result — positive AND negative — back to the strategy
and the DB, and periodically (LoRA-)fine-tune the surrogate.

The default strategy is an :class:`~repro.search.ensemble.Ensemble` of
``GreedyNeighborhood`` + ``LLMGuided`` — the paper's two interchangeable
proposal engines sharing one evaluation loop. ``--strategy`` on the CLIs
swaps in annealing, evolutionary, or the full four-member bandit ensemble.

The optional human gate (``approve_fn``) mirrors §3.2.2's human-in-the-loop;
the default auto-approves (the paper's stated end state once the DB grows).
Each iteration's ranked budget is submitted as ONE ``evaluate_batch`` call:
cache hits return instantly, the rest fan out over the evaluator's process
pool.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint, workload_features
from repro.core.cost_model import CostModel
from repro.core.design_space import PlanPoint, PlanTemplate, baseline_point
from repro.core.evaluator import Evaluator
from repro.core.llm_stack import LLMStack
from repro.core.mcp import Registry, build_registry
from repro.search import (Candidate, Ensemble, GreedyNeighborhood, LLMGuided,
                          SearchState, SearchStrategy, SurrogateGate,
                          select_candidates)


@dataclass
class LoopReport:
    arch: str
    shape: str
    iterations: List[Dict] = field(default_factory=list)
    best: Optional[DataPoint] = None
    baseline: Optional[DataPoint] = None

    def improvement(self) -> float:
        if not (self.best and self.baseline):
            return 1.0
        b0 = self.baseline.metrics.get("bound_s")
        b1 = self.best.metrics.get("bound_s")
        return (b1 / b0) if (b0 and b1) else 1.0


@dataclass
class DSELoop:
    evaluator: Evaluator
    db: CostDB
    llm_stack: LLMStack
    cost_model: Optional[CostModel] = None
    registry: Optional[Registry] = None
    approve_fn: Optional[Callable[[DataPoint], bool]] = None  # human gate
    pool_size: int = 2  # incumbent diversity pool
    finetune_every: int = 2
    strategy: Optional[SearchStrategy] = None  # None -> greedy+LLM ensemble
    gate: Optional[SurrogateGate] = None  # surrogate-gated evaluation

    def __post_init__(self):
        if self.registry is None:
            self.registry = build_registry(
                evaluator=self.evaluator, db=self.db,
                llm_stack=self.llm_stack, cost_model=self.cost_model)

    def _default_strategy(self) -> SearchStrategy:
        return Ensemble([GreedyNeighborhood(), LLMGuided(self.llm_stack)])

    # ------------------------------------------------------------------
    def run(self, arch: str, shape: str, *, iterations: int = 4,
            eval_budget: int = 3, seed_point: Optional[PlanPoint] = None,
            verbose: bool = True,
            heartbeat: Optional[Callable[[Dict], None]] = None) -> LoopReport:
        """Run the loop for one cell and return its :class:`LoopReport`.

        ``heartbeat``, when given, is called with a small progress dict —
        ``{"iteration", "phase", "evaluated", "compiled", "pruned",
        "cache_hits", "best_bound"}`` — after the baseline evaluation
        (iteration 0), after every proposal round (``phase="proposed"``),
        after every completed ``evaluate_batch`` (``phase="evaluated"``),
        and at the end of every iteration (``phase="iteration"``).
        Campaigns use it to refresh their ``progress.json`` at
        iteration/batch granularity: no supervisor-visible gap ever spans
        more than one slow step (one LLM proposal round, one evaluation
        batch, or one observe+fine-tune tail), which is what lets a hang
        timeout sit far below one cell's wall time; the callback must be
        cheap and must not raise."""
        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(self.evaluator.mesh.shape))
        wl = workload_features(cfg, cell)
        report = LoopReport(arch=arch, shape=shape)
        # strategies carry per-cell state (walker position, population,
        # bandit credit) — a loop bound to one is single-cell; campaigns
        # construct a fresh strategy per cell
        strategy = self.strategy or self._default_strategy()

        def log(msg):
            if verbose:
                print(f"[dse {arch}/{shape}] {msg}", flush=True)

        # iteration 0: the expert initial design (paper: DSE takes an
        # accelerator design with pre-defined parameters as its input)
        seed = seed_point or baseline_point(cell, template)
        t0 = time.time()
        cache = self.evaluator.cache
        base_hits0 = cache.hits if cache is not None else 0
        base_compiles0 = self.evaluator.compile_count
        base_dp = self.registry.call("simulate", arch=arch, shape=shape,
                                     point=dict(seed.dims), iteration=0,
                                     source="expert")
        report.baseline = base_dp
        if heartbeat is not None:
            heartbeat({"iteration": 0, "phase": "baseline", "evaluated": 1,
                       "compiled": self.evaluator.compile_count - base_compiles0,
                       "pruned": 0,
                       "cache_hits": (cache.hits - base_hits0)
                       if cache is not None else 0,
                       "best_bound": base_dp.metrics.get("bound_s")})
        log(f"baseline: {base_dp.status} bound={base_dp.metrics.get('bound_s')}s "
            f"dom={base_dp.metrics.get('dominant')} ({time.time()-t0:.0f}s)")

        pool: List[DataPoint] = [base_dp]
        for it in range(1, iterations + 1):
            incumbent = _best_of(pool) or base_dp
            state = SearchState(
                arch=arch, shape=shape, cfg=cfg, cell=cell, template=template,
                db=self.db, iteration=it, budget=eval_budget,
                incumbent=incumbent, pool=list(pool),
                cost_model=self.cost_model, workload=wl,
                mesh=self.evaluator.mesh_name)

            # --- propose: the pluggable strategy decides where to look ---
            cands = strategy.propose(state)
            ranked = select_candidates(state, cands)
            log(f"iter {it}: {len(cands)} proposed -> {len(ranked)} selected "
                f"({_source_counts(ranked)})")
            if heartbeat is not None:
                # propose can be slow (a real LLM call) — beat before the
                # batch so no single supervisor gap spans propose AND eval
                heartbeat({"iteration": it, "phase": "proposed",
                           "evaluated": 0, "compiled": 0, "pruned": 0,
                           "cache_hits": 0,
                           "best_bound": (incumbent.metrics.get("bound_s")
                                          if incumbent else None)})

            # --- gate + batch-evaluate ---
            if self.gate is not None:
                self.gate.calibrate(self.db, arch=arch, shape=shape,
                                    mesh=self.evaluator.mesh_name)
            hits0 = cache.hits if cache is not None else 0
            compiles0 = self.evaluator.compile_count
            pruned0 = self.evaluator.pruned_count
            new_dps = self.evaluator.evaluate_batch(
                arch, shape, [c.point for c in ranked],
                source=[c.source for c in ranked], iteration=it,
                gate=self.gate,
                incumbent_bound=(incumbent.metrics.get("bound_s")
                                 if incumbent.status == "ok" else None))
            if heartbeat is not None:
                # batch done: refresh the supervisor heartbeat before the
                # (possibly slow) observe/fine-tune tail of the iteration
                heartbeat({"iteration": it, "phase": "evaluated",
                           "evaluated": len(new_dps),
                           "compiled": self.evaluator.compile_count - compiles0,
                           "pruned": self.evaluator.pruned_count - pruned0,
                           "cache_hits": (cache.hits - hits0)
                           if cache is not None else 0,
                           "best_bound": (incumbent.metrics.get("bound_s")
                                          if incumbent else None)})
            for dp in new_dps:
                if (self.approve_fn is not None and dp.status == "ok"
                        and not self.approve_fn(dp)):
                    dp.status = "rejected"
                    dp.reason = "human-in-the-loop veto"
                log(f"  {dp.status:10s} bound={dp.metrics.get('bound_s')} "
                    f"dom={dp.metrics.get('dominant')} mem="
                    f"{dp.metrics.get('per_device_gib', float('nan')):.1f}GiB "
                    f"{_delta_str(dp, incumbent)}")
            # a design the gate pruned in an earlier iteration stays
            # proposable (it was never measured) but isn't re-recorded —
            # one pruned row per design, however often it is re-predicted
            prior_pruned = (self.db.keys(arch, shape)
                            - self.db.keys(arch, shape, include_pruned=False))
            self.db.append_many([
                dp for dp in new_dps
                if not (dp.status == "pruned"
                        and dp.point.get("__key__") in prior_pruned)])

            # --- observe: every result, positive AND negative, feeds back ---
            strategy.observe(new_dps)
            pool = _select_pool(pool + new_dps, self.pool_size)

            # --- periodic surrogate (LoRA) fine-tuning on the grown DB ---
            if self.cost_model is not None and it % self.finetune_every == 0:
                r = self.registry.call("finetune_cost_model")
                log("  " + _finetune_msg(r))

            entry = {
                "iteration": it,
                "evaluated": len(new_dps),
                "compiled": self.evaluator.compile_count - compiles0,
                "pruned": self.evaluator.pruned_count - pruned0,
                "cache_hits": (cache.hits - hits0) if cache is not None else 0,
                "sources": _source_counts(ranked),
                "allocation": (dict(strategy.credit)
                               if isinstance(strategy, Ensemble) else None),
                "best_bound": (_best_of(pool).metrics.get("bound_s")
                               if _best_of(pool) else None),
            }
            report.iterations.append(entry)
            if heartbeat is not None:
                heartbeat({**entry, "phase": "iteration"})

        report.best = _best_of(pool) or self.db.best(arch, shape)
        if report.best:
            log(f"best: bound={report.best.metrics.get('bound_s')}s "
                f"({report.improvement():.2%} of baseline) "
                f"plan={ {k: v for k, v in report.best.point.items() if k != '__key__'} }")
        return report

def _finetune_msg(r: Dict) -> str:
    """NaN/None-safe fine-tune log line (a None loss used to TypeError in an
    eagerly-evaluated f-string ternary)."""
    loss = r.get("loss")
    if isinstance(loss, (int, float)) and loss == loss:  # not None, not NaN
        return f"cost model: {r['status']} loss={loss:.4f}"
    return f"cost model: {r['status']} loss=n/a"


def _source_counts(cands: Sequence[Candidate]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for c in cands:
        out[c.source] = out.get(c.source, 0) + 1
    return out


def _best_of(pool: Sequence[DataPoint]) -> Optional[DataPoint]:
    ok = [d for d in pool if d.status == "ok" and d.metrics.get("bound_s")]
    return min(ok, key=lambda d: d.metrics["bound_s"]) if ok else None


def _select_pool(dps: Sequence[DataPoint], k: int) -> List[DataPoint]:
    ok = sorted((d for d in dps if d.status == "ok" and d.metrics.get("bound_s")),
                key=lambda d: d.metrics["bound_s"])
    # diversity: keep the best k-1 plus the most-different remaining design
    return list(ok[:k]) if len(ok) <= k else list(ok[: k - 1]) + [ok[-1]]


def _delta_str(dp: DataPoint, incumbent: DataPoint) -> str:
    a, b = dp.metrics.get("bound_s"), incumbent.metrics.get("bound_s")
    if not (a and b):
        return ""
    changed = {k: v for k, v in dp.point.items()
               if k != "__key__" and incumbent.point.get(k) != v}
    return f"x{a/b:.3f} vs incumbent (changed {changed})"
