"""TPU device models — the 'target FPGA device' input of SECDA-DSE.

Hardware constants per the assignment (TPU v5e): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI. VMEM/MXU budgets are the BRAM/DSP analogs
used by the kernel resource model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class DeviceModel:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bytes: int
    hbm_bw: float  # B/s
    ici_link_bw: float  # B/s per link
    vmem_bytes: int  # on-chip vector memory (BRAM analog)
    mxu_dim: int = 128  # systolic array edge (DSP analog)
    vpu_lanes: int = 8 * 128


TPU_V5E = DeviceModel(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    vmem_bytes=128 * 2**20,
)

DEVICES: Dict[str, DeviceModel] = {"tpu-v5e": TPU_V5E}


def get_device(name: str = "tpu-v5e") -> DeviceModel:
    return DEVICES[name]


@dataclass(frozen=True)
class RooflineTerms:
    """The three roofline terms, in seconds (per step), per the assignment."""

    compute_s: float
    memory_s: float
    collective_s: float

    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def bound(self) -> float:
        """Roofline step-time lower bound (perfect overlap of the 3 engines)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self):
        return dataclasses.asdict(self) | {"dominant": self.dominant(), "bound_s": self.bound()}


def roofline_terms(*, flops: float, hbm_bytes: float, wire_bytes: float,
                   device: DeviceModel = TPU_V5E) -> RooflineTerms:
    """All inputs are PER-DEVICE totals for one step (from the HLO analyzer)."""
    return RooflineTerms(
        compute_s=flops / device.peak_flops_bf16,
        memory_s=hbm_bytes / device.hbm_bw,
        collective_s=wire_bytes / device.ici_link_bw,
    )
