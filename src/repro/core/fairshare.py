"""Fair-share scheduling policy for the DSE service control plane.

The service daemon (``repro.launch.service``) multiplexes a bounded pool
of campaign workers across tenants. Every scheduling decision is made by
the pure functions here — the daemon feeds them a snapshot of tenant
state and applies the returned grants — so fairness is unit-testable and
replayable without booting an HTTP server or spawning workers (the same
pattern as ``plan_steals`` in the orchestrator; both are registered in
the RPR003 purity registry).

Policy: weighted round-robin with deficit credits. Each grant round,
every *eligible* tenant (non-empty backlog, under its worker cap and
cell budget) earns credit proportional to its priority; the tenant with
the highest accumulated credit wins the slot and pays ``1.0`` for it.
Credits persist across scheduler ticks, so a tenant that was skipped
while the pool was full catches up once slots free — a stalled tenant
cannot starve the others, and a high-priority tenant gets proportionally
more workers, not all of them.

Budget accounting is in *cells*: a tenant's submissions stop being
scheduled once the cells it has completed reach its declared budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


@dataclass
class TenantSnapshot:
    """One tenant's state as seen by a scheduler tick."""
    name: str
    priority: int = 1          # >= 1; relative worker share
    backlog: int = 0           # pending + leased cells in the tenant queue
    workers: int = 0           # currently running workers
    cells_done: int = 0        # completed cells (budget accounting)
    budget_cells: Optional[int] = None  # None = unlimited
    credit: float = 0.0        # deficit carried across ticks
    stalled: bool = False      # no heartbeat progress; earns no new credit


@dataclass
class GrantPlan:
    """Result of one scheduler tick: which tenants get a new worker, and
    the credit ledger to carry into the next tick."""
    grants: List[str] = field(default_factory=list)
    credits: Dict[str, float] = field(default_factory=dict)


def budget_left(budget_cells: Optional[int], cells_done: int) -> Optional[int]:
    """Remaining cell budget (None = unlimited, floor 0)."""
    if budget_cells is None:
        return None
    return max(0, budget_cells - cells_done)


def over_budget(budget_cells: Optional[int], cells_done: int) -> bool:
    """True once a tenant has exhausted its declared cell budget."""
    left = budget_left(budget_cells, cells_done)
    return left is not None and left <= 0


def _eligible(t: TenantSnapshot, extra_workers: int,
              max_workers_per_tenant: int) -> bool:
    if t.backlog <= 0 or over_budget(t.budget_cells, t.cells_done):
        return False
    granted = t.workers + extra_workers
    # one worker per backlog cell is the useful ceiling; the per-tenant
    # cap bounds how much of the pool a single tenant may hold
    return granted < min(t.backlog, max_workers_per_tenant)


def plan_worker_grants(tenants: Sequence[TenantSnapshot], free_slots: int,
                       max_workers_per_tenant: int = 2) -> GrantPlan:
    """Deficit-weighted round-robin: assign up to ``free_slots`` workers.

    Pure function of its inputs — no clock, no RNG; ties break on
    (priority, name) so the grant order is deterministic for any
    permutation of ``tenants``.
    """
    order = sorted(tenants, key=lambda t: (-t.priority, t.name))
    credits = {t.name: t.credit for t in order}
    granted: Dict[str, int] = {t.name: 0 for t in order}
    grants: List[str] = []
    for _ in range(max(0, free_slots)):
        eligible = [t for t in order
                    if not t.stalled
                    and _eligible(t, granted[t.name],
                                  max_workers_per_tenant)]
        if not eligible:
            break
        total = sum(t.priority for t in eligible)
        for t in eligible:
            credits[t.name] += t.priority / total
        # max() keeps the first maximum, so ties fall back to the sorted
        # (-priority, name) order — deterministic for any input permutation
        winner = max(eligible, key=lambda t: (credits[t.name], t.priority))
        credits[winner.name] -= 1.0
        granted[winner.name] += 1
        grants.append(winner.name)
    return GrantPlan(grants=grants, credits=credits)


__all__ = ["TenantSnapshot", "GrantPlan", "budget_left", "over_budget",
           "plan_worker_grants"]
