"""Retrieval-Augmented Generation module (paper §3.2.1).

Two retrieval sources, both vectorized:
  * the cost-model DB — prior hardware data points featurized by
    (plan dims, workload context), retrieved by cosine similarity so the LLM
    reasons over *similar prior designs* rather than the full raw logs;
  * the template/kernel source corpus — docstrings and module sources of this
    repo, indexed by hashed bag-of-words (the SECDA-TFLite codebase analog).

Only the top-k fragments enter the prompt ("maintain token limit while
providing enough context").
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost_db import CostDB, DataPoint, featurize

_DIM = 256


def _bow_vector(text: str, dim: int = _DIM) -> np.ndarray:
    v = np.zeros(dim, np.float32)
    for tok in re.findall(r"[a-zA-Z_][a-zA-Z0-9_]+", text.lower()):
        h = int(hashlib.md5(tok.encode()).hexdigest()[:8], 16)
        v[h % dim] += 1.0
    n = np.linalg.norm(v)
    return v / n if n else v


@dataclass
class CodeIndex:
    """Hashed bag-of-words index over repo sources (the codebase RAG)."""

    roots: Sequence[Path]
    chunks: List[Tuple[str, str]] = field(default_factory=list)  # (tag, text)
    _mat: Optional[np.ndarray] = None

    def build(self) -> "CodeIndex":
        for root in self.roots:
            for py in sorted(Path(root).rglob("*.py")):
                text = py.read_text()
                # one chunk per top-level def/class + the module docstring
                parts = re.split(r"\n(?=def |class )", text)
                for part in parts:
                    head = part.strip().splitlines()[0][:80] if part.strip() else ""
                    self.chunks.append((f"{py.name}:{head}", part[:2000]))
        self._mat = np.stack([_bow_vector(t) for _, t in self.chunks]) if self.chunks else None
        return self

    def retrieve(self, query: str, k: int = 3) -> List[Tuple[str, str]]:
        if self._mat is None:
            return []
        q = _bow_vector(query)
        scores = self._mat @ q
        idx = np.argsort(-scores)[:k]
        return [self.chunks[i] for i in idx]


@dataclass
class DesignRetriever:
    """Nearest-neighbour retrieval over the cost DB's featurized designs."""

    db: CostDB

    def retrieve(self, point: Dict, workload: Dict, k: int = 5,
                 arch: Optional[str] = None) -> List[DataPoint]:
        cands = self.db.query(arch=arch) if arch else self.db.all()
        cands = [d for d in cands if d.metrics.get("workload")]
        if not cands:
            return []
        q = featurize(point, workload)
        qn = np.linalg.norm(q) or 1.0
        scored = []
        for d in cands:
            v = featurize(d.point, d.metrics["workload"])
            s = float(v @ q) / ((np.linalg.norm(v) or 1.0) * qn)
            scored.append((s, d))
        scored.sort(key=lambda t: -t[0])
        return [d for _, d in scored[:k]]


def summarize_datapoint(d: DataPoint) -> str:
    """Compact textual 'hardware data point' for the prompt context."""
    m = d.metrics
    if d.status in ("ok", "infeasible"):
        return (f"[{d.status}] {d.arch}/{d.shape} plan={_plan_str(d.point)} "
                f"bound={m.get('bound_s', float('nan')):.3f}s dom={m.get('dominant','-')} "
                f"mem={m.get('per_device_gib', float('nan')):.1f}GiB "
                f"mfu={m.get('mfu_at_bound', 0)*100:.1f}%"
                + (f" NEGATIVE: {d.reason}" if d.negative() else ""))
    return f"[{d.status}] {d.arch}/{d.shape} plan={_plan_str(d.point)} NEGATIVE: {d.reason}"


def _plan_str(point: Dict) -> str:
    keep = {k: v for k, v in point.items() if k != "__key__"}
    return ",".join(f"{k}={v}" for k, v in sorted(keep.items()))
