"""Kernel design space registry — the jax-free half of kernel cells.

SECDA-DSE explores *accelerator-internal* parameters, not just sharding
plans: the Pallas tile/block knobs in ``repro.kernels`` (``block_q`` /
``block_k`` / ``causal`` for flash attention, ``block_rows`` for rmsnorm,
``chunk`` for the SSD scan, ``block`` for vecmul) are the pragma-level
dials the paper's DSE loop turns. This module holds everything the
supervisor layer (campaign / orchestrator CLIs, queue seeding, shard
math) needs to reason about that space **without importing jax**:

  * ``KernelShape`` — a named workload instance for one kernel (the
    analog of a ``ShapeCell``), carrying the problem sizes and dtype;
  * ``KERNEL_SHAPES`` / ``KERNEL_SHAPE_BY_NAME`` — the benchmark
    registry, sized to run in interpret mode on a CPU CI box;
  * the legal per-kernel dimension pools (divisibility-filtered against
    the shape, VMEM-checked via ``kernels.resource_model``);
  * the ``kernel:<name>`` arch-column encoding that threads kernel cells
    through the existing ``CostDB``/``CellQueue``/``merge_db`` plumbing
    unchanged (the colon is filesystem-safe and contains no ``__``, so
    report stems still split cleanly).

The jax-coupled half — ``KernelTemplate``/``KernelPoint`` — lives beside
``PlanTemplate`` in ``core.design_space`` and delegates here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.core.device import DeviceModel, TPU_V5E
from repro.kernels.resource_model import RESOURCE_FNS, KernelResources

#: arch-column prefix that marks a row/ticket/report as a kernel cell
KERNEL_ARCH_PREFIX = "kernel:"

#: bytes per element for the dtypes the kernel space explores
_ITEMSIZE = {"float32": 4, "bfloat16": 2}

#: candidate pools per tunable dimension, before per-shape filtering
_POOLS: Dict[str, Dict[str, Tuple[Any, ...]]] = {
    "flash_attention": {"block_q": (64, 128, 256, 512),
                        "block_k": (64, 128, 256, 512),
                        "causal": (True, False)},
    "rmsnorm": {"block_rows": (32, 64, 128, 256)},
    "ssd_scan": {"chunk": (32, 64, 128, 256)},
    "vecmul": {"block": (256, 512, 1024, 2048, 4096)},
}

#: the frozen-default point each kernel ships with today (``ops.py``
#: signatures) — the "default" side of every tuned-vs-default comparison,
#: snapped down to the largest legal value for small shapes
_DEFAULTS: Dict[str, Dict[str, Any]] = {
    "flash_attention": {"block_q": 512, "block_k": 512, "causal": True},
    "rmsnorm": {"block_rows": 128},
    "ssd_scan": {"chunk": 256},
    "vecmul": {"block": 1024},
}


@dataclass(frozen=True)
class KernelShape:
    """One kernel workload instance: problem sizes + dtype.

    ``params`` keys per kernel: flash_attention ``b,sq,sk,h,kh,d``;
    rmsnorm ``rows,d``; ssd_scan ``b,s,nh,dh,N``; vecmul ``L``.
    """

    name: str
    kernel: str
    params: Mapping[str, int] = field(default_factory=dict)
    dtype: str = "float32"

    @property
    def itemsize(self) -> int:
        """Bytes per element of the working dtype."""
        return _ITEMSIZE[self.dtype]


#: CI/interpret-sized benchmark shapes — at least one per kernel, two
#: dtypes in play, plus a GQA attention variant (kh < h)
KERNEL_SHAPES: Tuple[KernelShape, ...] = (
    KernelShape("attn_s128_f32", "flash_attention",
                {"b": 2, "sq": 128, "sk": 128, "h": 4, "kh": 4, "d": 64},
                "float32"),
    KernelShape("attn_s256_gqa_bf16", "flash_attention",
                {"b": 1, "sq": 256, "sk": 256, "h": 4, "kh": 2, "d": 64},
                "bfloat16"),
    KernelShape("rms_512x512_f32", "rmsnorm",
                {"rows": 512, "d": 512}, "float32"),
    KernelShape("rms_1kx256_bf16", "rmsnorm",
                {"rows": 1024, "d": 256}, "bfloat16"),
    KernelShape("ssd_s256_f32", "ssd_scan",
                {"b": 1, "s": 256, "nh": 4, "dh": 32, "N": 32}, "float32"),
    KernelShape("vec_64k_f32", "vecmul", {"L": 65536}, "float32"),
)

KERNEL_SHAPE_BY_NAME: Dict[str, KernelShape] = {
    s.name: s for s in KERNEL_SHAPES}

KERNEL_NAMES: Tuple[str, ...] = tuple(sorted(_POOLS))


def kernel_arch(kernel: str) -> str:
    """Encode a kernel name into the CostDB/queue ``arch`` column."""
    return KERNEL_ARCH_PREFIX + kernel


def parse_kernel_arch(arch: str) -> Optional[str]:
    """Inverse of :func:`kernel_arch`; None for plan-space arch ids."""
    if arch.startswith(KERNEL_ARCH_PREFIX):
        return arch[len(KERNEL_ARCH_PREFIX):]
    return None


def legal_kernel_dims(shape: KernelShape) -> Dict[str, Tuple[Any, ...]]:
    """Per-shape legal pools: block dims that must divide a sequence axis
    (flash ``block_q``/``block_k``, ssd ``chunk``) are filtered to exact
    divisors no larger than the axis — those kernels assert divisibility
    after clamping; rmsnorm/vecmul pad internally, so their pools pass
    through unfiltered."""
    pools = dict(_POOLS[shape.kernel])
    p = shape.params
    if shape.kernel == "flash_attention":
        pools["block_q"] = tuple(v for v in pools["block_q"]
                                 if v <= p["sq"] and p["sq"] % v == 0)
        pools["block_k"] = tuple(v for v in pools["block_k"]
                                 if v <= p["sk"] and p["sk"] % v == 0)
    elif shape.kernel == "ssd_scan":
        pools["chunk"] = tuple(v for v in pools["chunk"]
                               if v <= p["s"] and p["s"] % v == 0)
    return pools


def kernel_resources(shape: KernelShape, dims: Mapping[str, Any],
                     device: DeviceModel = TPU_V5E) -> KernelResources:
    """Run the analytic resource model for one candidate point: the
    dry-run-tier feasibility check and latency bound for kernel cells."""
    fn = RESOURCE_FNS[shape.kernel]
    p = shape.params
    if shape.kernel == "vecmul":
        return fn(p["L"], int(dims["block"]),
                  itemsize=shape.itemsize, dev=device)
    if shape.kernel == "rmsnorm":
        return fn(p["rows"], p["d"], int(dims["block_rows"]),
                  itemsize=shape.itemsize, dev=device)
    if shape.kernel == "flash_attention":
        return fn(p["b"], p["sq"], p["sk"], p["h"], p["kh"], p["d"],
                  int(dims["block_q"]), int(dims["block_k"]),
                  itemsize=shape.itemsize, dev=device)
    if shape.kernel == "ssd_scan":
        return fn(p["b"], p["s"], p["nh"], p["dh"], p["N"],
                  int(dims["chunk"]), itemsize=shape.itemsize, dev=device)
    raise KeyError(f"unknown kernel {shape.kernel!r}")


def default_kernel_dims(shape: KernelShape) -> Dict[str, Any]:
    """The shipped-default point for a shape, snapped into the legal
    pools (e.g. ``block_q=512`` becomes 128 on a 128-long sequence —
    exactly what the kernel's own min-clamp would run)."""
    legal = legal_kernel_dims(shape)
    out: Dict[str, Any] = {}
    for k, default in _DEFAULTS[shape.kernel].items():
        pool = legal[k]
        if default in pool:
            out[k] = default
        else:
            # the kernels clamp block=min(block, axis): the largest legal
            # value <= default is what the default actually executes as
            smaller = [v for v in pool if isinstance(v, int) and v <= default]
            out[k] = max(smaller) if smaller else pool[0]
    return out


def kernel_workload(shape: KernelShape) -> Dict[str, float]:
    """Map a kernel shape onto the fixed workload-feature keys the cost
    model featurizer reads (missing keys featurize to zero), so one
    surrogate architecture serves both design spaces."""
    p = shape.params
    seq = p.get("sq") or p.get("s") or p.get("rows") or p.get("L") or 0
    elems = 1
    for v in p.values():
        elems *= max(int(v), 1)
    return {
        "n_params": float(elems),
        "seq_len": float(seq),
        "global_batch": float(p.get("b", 1)),
        "d_model": float(p.get("d") or p.get("dh") or 0),
        "is_train": 0.0,
        "is_decode": 0.0,
    }
