"""Chain-of-Thought scaffold (paper §3.2.1, Fig. 4).

The CoT component structures the LLM's exploration reasoning into explicit
stages, each producing an auditable trace:

  1. ANALYZE   — which roofline term dominates, by how much, and why;
  2. ENUMERATE — candidate plan mutations whose preconditions hold;
  3. ESTIMATE  — napkin math for the expected delta of each candidate on the
                 dominant term (hardware-grounded closed forms);
  4. RANK      — sort by predicted win; emit top-k proposals.

The same scaffold is embedded into the LLM prompt (so a real model reasons
step-by-step), and executed symbolically by MockLLM so the loop is exact and
hermetic offline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.cost_db import DataPoint

# move catalog: (dimension, value) with precondition + effect rationale
@dataclass(frozen=True)
class Move:
    dim: str
    value: Any
    targets: Tuple[str, ...]  # which roofline terms it attacks
    rationale: str

    def applies(self, point: Dict, metrics: Dict) -> bool:
        return point.get(self.dim) != self.value


MOVES: List[Move] = [
    Move("batch_rule", "data+model", ("collective", "compute"),
         "flatten batch over all chips: removes TP activation all-reduces; "
         "grads reduce over the full mesh instead"),
    Move("batch_rule", "data", ("memory",),
         "restore 2D DP x TP so params/optimizer shard over the model axis"),
    Move("embed_rule", "data", ("memory",),
         "ZeRO-3-style weight sharding over the data axis (all-gather per layer)"),
    Move("seq_rule", "model", ("memory",),
         "sequence-parallel residuals: saved activations shrink by the TP degree"),
    Move("seq_rule", None, ("collective",),
         "drop SP resharding: removes per-layer seq all-gathers when memory allows"),
    Move("attn_rule", "head_dim", ("memory", "collective"),
         "shard head_dim when head count does not divide the TP axis"),
    Move("attn_rule", "heads", ("compute",), "shard attention by heads (local softmax)"),
    Move("expert_rule", "expert_ffn", ("memory",),
         "shard the expert FFN dim when n_experts does not divide the TP axis"),
    Move("expert_rule", "experts", ("collective",),
         "expert parallelism: each chip holds n_experts/TP experts"),
    Move("vocab_rule", "model", ("memory",), "shard embedding/LM-head vocab"),
    Move("loss_chunk", 1024, ("memory",),
         "chunk the CE loss so [B,S,V] logits are never materialised"),
    Move("loss_chunk", 512, ("memory",), "finer CE chunking"),
    Move("remat", "full", ("memory",), "full activation remat (+1 fwd of compute)"),
    Move("remat", "dots", ("compute",),
         "keep matmul outputs: removes the remat recompute fwd pass"),
    Move("remat", "none", ("compute",), "no remat when memory headroom exists"),
    Move("microbatches", 2, ("memory",), "halve per-step activation footprint"),
    Move("microbatches", 4, ("memory",), "quarter activation footprint"),
    Move("zero1", True, ("memory",), "shard optimizer m/v over the data axis"),
    Move("grad_compress", "int8", ("collective",),
         "int8 gradient all-reduce (4x wire reduction) with error feedback"),
    Move("decode_attn", "sp_shardmap", ("collective", "memory"),
         "flash-decoding shard_map: KV stays sequence-sharded; only softmax "
         "stats cross the mesh instead of the whole cache"),
    Move("seq_kv_rule", "model", ("memory",), "shard decode KV caches on sequence"),
    Move("opt_int8", True, ("memory",),
         "blockwise int8 Adam moments: optimizer state 8B -> 2B per param"),
    Move("attn_impl", "tri", ("compute",),
         "triangular block scan: skip fully-masked causal blocks "
         "(~0.5x attention FLOPs; O(s*w) for sliding window)"),
]


@dataclass
class CoTTrace:
    analyze: str = ""
    enumerate: List[str] = field(default_factory=list)
    estimate: List[Tuple[str, float, str]] = field(default_factory=list)
    rank: List[str] = field(default_factory=list)

    def render(self) -> str:
        lines = ["Step 1 — ANALYZE:", "  " + self.analyze, "Step 2 — ENUMERATE:"]
        lines += [f"  - {e}" for e in self.enumerate]
        lines.append("Step 3 — ESTIMATE (napkin math):")
        lines += [f"  - {m}: predicted x{f:.2f} on target term — {w}"
                  for m, f, w in self.estimate]
        lines.append("Step 4 — RANK:")
        lines += [f"  {i+1}. {r}" for i, r in enumerate(self.rank)]
        return "\n".join(lines)


def _estimate_factor(move: Move, point: Dict, metrics: Dict, workload: Dict,
                     mesh_model: int) -> Tuple[float, str]:
    """Closed-form napkin estimate of the dominant-term multiplier."""
    dom = metrics.get("dominant", "collective")
    if dom not in move.targets:
        return 1.0, "does not address the dominant term"
    if move.dim == "batch_rule" and move.value == "data+model":
        return 0.15, ("TP activation all-reduces (O(L·b_local·s·d) wire) vanish; "
                      "remaining wire = one gradient reduce over params")
    if move.dim == "loss_chunk" and move.value:
        v = workload.get("vocab", 1e5)
        return 0.5, f"logits [B,S,{int(v)}] become [B,{move.value},{int(v)}] per chunk"
    if move.dim == "decode_attn":
        return 0.1, "cache all-gather (GB) replaced by softmax stats (KB)"
    if move.dim == "attn_rule" and move.value == "head_dim":
        return 0.5, "attention tensors shard on head_dim instead of replicating"
    if move.dim == "expert_rule":
        return 0.3, "expert weights shard instead of replicating"
    if move.dim == "microbatches":
        return 1.0 / float(move.value), "activation live set divides by k"
    if move.dim == "remat" and move.value == "full":
        return 0.6, "live activations drop to one residual per layer"
    if move.dim == "remat" and move.value in ("dots", "none"):
        return 0.75, "removes the extra remat forward pass (8NDf -> 6NDf)"
    if move.dim == "grad_compress":
        return 0.6, "gradient wire bytes x0.25 on the DP axis"
    if move.dim == "zero1":
        return 0.8, "optimizer state divides by the data-axis degree"
    if move.dim == "seq_rule" and move.value == "model":
        return 0.7, "residual live set divides by TP degree"
    if move.dim == "seq_rule" and move.value is None:
        return 0.7, "drops per-layer seq all-gather/reduce-scatter pairs"
    if move.dim == "attn_impl" and move.value == "tri":
        s = workload.get("seq_len", 4096)
        return 0.75, (f"causal block skip: attention dots go S^2 -> S^2/2 "
                      f"(S={int(s)}); larger win the more attention-bound")
    return 0.9, move.rationale


def cot_propose(point: Dict, metrics: Dict, workload: Dict, *,
                mesh_model: int = 16, k: int = 4,
                template_dims: Optional[Dict] = None) -> Tuple[List[Dict], CoTTrace]:
    """Run the 4-stage CoT symbolically. Returns (proposed plan dicts, trace)."""
    trace = CoTTrace()
    dom = metrics.get("dominant", "?")
    terms = {t: metrics.get(f"{t}_s", 0.0) for t in ("compute", "memory", "collective")}
    fits = metrics.get("fits_hbm", True)
    trace.analyze = (
        f"terms: compute={terms['compute']:.3f}s memory={terms['memory']:.3f}s "
        f"collective={terms['collective']:.3f}s -> dominant={dom}; "
        + ("HBM OK" if fits else f"HBM VIOLATION ({metrics.get('per_device_gib', 0):.1f} GiB)"))

    cands: List[Tuple[float, Move]] = []
    for mv in MOVES:
        if not mv.applies(point, metrics):
            continue
        if template_dims is not None:
            legal = template_dims.get(mv.dim, ())
            if mv.value not in legal:
                trace.enumerate.append(
                    f"{mv.dim}={mv.value}: REJECTED (outside device-aware range)")
                continue
        # when HBM is violated, memory moves take absolute priority
        targets = mv.targets if fits else tuple(set(mv.targets) | {"memory"} if "memory" in mv.targets else mv.targets)
        eff_dom = dom if fits else "memory"
        f, why = _estimate_factor(mv, point, {**metrics, "dominant": eff_dom},
                                  workload, mesh_model)
        trace.enumerate.append(f"{mv.dim}={mv.value}: {mv.rationale}")
        if f < 1.0:
            cands.append((f, mv))
            trace.estimate.append((f"{mv.dim}={mv.value}", f, why))

    cands.sort(key=lambda t: t[0])
    proposals = []
    for f, mv in cands[:k]:
        newp = {kk: vv for kk, vv in point.items() if kk != "__key__"}
        newp[mv.dim] = mv.value
        proposals.append(newp)
        trace.rank.append(f"{mv.dim}={mv.value} (predicted x{f:.2f})")

    # compound moves: single mutations often trade the dominant term against
    # HBM feasibility, so propose the known-good combinations as one step
    for combo, why in _compounds(point, metrics, workload):
        legal = True
        if template_dims is not None:
            legal = all(v in template_dims.get(kk, ()) for kk, v in combo.items())
        if legal and any(point.get(kk) != v for kk, v in combo.items()):
            newp = {kk: vv for kk, vv in point.items() if kk != "__key__"}
            newp.update(combo)
            if newp not in proposals:
                proposals.append(newp)
                trace.rank.append(f"compound {combo} — {why}")
    return proposals[: max(k, 4)], trace


def _compounds(point: Dict, metrics: Dict, workload: Dict):
    """Multi-dimension proposals (learned from negative data points: the
    best single moves frequently overflow HBM without a paired memory move)."""
    dom = metrics.get("dominant")
    fits = metrics.get("fits_hbm", True)
    out = []
    is_train = workload.get("is_train", 0.0) >= 1.0
    if is_train and (dom == "collective" or not fits):
        out.append((
            {"batch_rule": "data+model", "embed_rule": "data",
             "loss_chunk": 1024, "seq_rule": None},
            "flat DP over all chips + FSDP weight sharding + chunked CE: "
            "removes TP activation all-reduces AND keeps params/logits in HBM"))
        out.append((
            {"batch_rule": "data+model", "embed_rule": "data",
             "loss_chunk": 1024, "seq_rule": None, "remat": "dots"},
            "same + matmul-output remat policy (drops the recompute fwd)"))
    if workload.get("is_decode", 0.0) >= 1.0:
        out.append((
            {"decode_attn": "sp_shardmap", "seq_kv_rule": "model"},
            "sequence-sharded KV + flash-decoding stat combine"))
    if not fits and is_train:
        out.append((
            {"loss_chunk": 1024, "microbatches": 4, "remat": "full"},
            "emergency memory triage: chunked CE + 4 microbatches + full remat"))
        out.append((
            {"embed_rule": "data", "loss_chunk": 1024, "seq_rule": "model",
             "remat": "full", "microbatches": 2, "zero1": True, "opt_int8": True},
            "large-model memory triage: 2D weight sharding (TP x data) + "
            "chunked CE + SP residuals + ZeRO-2 sharded grad accumulation"))
        out.append((
            {"embed_rule": "data", "loss_chunk": 1024, "seq_rule": "model",
             "remat": "full", "microbatches": 4, "zero1": True,
             "attn_impl": "tri", "opt_int8": True},
            "same with 4 microbatches + causal-skip attention"))
    return out
