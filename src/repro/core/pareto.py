"""Pareto dominance, non-dominated sorting, and deterministic front order.

The multi-objective campaign layer ranks designs by an *objective vector*
(see ``repro.core.cost_db.derive_objectives``) instead of the scalar
``bound_s``. This module is the stdlib-only kernel of that layer: every
function here is a pure function of its arguments — no wall clock, no RNG,
no jax — because merged Pareto leaderboards must stay byte-identical under
any shard order, queue kill, or steal, exactly like the scalar ones.

Conventions:

* every objective is **minimized** — callers negate maximize-objectives
  before building vectors (``cost_db.MAXIMIZE_OBJECTIVES``);
* vectors within one ranking call must share one dimensionality and one
  key order (``cost_db.pareto_rows`` aligns them over the sorted union of
  objective keys, missing values -> ``+inf``);
* the deterministic total order is ``(rank, -crowding, tiebreak)`` where
  the tiebreak is ``(ts, serialized row)`` — two DBs holding the same
  rows in any order produce the same front, byte for byte.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

Vector = Sequence[float]

_INF = float("inf")


def dominates(a: Vector, b: Vector) -> bool:
    """True when ``a`` Pareto-dominates ``b``: no worse in every objective
    and strictly better in at least one (minimization). Equal vectors never
    dominate each other."""
    better = False
    for x, y in zip(a, b):
        if x > y:
            return False
        if x < y:
            better = True
    return better


def front_ranks(vectors: Sequence[Vector]) -> List[int]:
    """Non-dominated sorting: rank 0 is the Pareto front, rank 1 the front
    of what remains after peeling rank 0, and so on. O(n^2) per peel —
    campaign cells hold tens of designs, not millions. Duplicated vectors
    share a rank (neither dominates the other)."""
    n = len(vectors)
    ranks = [-1] * n
    remaining = list(range(n))
    rank = 0
    while remaining:
        front = [i for i in remaining
                 if not any(dominates(vectors[j], vectors[i])
                            for j in remaining if j != i)]
        for i in front:
            ranks[i] = rank
        remaining = [i for i in remaining if ranks[i] == -1]
        rank += 1
    return ranks


def crowding_distances(vectors: Sequence[Vector]) -> List[float]:
    """NSGA-II crowding distance within one front: boundary points get
    ``inf``, interior points the sum of normalized neighbor gaps per
    objective. Callers must pass the front in a canonical order — with
    value ties, which index lands on the boundary follows input order
    (``front_order`` sorts fronts canonically before calling this)."""
    n = len(vectors)
    if n == 0:
        return []
    dist = [0.0] * n
    for k in range(len(vectors[0])):
        order = sorted(range(n), key=lambda i: vectors[i][k])
        dist[order[0]] = dist[order[-1]] = _INF
        span = vectors[order[-1]][k] - vectors[order[0]][k]
        if span <= 0:
            continue
        for pos in range(1, n - 1):
            i = order[pos]
            if dist[i] == _INF:
                continue
            dist[i] += (vectors[order[pos + 1]][k]
                        - vectors[order[pos - 1]][k]) / span
    return dist


def front_order(vectors: Sequence[Vector], tiebreaks: Sequence,
                ) -> Tuple[List[int], List[int], List[float]]:
    """Deterministic total order over ``vectors``: ``(order, ranks,
    crowding)`` where ``order`` lists indices sorted by
    ``(rank, -crowding, tiebreak)`` — front first, within a front the most
    spread-out (boundary) points first, ties broken by the caller's
    ``tiebreaks`` (the cost DB uses ``(ts, to_json())``).

    Crowding is computed per front over a canonical ``(vector, tiebreak)``
    ordering of that front, so the result is a pure function of the *set*
    of (vector, tiebreak) pairs — insertion order never matters."""
    if len(vectors) != len(tiebreaks):
        raise ValueError(f"{len(vectors)} vectors, {len(tiebreaks)} tiebreaks")
    ranks = front_ranks(vectors)
    crowding = [0.0] * len(vectors)
    for r in sorted(set(ranks)):
        members = [i for i in range(len(vectors)) if ranks[i] == r]
        members.sort(key=lambda i: (tuple(vectors[i]), tiebreaks[i]))
        for i, d in zip(members, crowding_distances(
                [vectors[i] for i in members])):
            crowding[i] = d
    order = sorted(range(len(vectors)),
                   key=lambda i: (ranks[i], -crowding[i], tiebreaks[i]))
    return order, ranks, crowding


def hypervolume(vectors: Sequence[Vector], ref: Vector) -> float:
    """Exact hypervolume dominated by ``vectors`` w.r.t. reference point
    ``ref`` (minimization: the volume of the union of boxes
    ``[v, ref]``). Recursive dimension sweep — exponential in objective
    count, fine for the <=4-objective fronts campaigns produce. Points not
    strictly better than ``ref`` in every objective contribute nothing."""
    pts = sorted({tuple(float(x) for x in v) for v in vectors
                  if all(x < r for x, r in zip(v, ref))})
    return _hv(pts, tuple(float(r) for r in ref))


def _hv(pts: List[Tuple[float, ...]], ref: Tuple[float, ...]) -> float:
    if not pts:
        return 0.0
    if len(ref) == 1:
        return ref[0] - min(p[0] for p in pts)
    total = 0.0
    for i, p in enumerate(pts):  # pts sorted ascending by first coordinate
        hi = pts[i + 1][0] if i + 1 < len(pts) else ref[0]
        width = hi - p[0]
        if width > 0:
            total += width * _hv(sorted(q[1:] for q in pts[:i + 1]),
                                 ref[1:])
    return total


__all__ = ["dominates", "front_ranks", "crowding_distances", "front_order",
           "hypervolume"]
