"""Learned surrogate cost model, LoRA-fine-tuned on the cost DB.

Predicts (log10 roofline bound, feasibility) from plan+workload features so
the Explorer can pre-rank candidate permutations *before* paying for a
compile — the paper's answer to 'even simulation-based evaluation can remain
computationally expensive' (§5.4-i).

Base MLP pre-trained once per session; subsequent adaptation uses LoRA
(frozen base + low-rank adapters), mirroring §3.2.2 exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lora as lora_mod
from repro.core.cost_db import CostDB, featurize

HIDDEN = (64, 64)


def init_mlp(key, in_dim: int):
    keys = jax.random.split(key, len(HIDDEN) + 1)
    dims = (in_dim,) + HIDDEN
    params = {}
    for i, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(keys[i], (di, do)) * (1.0 / np.sqrt(di))
        params[f"b{i}"] = jnp.zeros((do,))
    params["w_out"] = jax.random.normal(keys[-1], (HIDDEN[-1], 2)) * 0.1
    params["b_out"] = jnp.zeros((2,))
    return params


def mlp_forward(params, x):
    h = x
    for i in range(len(HIDDEN)):
        h = jnp.tanh(h @ params[f"w{i}"] + params[f"b{i}"])
    out = h @ params["w_out"] + params["b_out"]
    return out[..., 0], jax.nn.sigmoid(out[..., 1])  # (log10 bound, p_feasible)


def _loss(params, X, y, feas):
    pred, pf = mlp_forward(params, X)
    reg = jnp.mean((pred - y) ** 2 * feas) * (feas.sum() / jnp.maximum(feas.sum(), 1))
    bce = -jnp.mean(feas * jnp.log(pf + 1e-6) + (1 - feas) * jnp.log(1 - pf + 1e-6))
    return reg + bce


@dataclass
class CostModel:
    in_dim: int
    params: Dict = field(default_factory=dict)
    lora: Optional[Dict] = None
    trained: bool = False

    @classmethod
    def create(cls, in_dim: int, seed: int = 0) -> "CostModel":
        return cls(in_dim=in_dim, params=init_mlp(jax.random.key(seed), in_dim))

    def _effective(self):
        if self.lora is None:
            return self.params
        return lora_mod.apply_lora(self.params, self.lora)

    def predict(self, feats: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        x = jnp.asarray(feats)
        if x.ndim == 1:
            x = x[None]
        b, pf = mlp_forward(self._effective(), x)
        return np.asarray(b), np.asarray(pf)

    # ------------------------------------------------------------------
    def pretrain(self, db: CostDB, steps: int = 300, lr: float = 1e-2,
                 split: Optional[str] = "train") -> float:
        """Full-parameter fit of the base model (done once).

        Default trains on the deterministic ``train`` key-hash split only —
        the held-out ``val`` rows back :meth:`validation_error`, which is
        what the SurrogateGate's calibration guard trusts. ``split=None``
        uses every row (tiny-DB benchmarks that bypass the guard).
        """
        X, y, feas = db.training_set(split=split)
        if X.shape[0] < 4:
            return float("nan")
        grad = jax.jit(jax.grad(_loss))
        lossj = jax.jit(_loss)
        Xj, yj, fj = jnp.asarray(X), jnp.asarray(y), jnp.asarray(feas)
        for _ in range(steps):
            g = grad(self.params, Xj, yj, fj)
            self.params = jax.tree.map(lambda p, gg: p - lr * gg, self.params, g)
        self.trained = True
        return float(lossj(self.params, Xj, yj, fj))

    def finetune_lora(self, db: CostDB, rank: int = 4, steps: int = 200,
                      lr: float = 5e-3, seed: int = 1,
                      split: Optional[str] = "train") -> float:
        """LoRA adaptation: base frozen, adapters trained on the grown DB
        (``train`` split by default; ``val`` stays held out for the gate)."""
        X, y, feas = db.training_set(split=split)
        if X.shape[0] < 4:
            return float("nan")
        if self.lora is None:
            self.lora, _ = lora_mod.init_lora(self.params, jax.random.key(seed), rank)

        def loss_of(lora):
            eff = lora_mod.apply_lora(self.params, lora)
            return _loss(eff, jnp.asarray(X), jnp.asarray(y), jnp.asarray(feas))

        grad = jax.jit(jax.grad(loss_of))
        for _ in range(steps):
            g = grad(self.lora)
            self.lora = jax.tree.map(lambda p, gg: p - lr * gg, self.lora, g)
        return float(loss_of(self.lora))

    def validation_error(self, db: CostDB, *, arch: Optional[str] = None,
                         shape: Optional[str] = None,
                         mesh: Optional[str] = None) -> Tuple[float, int]:
        """(RMSE in log10-bound decades, n rows) on the held-out ``val``
        split, feasible rows only (infeasible rows have no measured bound).
        ``arch``/``shape``/``mesh`` restrict to one cell's validation rows
        (the SurrogateGate's per-cell guard). Returns (nan, 0) when no
        validation rows exist — the gate treats that as uncalibrated."""
        X, y, feas = db.training_set(split="val", arch=arch, shape=shape,
                                     mesh=mesh)
        mask = feas > 0.5
        if not mask.any():
            return float("nan"), 0
        pred, _ = self.predict(X[mask])
        rmse = float(np.sqrt(np.mean((pred - y[mask]) ** 2)))
        return rmse, int(mask.sum())

    def measured_calibration(self, db: CostDB, *, arch: Optional[str] = None,
                             shape: Optional[str] = None,
                             mesh: Optional[str] = None,
                             ) -> Tuple[float, int, float]:
        """Prediction-vs-**measured** error over the tier-2 rows:
        ``(rmse, n, offset)``.

        The surrogate predicts log10 of the analytical roofline bound;
        measured wall clocks live on a different absolute scale (host
        interpret-mode backends are orders of magnitude off the modeled
        device, and even on-device there is constant launch overhead). What
        the promotion ladder needs from measurements is *relative*
        calibration — does the surrogate rank and space designs the way the
        wall clock does — so we first remove the systematic scale:
        ``offset`` is the mean of ``log10(measured_s) - predicted`` and the
        returned RMSE is the standard deviation of the residual around it,
        in decades. Returns ``(nan, 0, nan)`` with no usable measured rows
        or an untrained model."""
        if not self.trained:
            return float("nan"), 0, float("nan")
        feats, actual = [], []
        for d in db.measured_rows(arch, shape, mesh=mesh):
            ms = d.metrics.get("measured_s")
            if d.status != "ok" or not ms or ms <= 0:
                continue
            feats.append(featurize(d.point, d.metrics["workload"]))
            actual.append(np.log10(ms))
        if not feats:
            return float("nan"), 0, float("nan")
        pred, _ = self.predict(np.stack(feats))
        resid = np.asarray(actual) - pred
        offset = float(np.mean(resid))
        rmse = float(np.sqrt(np.mean((resid - offset) ** 2)))
        return rmse, len(feats), offset

    def rank_candidates(self, feats: np.ndarray) -> np.ndarray:
        """Indices sorted by predicted bound, infeasible-penalised."""
        b, pf = self.predict(feats)
        score = b + 2.0 * (1.0 - pf)  # infeasible ~ +2 decades
        return np.argsort(score)
