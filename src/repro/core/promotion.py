"""Pure decision functions of the promotion ladder (tier-2 policy).

These two functions decide *which* designs get the expensive measured tier
and *which* duplicate measurement is canonical. They live here — not in
``repro.search.ladder`` — because the jax-free supervisor surfaces
(``merge_db``'s leaderboard rebuild, the orchestrator) call them too, and
importing anything under ``repro.search`` drags jax in via the design-space
module. Both are RPR003-registered pure functions: no clock, no RNG, no
I/O — same inputs, same promotions, on every shard and every replay
(``repro.search.ladder`` re-exports them for the search-facing API).

Design-space agnostic by construction: the policy sees only DataPoints and
``__key__`` identities, never plan dims — kernel campaigns
(``launch.kernel_cell``, ``arch="kernel:<name>"`` rows) promote and dedupe
through these same two functions unchanged.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence, Set

from repro.core.cost_db import DataPoint


def plan_promotions(heads: Sequence[DataPoint], measured_keys: Set[str], *,
                    top_k: int, budget_left: Optional[int] = None,
                    ) -> List[DataPoint]:
    """Pick which leaderboard heads earn a tier-2 measurement.

    ``heads`` come best-first (``CostDB.winners``); anything already
    measured (``measured_keys`` holds point ``__key__`` values) is skipped
    — the measured cache would replay it anyway, but not promoting it at
    all keeps the BENCH counters honest. At most ``top_k`` promotions, and
    never more than ``budget_left`` when a campaign-wide budget is in
    force."""
    if top_k <= 0:
        return []
    chosen: List[DataPoint] = []
    seen: Set[str] = set()
    for d in heads:
        key = d.point.get("__key__")
        if not key or key in measured_keys or key in seen:
            continue
        seen.add(key)
        chosen.append(d)
        if len(chosen) >= top_k:
            break
    if budget_left is not None:
        chosen = chosen[:max(int(budget_left), 0)]
    return chosen


def plan_front_promotions(front: Sequence[DataPoint],
                          measured_keys: Set[str], *, top_k: int,
                          budget_left: Optional[int] = None,
                          ) -> List[DataPoint]:
    """Front-rank promotion plan for ``--objective pareto`` campaigns:
    the same dedupe/cap/budget contract as :func:`plan_promotions`, but
    ``front`` comes in deterministic Pareto order (``CostDB.front`` —
    rank, then crowding, boundary points first), so measured execution
    covers the front's extremes and spread instead of re-measuring the
    scalar head's neighborhood. Kept as its own registered entry point so
    supervisors can dispatch on objective mode without re-deriving the
    ordering contract."""
    return plan_promotions(front, measured_keys, top_k=top_k,
                           budget_left=budget_left)


def select_measured_row(rows: Iterable[DataPoint]) -> Optional[DataPoint]:
    """The canonical measured row among duplicates: earliest-wins by
    ``(ts, serialized form)`` — the same total order ``merge_db`` dedupes
    with, so a leaderboard built from any shard subset reports the same
    measurement. ``None`` when ``rows`` is empty."""
    best: Optional[DataPoint] = None
    best_key = None
    for d in rows:
        k = (d.ts, d.to_json())
        if best is None or k < best_key:
            best, best_key = d, k
    return best
