"""LLM client protocol (paper §3.2.1: Ollama-served local models).

* ``OllamaClient`` — HTTP client matching the paper's deployment (model-
  swappable, no code change). Unused in this offline container but complete.
* ``MockLLM`` — hermetic deterministic stand-in. For *propose* prompts it
  executes the same CoT scaffold embedded in the prompt (so loop mechanics,
  parsing, validation and negative-datapoint paths are exercised exactly);
  for *generate-accelerator* prompts (the paper's §4 vecmul experiment) it
  instantiates the SECDA-native kernel template from the NL spec.
"""
from __future__ import annotations

import json
import re
import urllib.request
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Protocol


class LLMClient(Protocol):
    name: str

    def complete(self, prompt: str, *, system: str = "") -> str: ...


@dataclass
class OllamaClient:
    """Minimal Ollama /api/generate client (swap models via ``model=``)."""

    model: str = "qwen2.5-coder:7b"
    host: str = "http://localhost:11434"
    name: str = "ollama"
    timeout: float = 120.0

    def complete(self, prompt: str, *, system: str = "") -> str:
        payload = json.dumps({
            "model": self.model, "prompt": prompt, "system": system,
            "stream": False,
        }).encode()
        req = urllib.request.Request(
            f"{self.host}/api/generate", data=payload,
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=self.timeout) as r:
            return json.loads(r.read())["response"]


@dataclass
class MockLLM:
    """Deterministic offline 'LLM': executes the prompt's embedded task spec.

    The prompt carries machine-readable JSON blocks (context the LLM Stack
    always provides); the mock consumes them the way a fine-tuned model is
    *trained* to — making the full SECDA-DSE loop runnable and testable
    without network or GPU inference.
    """

    name: str = "mock"
    calls: List[str] = field(default_factory=list)

    def complete(self, prompt: str, *, system: str = "") -> str:
        self.calls.append(prompt)
        task = _json_block(prompt, "TASK")
        if task is None:
            return "UNSUPPORTED PROMPT"
        if task.get("kind") == "propose_plans":
            from repro.core.cot import cot_propose

            proposals, trace = cot_propose(
                task["point"], task["metrics"], task["workload"],
                mesh_model=task.get("mesh_model", 16),
                k=task.get("k", 4),
                template_dims={k: tuple(v) for k, v in task.get("template", {}).items()}
                if task.get("template") else None,
            )
            return (trace.render() + "\n\nFINAL ANSWER:\n```json\n"
                    + json.dumps({"proposals": proposals}) + "\n```")
        if task.get("kind") == "generate_accelerator":
            return _generate_vecmul(task)
        return "UNSUPPORTED TASK"


def _json_block(text: str, tag: str) -> Optional[Dict]:
    m = re.search(rf"<{tag}>\s*(\{{.*?\}})\s*</{tag}>", text, re.S)
    if not m:
        return None
    try:
        return json.loads(m.group(1))
    except json.JSONDecodeError:
        return None


def _generate_vecmul(task: Dict) -> str:
    """NL spec -> SECDA-native TPU kernel instantiation (paper Appendix)."""
    spec = task.get("spec", "")
    L = task.get("length", 4096)
    # parse "two input vectors X and Y", "element-wise multiplication", buffers
    wants_mul = bool(re.search(r"element-?wise\s+multiplication", spec, re.I))
    wants_load = bool(re.search(r"load", spec, re.I))
    wants_store = bool(re.search(r"(store|written?\s+back)", spec, re.I))
    design = {
        "kernel": "vecmul" if wants_mul else "unknown",
        "interfaces": {"in": ["X", "Y"], "out": ["Z"]},
        "modules": {
            "load": "BlockSpec HBM->VMEM streaming" if wants_load else None,
            "compute": "VPU elementwise multiply, full block in parallel",
            "store": "VMEM->HBM write via out_specs" if wants_store else None,
        },
        "parameters": {"L": L, "block": min(L, 1024)},
        "buffers": ["X_vmem", "Y_vmem", "Z_vmem"],
    }
    reasoning = (
        "Step 1: the spec asks for two AXI-stream inputs -> two HBM operands "
        "streamed through VMEM blocks.\nStep 2: element-wise multiply maps to "
        "the 8x128 VPU, one block per grid step (the 'L parallel ops').\n"
        "Step 3: load-compute-store = BlockSpec in_specs -> kernel body -> "
        "out_specs.\n")
    return (reasoning + "\nFINAL ANSWER:\n```json\n" + json.dumps(design) + "\n```")


def parse_json_answer(text: str) -> Optional[Dict]:
    m = re.search(r"```json\s*(\{.*?\})\s*```", text, re.S)
    if not m:
        return None
    try:
        return json.loads(m.group(1))
    except json.JSONDecodeError:
        return None
