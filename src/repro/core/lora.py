"""LoRA (Hu et al., 2022) in JAX — the paper's parameter-efficient
fine-tuning mechanism (§3.2.2): the base model's weights are frozen and small
low-rank A·B adapters are trained on accumulated hardware data points.

Generic over any pytree of 2-D weight matrices; used here to adapt the
learned cost model (``cost_model.py``) as the DB grows.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def init_lora(params, key, rank: int = 8, scale: float = 0.01):
    """One (A, B) adapter per 2-D leaf; other leaves get None."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))
    adapters = []
    for leaf, k in zip(leaves, keys):
        if leaf.ndim == 2:
            fi, fo = leaf.shape
            a = scale * jax.random.normal(k, (fi, rank), jnp.float32)
            b = jnp.zeros((rank, fo), jnp.float32)
            adapters.append({"a": a, "b": b})
        else:
            adapters.append(None)
    return jax.tree_util.tree_unflatten(treedef, adapters), treedef


def apply_lora(params, lora):
    """Effective weights: W + A @ B (frozen base + adapters)."""

    def one(p, ad):
        if ad is None or p.ndim != 2:
            return p
        return p + ad["a"] @ ad["b"]

    return jax.tree.map(one, params, lora,
                        is_leaf=lambda x: x is None or isinstance(x, dict) and "a" in x)


def lora_param_count(lora) -> int:
    return sum(x.size for x in jax.tree.leaves(lora))
