"""The execution-plan design space (SECDA-DSE's 'architectural directives').

A :class:`PlanTemplate` is the SECDA-native template for one (workload x
device) pair: it enumerates the legal values of every plan dimension with
*device-aware parameter ranges* (divisibility against the mesh, VMEM budgets
for kernel blocks). Candidate generation is constrained to the template —
the paper's mechanism for avoiding unconstrained free-form designs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.device import DeviceModel, TPU_V5E
from repro.core.kernel_space import (KernelShape, default_kernel_dims,
                                     kernel_resources, legal_kernel_dims)
from repro.sharding.plan import ShardingPlan, baseline_rules

# plan dimensions the explorer may mutate, with their global value pools
DIMENSIONS: Dict[str, Tuple] = {
    "batch_rule": ("data", "data+model"),  # DP vs fully-flat FSDP-style batch
    "seq_rule": (None, "model"),  # sequence-parallel residuals
    "attn_rule": ("heads", "head_dim", "none"),
    "ffn_rule": ("model", None),
    "vocab_rule": ("model", None),
    "expert_rule": ("experts", "expert_ffn", "none"),
    "embed_rule": (None, "data"),  # ZeRO-3-style weight sharding over data
    "seq_kv_rule": ("model", None, "kv_heads"),
    "remat": ("none", "dots", "full"),
    "microbatches": (1, 2, 4, 8),
    "zero1": (True, False),
    "grad_compress": ("none", "int8", "topk"),
    "decode_attn": ("gspmd", "sp_shardmap"),
    "loss_chunk": (0, 512, 1024),
    "attn_impl": ("chunked", "tri"),  # tri = causal-skip triangular block scan
    "opt_int8": (False, True),  # blockwise int8 Adam moments
}


@dataclass(frozen=True)
class PlanPoint:
    """One candidate configuration = assignments over DIMENSIONS."""

    dims: Mapping[str, Any]

    def key(self) -> str:
        blob = json.dumps(dict(sorted(self.dims.items())), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def to_dict(self):
        return dict(self.dims)


def point_to_plan(cfg: ArchConfig, cell: ShapeCell, point: PlanPoint,
                  *, multi_pod: bool = False, name: Optional[str] = None) -> ShardingPlan:
    """Materialise a PlanPoint into a resolvable ShardingPlan."""
    d = dict(point.dims)
    rules = baseline_rules(multi_pod)
    data_axes = rules["batch"]

    if d.get("batch_rule") == "data+model":
        rules["batch"] = tuple(data_axes) + ("model",)
        rules["moe_groups"] = rules["batch"]
    rules["seq"] = d.get("seq_rule", "model")

    attn = d.get("attn_rule", "heads")
    rules["heads"] = "model" if attn in ("heads", "heads_pad") else None
    rules["kv_heads"] = "model" if attn in ("heads", "heads_pad") else None
    rules["head_dim"] = "model" if attn == "head_dim" else None
    force_uneven = ("heads", "kv_heads") if attn == "heads_pad" else ()

    rules["ffn"] = d.get("ffn_rule", "model")
    rules["vocab"] = d.get("vocab_rule", "model")

    expert = d.get("expert_rule", "experts")
    rules["experts"] = "model" if expert == "experts" else None
    rules["expert_ffn"] = "model" if expert == "expert_ffn" else None

    rules["embed"] = d.get("embed_rule")
    skv = d.get("seq_kv_rule", "model")
    rules["seq_kv"] = "model" if skv == "model" else None
    if skv == "kv_heads":
        rules["seq_kv"] = None  # kv_heads already sharded via attn rule

    return ShardingPlan(
        name=name or f"dse-{point.key()}",
        rules=rules,
        remat=d.get("remat", "full"),
        microbatches=int(d.get("microbatches", 1)),
        zero1=bool(d.get("zero1", True)),
        grad_compress=d.get("grad_compress", "none"),
        decode_attn=d.get("decode_attn", "gspmd"),
        loss_chunk=int(d.get("loss_chunk", 0)),
        attn_impl=d.get("attn_impl", "chunked"),
        opt_int8=bool(d.get("opt_int8", False)),
        force_uneven=force_uneven,
        kernel_blocks=d.get("kernel_blocks", {}),
    )


def baseline_point(cell: ShapeCell, template: Optional["PlanTemplate"] = None) -> PlanPoint:
    """The expert initial design (Megatron-style TP + SP + ZeRO-1 + remat).

    With a template, each dimension is clamped to the first legal value in
    preference order (device-aware ranges), so the seed is always valid —
    e.g. attn falls back heads -> head_dim -> none for llava's 56 heads.
    """
    prefs = {
        "batch_rule": ("data",),
        "seq_rule": ("model", None),
        "attn_rule": ("heads", "head_dim", "none"),
        "ffn_rule": ("model", None),
        "vocab_rule": ("model", None),
        "expert_rule": ("experts", "expert_ffn", "none"),
        "embed_rule": (None,),
        "seq_kv_rule": ("model", None),
        "remat": ("full",) if cell.kind == "train" else ("none",),
        "microbatches": (1,),
        "zero1": (True,),
        "grad_compress": ("none",),
        "decode_attn": ("gspmd",),
        "loss_chunk": (0,),
        "attn_impl": ("chunked",),
        "opt_int8": (False,),
    }
    if template is None:
        return PlanPoint(dims={k: v[0] for k, v in prefs.items()})
    legal = template.dims()
    dims = {}
    for k, pref in prefs.items():
        pool = legal.get(k, pref)
        dims[k] = next((p for p in pref if p in pool), pool[0])
    return PlanPoint(dims=dims)


@dataclass
class PlanTemplate:
    """Device-aware legal ranges for one (arch x shape x mesh) workload."""

    cfg: ArchConfig
    cell: ShapeCell
    mesh_shape: Mapping[str, int]
    device: DeviceModel = TPU_V5E

    def dims(self) -> Dict[str, Tuple]:
        """DIMENSIONS filtered by device/workload constraints."""
        model = self.mesh_shape.get("model", 1)
        c, cell = self.cfg, self.cell
        out: Dict[str, Tuple] = {}
        for k, vals in DIMENSIONS.items():
            vals = list(vals)
            if k == "attn_rule":
                if c.n_heads == 0:
                    vals = ["none"]
                else:
                    if c.n_heads % model != 0 and "heads" in vals:
                        vals.remove("heads")  # device-aware range narrowing
                    if c.head_dim() % model != 0 and "head_dim" in vals:
                        vals.remove("head_dim")
            if k == "expert_rule":
                if c.moe is None:
                    vals = ["none"]
                else:
                    if c.moe.n_experts % model != 0 and "experts" in vals:
                        vals.remove("experts")
                    if c.moe.d_ff_expert % model != 0 and "expert_ffn" in vals:
                        vals.remove("expert_ffn")
            if k == "ffn_rule" and c.d_ff and c.d_ff % model != 0:
                vals = [v for v in vals if v != "model"]
            if k == "vocab_rule" and c.vocab % model != 0:
                vals = [v for v in vals if v != "model"]
            if k == "microbatches":
                vals = [v for v in vals if cell.global_batch % v == 0]
                if cell.kind != "train":
                    vals = [1]
            if k == "opt_int8" and cell.kind != "train":
                vals = [False]
            if k in ("remat", "grad_compress", "zero1", "loss_chunk") and cell.kind != "train":
                vals = [vals[0]] if k != "remat" else ["none"]
            if k == "loss_chunk":
                vals = [v for v in vals if v == 0 or (cell.kind == "train" and cell.seq_len % v == 0)]
            if k == "decode_attn" and cell.kind != "decode":
                vals = ["gspmd"]
            if k == "attn_impl":
                if c.n_heads == 0 or cell.kind == "decode":
                    vals = ["chunked"]  # no self-attn pass to triangulate
            out[k] = tuple(vals)
        return out

    def validate(self, point: PlanPoint) -> Tuple[bool, str]:
        legal = self.dims()
        for k, v in point.dims.items():
            if k == "kernel_blocks":
                continue
            if k not in legal:
                return False, f"unknown dimension {k}"
            if v not in legal[k]:
                return False, f"{k}={v!r} outside device-aware range {legal[k]}"
        # cross-dimension constraint: each device must keep >=1 row per
        # microbatch, else the pipeline idles 1/k of the fleet
        mb = int(point.dims.get("microbatches", 1))
        if mb > 1:
            bdeg = self.mesh_shape.get("pod", 1) * self.mesh_shape.get("data", 1)
            if point.dims.get("batch_rule") == "data+model":
                bdeg *= self.mesh_shape.get("model", 1)
            b_local = self.cell.global_batch // min(bdeg, self.cell.global_batch)
            if b_local % mb != 0:
                return False, (f"microbatches={mb} but only {b_local} "
                               f"rows/device under batch_rule="
                               f"{point.dims.get('batch_rule')}")
        return True, ""

    def neighbors(self, point: PlanPoint) -> Iterator[PlanPoint]:
        """All single-dimension mutations (the Explorer's permutation set)."""
        legal = self.dims()
        for k, vals in legal.items():
            for v in vals:
                if v != point.dims.get(k):
                    yield PlanPoint(dims={**point.dims, k: v})

    def repair(self, point: PlanPoint) -> PlanPoint:
        """Template-specific candidate repair (the search layer delegates
        here, so strategies stay design-space-agnostic): the only plan-space
        cross-dimension clash — a microbatch count the per-device batch
        can't absorb — is fixed by dropping back to microbatches=1."""
        ok, _ = self.validate(point)
        if ok:
            return point
        return PlanPoint(dims={**point.dims, "microbatches": 1})

    def random_points(self, rng, n: int) -> List[PlanPoint]:
        legal = self.dims()
        keys = sorted(legal)
        out = []
        for _ in range(n):
            p = PlanPoint(dims={k: legal[k][rng.randrange(len(legal[k]))]
                                for k in keys})
            out.append(self.repair(p))
        return out


@dataclass(frozen=True)
class KernelPoint(PlanPoint):
    """A kernel-space candidate: assignments over one kernel's tile dims.

    Shares ``PlanPoint``'s key/serialization contract so the CostDB,
    caches, and search strategies treat both spaces identically; the
    subclass exists so call sites can tell the spaces apart.
    """


def baseline_kernel_point(shape: KernelShape,
                          template: Optional["KernelTemplate"] = None
                          ) -> KernelPoint:
    """The expert initial design for a kernel cell: the shipped defaults
    (``ops.py`` signatures), snapped into the shape's legal pools and —
    with a template — repaired to VMEM feasibility."""
    p = KernelPoint(dims=default_kernel_dims(shape))
    if template is not None:
        p = template.repair(p)
    return p


@dataclass
class KernelTemplate:
    """Device-aware legal tile ranges for one kernel workload shape.

    The kernel-space sibling of :class:`PlanTemplate`: same ``dims`` /
    ``validate`` / ``neighbors`` / ``repair`` / ``random_points`` surface
    (so every search strategy runs unchanged), but legality means Pallas
    grid divisibility and a double-buffered VMEM budget from
    ``kernels.resource_model`` instead of mesh divisibility.
    ``validate``'s reject strings are a pinned contract shared with
    ``PlanTemplate`` (tests assert them verbatim).
    """

    kshape: KernelShape
    device: DeviceModel = TPU_V5E

    def dims(self) -> Dict[str, Tuple]:
        """Legal pools, divisibility-filtered against the workload shape."""
        return legal_kernel_dims(self.kshape)

    def validate(self, point: PlanPoint) -> Tuple[bool, str]:
        """(ok, reason): unknown dims and out-of-pool values reuse
        PlanTemplate's pinned messages; the kernel-specific constraint is
        the double-buffered VMEM bound from the resource model."""
        legal = self.dims()
        for k, v in point.dims.items():
            if k not in legal:
                return False, f"unknown dimension {k}"
            if v not in legal[k]:
                return False, f"{k}={v!r} outside device-aware range {legal[k]}"
        res = kernel_resources(self.kshape, point.dims, self.device)
        if not res.feasible:
            return False, (f"VMEM {res.vmem_bytes} B double-buffered exceeds "
                           f"{self.device.vmem_bytes} B budget")
        return True, ""

    def neighbors(self, point: PlanPoint) -> Iterator[PlanPoint]:
        """Single-dimension mutations, filtered to validity (closure
        property: every yielded point passes ``validate``)."""
        legal = self.dims()
        for k, vals in legal.items():
            for v in vals:
                if v != point.dims.get(k):
                    cand = KernelPoint(dims={**point.dims, k: v})
                    ok, _ = self.validate(cand)
                    if ok:
                        yield cand

    def repair(self, point: PlanPoint) -> KernelPoint:
        """Snap a candidate into the template: unknown dims are dropped,
        out-of-pool values fall back to the shipped default, and block
        dims shrink (largest first) until the double-buffered working set
        fits VMEM."""
        legal = self.dims()
        dims = dict(default_kernel_dims(self.kshape))
        for k, v in point.dims.items():
            if k in legal and v in legal[k]:
                dims[k] = v
        while not kernel_resources(self.kshape, dims, self.device).feasible:
            shrinkable = [(k, [v for v in legal[k]
                               if isinstance(v, int) and v < dims[k]])
                          for k in dims if isinstance(dims[k], int)]
            shrinkable = [(k, vs) for k, vs in shrinkable if vs]
            if not shrinkable:
                break  # nothing left to shrink; validate() will reject
            k, vs = max(shrinkable, key=lambda kv: dims[kv[0]])
            dims[k] = max(vs)
        return KernelPoint(dims=dims)

    def random_points(self, rng, n: int) -> List[KernelPoint]:
        """n uniform samples over the legal pools, each repaired to a
        valid point (closure property shared with ``neighbors``)."""
        legal = self.dims()
        keys = sorted(legal)
        out = []
        for _ in range(n):
            p = KernelPoint(dims={k: legal[k][rng.randrange(len(legal[k]))]
                                  for k in keys})
            out.append(self.repair(p))
        return out
