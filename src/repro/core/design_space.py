"""The execution-plan design space (SECDA-DSE's 'architectural directives').

A :class:`PlanTemplate` is the SECDA-native template for one (workload x
device) pair: it enumerates the legal values of every plan dimension with
*device-aware parameter ranges* (divisibility against the mesh, VMEM budgets
for kernel blocks). Candidate generation is constrained to the template —
the paper's mechanism for avoiding unconstrained free-form designs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.device import DeviceModel, TPU_V5E
from repro.sharding.plan import ShardingPlan, baseline_rules

# plan dimensions the explorer may mutate, with their global value pools
DIMENSIONS: Dict[str, Tuple] = {
    "batch_rule": ("data", "data+model"),  # DP vs fully-flat FSDP-style batch
    "seq_rule": (None, "model"),  # sequence-parallel residuals
    "attn_rule": ("heads", "head_dim", "none"),
    "ffn_rule": ("model", None),
    "vocab_rule": ("model", None),
    "expert_rule": ("experts", "expert_ffn", "none"),
    "embed_rule": (None, "data"),  # ZeRO-3-style weight sharding over data
    "seq_kv_rule": ("model", None, "kv_heads"),
    "remat": ("none", "dots", "full"),
    "microbatches": (1, 2, 4, 8),
    "zero1": (True, False),
    "grad_compress": ("none", "int8", "topk"),
    "decode_attn": ("gspmd", "sp_shardmap"),
    "loss_chunk": (0, 512, 1024),
    "attn_impl": ("chunked", "tri"),  # tri = causal-skip triangular block scan
    "opt_int8": (False, True),  # blockwise int8 Adam moments
}


@dataclass(frozen=True)
class PlanPoint:
    """One candidate configuration = assignments over DIMENSIONS."""

    dims: Mapping[str, Any]

    def key(self) -> str:
        blob = json.dumps(dict(sorted(self.dims.items())), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def to_dict(self):
        return dict(self.dims)


def point_to_plan(cfg: ArchConfig, cell: ShapeCell, point: PlanPoint,
                  *, multi_pod: bool = False, name: Optional[str] = None) -> ShardingPlan:
    """Materialise a PlanPoint into a resolvable ShardingPlan."""
    d = dict(point.dims)
    rules = baseline_rules(multi_pod)
    data_axes = rules["batch"]

    if d.get("batch_rule") == "data+model":
        rules["batch"] = tuple(data_axes) + ("model",)
        rules["moe_groups"] = rules["batch"]
    rules["seq"] = d.get("seq_rule", "model")

    attn = d.get("attn_rule", "heads")
    rules["heads"] = "model" if attn in ("heads", "heads_pad") else None
    rules["kv_heads"] = "model" if attn in ("heads", "heads_pad") else None
    rules["head_dim"] = "model" if attn == "head_dim" else None
    force_uneven = ("heads", "kv_heads") if attn == "heads_pad" else ()

    rules["ffn"] = d.get("ffn_rule", "model")
    rules["vocab"] = d.get("vocab_rule", "model")

    expert = d.get("expert_rule", "experts")
    rules["experts"] = "model" if expert == "experts" else None
    rules["expert_ffn"] = "model" if expert == "expert_ffn" else None

    rules["embed"] = d.get("embed_rule")
    skv = d.get("seq_kv_rule", "model")
    rules["seq_kv"] = "model" if skv == "model" else None
    if skv == "kv_heads":
        rules["seq_kv"] = None  # kv_heads already sharded via attn rule

    return ShardingPlan(
        name=name or f"dse-{point.key()}",
        rules=rules,
        remat=d.get("remat", "full"),
        microbatches=int(d.get("microbatches", 1)),
        zero1=bool(d.get("zero1", True)),
        grad_compress=d.get("grad_compress", "none"),
        decode_attn=d.get("decode_attn", "gspmd"),
        loss_chunk=int(d.get("loss_chunk", 0)),
        attn_impl=d.get("attn_impl", "chunked"),
        opt_int8=bool(d.get("opt_int8", False)),
        force_uneven=force_uneven,
        kernel_blocks=d.get("kernel_blocks", {}),
    )


def baseline_point(cell: ShapeCell, template: Optional["PlanTemplate"] = None) -> PlanPoint:
    """The expert initial design (Megatron-style TP + SP + ZeRO-1 + remat).

    With a template, each dimension is clamped to the first legal value in
    preference order (device-aware ranges), so the seed is always valid —
    e.g. attn falls back heads -> head_dim -> none for llava's 56 heads.
    """
    prefs = {
        "batch_rule": ("data",),
        "seq_rule": ("model", None),
        "attn_rule": ("heads", "head_dim", "none"),
        "ffn_rule": ("model", None),
        "vocab_rule": ("model", None),
        "expert_rule": ("experts", "expert_ffn", "none"),
        "embed_rule": (None,),
        "seq_kv_rule": ("model", None),
        "remat": ("full",) if cell.kind == "train" else ("none",),
        "microbatches": (1,),
        "zero1": (True,),
        "grad_compress": ("none",),
        "decode_attn": ("gspmd",),
        "loss_chunk": (0,),
        "attn_impl": ("chunked",),
        "opt_int8": (False,),
    }
    if template is None:
        return PlanPoint(dims={k: v[0] for k, v in prefs.items()})
    legal = template.dims()
    dims = {}
    for k, pref in prefs.items():
        pool = legal.get(k, pref)
        dims[k] = next((p for p in pref if p in pool), pool[0])
    return PlanPoint(dims=dims)


@dataclass
class PlanTemplate:
    """Device-aware legal ranges for one (arch x shape x mesh) workload."""

    cfg: ArchConfig
    cell: ShapeCell
    mesh_shape: Mapping[str, int]
    device: DeviceModel = TPU_V5E

    def dims(self) -> Dict[str, Tuple]:
        """DIMENSIONS filtered by device/workload constraints."""
        model = self.mesh_shape.get("model", 1)
        c, cell = self.cfg, self.cell
        out: Dict[str, Tuple] = {}
        for k, vals in DIMENSIONS.items():
            vals = list(vals)
            if k == "attn_rule":
                if c.n_heads == 0:
                    vals = ["none"]
                else:
                    if c.n_heads % model != 0 and "heads" in vals:
                        vals.remove("heads")  # device-aware range narrowing
                    if c.head_dim() % model != 0 and "head_dim" in vals:
                        vals.remove("head_dim")
            if k == "expert_rule":
                if c.moe is None:
                    vals = ["none"]
                else:
                    if c.moe.n_experts % model != 0 and "experts" in vals:
                        vals.remove("experts")
                    if c.moe.d_ff_expert % model != 0 and "expert_ffn" in vals:
                        vals.remove("expert_ffn")
            if k == "ffn_rule" and c.d_ff and c.d_ff % model != 0:
                vals = [v for v in vals if v != "model"]
            if k == "vocab_rule" and c.vocab % model != 0:
                vals = [v for v in vals if v != "model"]
            if k == "microbatches":
                vals = [v for v in vals if cell.global_batch % v == 0]
                if cell.kind != "train":
                    vals = [1]
            if k == "opt_int8" and cell.kind != "train":
                vals = [False]
            if k in ("remat", "grad_compress", "zero1", "loss_chunk") and cell.kind != "train":
                vals = [vals[0]] if k != "remat" else ["none"]
            if k == "loss_chunk":
                vals = [v for v in vals if v == 0 or (cell.kind == "train" and cell.seq_len % v == 0)]
            if k == "decode_attn" and cell.kind != "decode":
                vals = ["gspmd"]
            if k == "attn_impl":
                if c.n_heads == 0 or cell.kind == "decode":
                    vals = ["chunked"]  # no self-attn pass to triangulate
            out[k] = tuple(vals)
        return out

    def validate(self, point: PlanPoint) -> Tuple[bool, str]:
        legal = self.dims()
        for k, v in point.dims.items():
            if k == "kernel_blocks":
                continue
            if k not in legal:
                return False, f"unknown dimension {k}"
            if v not in legal[k]:
                return False, f"{k}={v!r} outside device-aware range {legal[k]}"
        # cross-dimension constraint: each device must keep >=1 row per
        # microbatch, else the pipeline idles 1/k of the fleet
        mb = int(point.dims.get("microbatches", 1))
        if mb > 1:
            bdeg = self.mesh_shape.get("pod", 1) * self.mesh_shape.get("data", 1)
            if point.dims.get("batch_rule") == "data+model":
                bdeg *= self.mesh_shape.get("model", 1)
            b_local = self.cell.global_batch // min(bdeg, self.cell.global_batch)
            if b_local % mb != 0:
                return False, (f"microbatches={mb} but only {b_local} "
                               f"rows/device under batch_rule="
                               f"{point.dims.get('batch_rule')}")
        return True, ""

    def neighbors(self, point: PlanPoint) -> Iterator[PlanPoint]:
        """All single-dimension mutations (the Explorer's permutation set)."""
        legal = self.dims()
        for k, vals in legal.items():
            for v in vals:
                if v != point.dims.get(k):
                    yield PlanPoint(dims={**point.dims, k: v})

    def random_points(self, rng, n: int) -> List[PlanPoint]:
        legal = self.dims()
        keys = sorted(legal)
        out = []
        for _ in range(n):
            p = PlanPoint(dims={k: legal[k][rng.randrange(len(legal[k]))]
                                for k in keys})
            ok, _ = self.validate(p)
            if not ok:  # cross-dimension repair (microbatch/batch-rule clash)
                p = PlanPoint(dims={**p.dims, "microbatches": 1})
            out.append(p)
        return out
