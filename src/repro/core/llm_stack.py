"""LLM Stack (paper §3.2): RAG + CoT + client + fine-tuning orchestration.

Builds the prompt from retrieved context (prior hardware data points + code
fragments), embeds the CoT scaffold and a machine-readable TASK block, calls
the LLM client, parses/validates the response against the template, and
returns proposals. Invalid responses are surfaced as *rejected* data points.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.cost_db import CostDB, DataPoint, workload_features
from repro.core.design_space import PlanPoint, PlanTemplate
from repro.core.llm_client import LLMClient, MockLLM, parse_json_answer
from repro.core.rag import CodeIndex, DesignRetriever, summarize_datapoint

SYSTEM_PROMPT = (
    "You are a TPU execution-plan design assistant inside SECDA-DSE. "
    "Reason step by step (ANALYZE -> ENUMERATE -> ESTIMATE -> RANK) and then "
    "emit a final ```json block with {\"proposals\": [plan dicts]}. Plans must "
    "stay inside the device-aware ranges given in <TASK>."
)


@dataclass
class LLMStack:
    client: LLMClient = field(default_factory=MockLLM)
    db: Optional[CostDB] = None
    code_index: Optional[CodeIndex] = None

    def _context(self, arch: str, point: Dict, workload: Dict, k: int = 5) -> str:
        parts = []
        if self.db is not None:
            retr = DesignRetriever(self.db).retrieve(point, workload, k=k, arch=arch)
            if retr:
                parts.append("Similar prior hardware data points:")
                parts += ["  " + summarize_datapoint(d) for d in retr]
        if self.code_index is not None:
            frags = self.code_index.retrieve(
                f"{arch} sharding plan remat collective {point}", k=2)
            for tag, text in frags:
                parts.append(f"--- {tag} ---\n{text[:400]}")
        return "\n".join(parts)

    # ------------------------------------------------------------------
    def propose(self, arch: str, shape: str, cfg, cell, template: PlanTemplate,
                point: PlanPoint, metrics: Dict, *, k: int = 4,
                ) -> Tuple[List[PlanPoint], List[DataPoint], str]:
        """Refine candidates around ``point``. Returns (valid proposals,
        rejected negative data points, raw LLM transcript)."""
        wl = workload_features(cfg, cell)
        task = {
            "kind": "propose_plans",
            "point": {kk: vv for kk, vv in point.dims.items()},
            "metrics": {kk: metrics.get(kk) for kk in
                        ("compute_s", "memory_s", "collective_s", "bound_s",
                         "dominant", "fits_hbm", "per_device_gib")},
            "workload": wl,
            "template": {kk: list(vv) for kk, vv in template.dims().items()},
            "mesh_model": template.mesh_shape.get("model", 16),
            "k": k,
        }
        prompt = (
            f"Workload: {arch}/{shape}. Improve the execution plan.\n"
            + self._context(arch, dict(point.dims), wl)
            + "\n<TASK>" + json.dumps(task, default=str) + "</TASK>\n"
            "Follow the CoT scaffold and emit the final json block.")
        raw = self.client.complete(prompt, system=SYSTEM_PROMPT)
        ans = parse_json_answer(raw)
        valid: List[PlanPoint] = []
        rejected: List[DataPoint] = []
        if not ans or "proposals" not in ans:
            rejected.append(DataPoint(
                arch=arch, shape=shape, mesh="-", point=dict(point.dims),
                status="rejected", reason="unparseable LLM response",
                source=f"llm:{self.client.name}",
                metrics={"workload": wl}))
            return valid, rejected, raw
        for prop in ans["proposals"]:
            cand = PlanPoint(dims={kk: prop.get(kk, point.dims.get(kk))
                                   for kk in point.dims})
            ok, why = template.validate(cand)
            if ok:
                valid.append(cand)
            else:
                rejected.append(DataPoint(
                    arch=arch, shape=shape, mesh="-", point=dict(cand.dims),
                    status="rejected", reason=f"template violation: {why}",
                    source=f"llm:{self.client.name}",
                    metrics={"workload": wl}))
        return valid, rejected, raw

    # ------------------------------------------------------------------
    def generate_accelerator(self, spec: str, length: int = 4096) -> Tuple[Optional[Dict], str]:
        """Paper §4: NL spec -> SECDA-native kernel design (vecmul demo)."""
        task = {"kind": "generate_accelerator", "spec": spec, "length": length}
        prompt = ("Create a SECDA-native accelerator from this specification.\n"
                  "<TASK>" + json.dumps(task) + "</TASK>")
        raw = self.client.complete(prompt, system=SYSTEM_PROMPT)
        return parse_json_answer(raw), raw
