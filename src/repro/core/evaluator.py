"""Evaluation module (paper §3.2.2): simulation-first design assessment.

The 'SystemC simulation' is the XLA dry-run compile (lower+compile+HLO cost
extraction, see ``launch/dryrun.py``); the 'hardware resource limits' gate is
the per-device HBM budget + kernel VMEM resource model. Designs that fail
compile, violate budgets, or fall outside the template are returned as
*negative* data points — never silently dropped.

Evaluation throughput is the DSE bottleneck, so this module amortizes it two
ways:

* ``evaluate_batch`` fans candidate compiles out across a spawn-based
  ``concurrent.futures`` process pool — each worker sets its own
  ``XLA_FLAGS`` (forced host device count = mesh size) *before* jax is
  imported, so the parent's device configuration never constrains workers;
* an optional content-addressed :class:`~repro.core.eval_cache.DryRunCache`
  keyed by ``(arch, shape, mesh_name, point.key())`` serves repeated designs
  without recompiling — across iterations, restarts, and campaigns.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import DataPoint, derive_objectives, workload_features
from repro.core.design_space import PlanPoint, PlanTemplate, point_to_plan
from repro.core.device import TPU_V5E, DeviceModel
from repro.core.eval_cache import DryRunCache


# ---------------------------------------------------------------------------
# pool worker (top-level for pickling; runs in a fresh spawn interpreter)
# ---------------------------------------------------------------------------
def _pool_worker_init(n_devices: int) -> None:
    """Runs before any task: pin the forced host device count so the worker's
    first jax import (inside ``launch/dryrun``) sees a mesh-sized fleet."""
    flags = f"--xla_force_host_platform_device_count={n_devices}"
    os.environ["DRYRUN_XLA_FLAGS"] = flags
    os.environ["XLA_FLAGS"] = flags


_WORKER_MESH: Optional[Tuple[Tuple, Any]] = None  # (mesh key, jax Mesh)


def _pool_worker_evaluate(payload: Dict[str, Any]) -> Dict[str, Any]:
    """Dry-run one candidate in a worker process; returns the run_cell rec."""
    global _WORKER_MESH
    from repro.launch import dryrun  # first jax import happens here
    from repro.launch.mesh import make_mesh

    mesh_axes = tuple(tuple(kv) for kv in payload["mesh_axes"])  # ((axis, size), ...)
    if _WORKER_MESH is None or _WORKER_MESH[0] != mesh_axes:
        _WORKER_MESH = (mesh_axes, make_mesh([s for _, s in mesh_axes],
                                             [a for a, _ in mesh_axes]))
    mesh = _WORKER_MESH[1]
    cfg, cell = payload["cfg"], payload["cell"]
    point = PlanPoint(dims=payload["dims"])
    plan = point_to_plan(cfg, cell, point, multi_pod="pod" in dict(mesh_axes))
    from pathlib import Path

    return dryrun.run_cell(payload["arch"], payload["shape"], mesh,
                           payload["run_name"], plan=plan,
                           artifact_dir=Path(payload["artifact_dir"]),
                           cfg=cfg, cell=cell)


@dataclass
class Evaluator:
    mesh: Any  # jax Mesh (production or reduced)
    mesh_name: str
    device: DeviceModel = TPU_V5E
    artifact_dir: Optional[str] = None
    cache: Optional[DryRunCache] = None
    max_workers: int = 1  # >1 enables the process pool in evaluate_batch
    compile_count: int = 0  # dry-run compile attempts (cache misses; excludes template-skips)
    pruned_count: int = 0  # candidates the surrogate gate kept out of the pool
    # tier-2 (measured execution) state — see ``measure``
    measured_cache: Optional[DryRunCache] = None  # content-addressed, beside dryrun_cache
    measure_runs: int = 3  # timed calls per measurement (min is reported)
    measured_count: int = 0  # actual timed executions (cache misses)
    measured_replayed: int = 0  # measurements served from measured_cache

    # ------------------------------------------------------------------
    def evaluate(self, arch: str, shape: str, point: PlanPoint,
                 *, source: str = "explorer", iteration: int = -1) -> DataPoint:
        return self.evaluate_batch(arch, shape, [point], source=source,
                                   iteration=iteration, workers=1)[0]

    def evaluate_batch(self, arch: str, shape: str,
                       points: Sequence[PlanPoint], *,
                       source: str | Sequence[str] = "explorer",
                       iteration: int = -1,
                       workers: Optional[int] = None,
                       gate=None,
                       incumbent_bound: Optional[float] = None,
                       ) -> List[DataPoint]:
        """Evaluate ``points`` (order-preserving). Template rejections are
        decided inline, cached designs are served without recompiling, the
        optional :class:`~repro.search.gate.SurrogateGate` prunes candidates
        whose predicted bound is hopeless vs ``incumbent_bound`` (recorded as
        ``pruned`` data points with the prediction — never a compile), and
        the remaining dry-run compiles fan out across the process pool.

        ``source`` may be one tag for the whole batch or a per-point
        sequence (strategy provenance for the cost DB ``source`` field)."""
        srcs = ([source] * len(points) if isinstance(source, str)
                else list(source))
        if len(srcs) != len(points):
            raise ValueError(f"{len(srcs)} sources for {len(points)} points")
        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(self.mesh.shape), self.device)
        wl = workload_features(cfg, cell)

        results: List[Optional[DataPoint]] = [None] * len(points)
        pending: List[Tuple[int, PlanPoint]] = []
        for i, point in enumerate(points):
            base = self._base(arch, shape, point, srcs[i], iteration)
            ok, why = template.validate(point)
            if not ok:
                results[i] = DataPoint(**base, status="rejected", reason=why,
                                       metrics={"workload": wl})
                continue
            rec = (self.cache.get(arch, shape, self.mesh_name, point.key())
                   if self.cache is not None else None)
            if rec is not None:
                results[i] = self._rec_to_datapoint(rec, wl, base)
                continue
            pending.append((i, point))

        pending = self._gate_prune(gate, pending, wl=wl,
                                   incumbent_bound=incumbent_bound,
                                   srcs=srcs, arch=arch, shape=shape,
                                   iteration=iteration, results=results)

        n_workers = self.max_workers if workers is None else workers
        n_workers = min(n_workers, len(pending))
        if pending and n_workers > 1:
            recs = self._run_pool(arch, shape, cfg, cell, pending, n_workers)
        else:
            recs = [self._run_serial(arch, shape, cfg, cell, pt)
                    for _, pt in pending]

        for (i, point), rec in zip(pending, recs):
            if rec.get("status") not in ("skipped", "worker-failed"):
                self.compile_count += 1  # a lower+compile was actually issued
            # errors are NOT cached: run_cell catches everything, so a
            # transient crash (OOM, dead worker) must stay retryable — only
            # deterministic outcomes are worth replaying forever
            if self.cache is not None and rec.get("status") in ("ok", "skipped"):
                self.cache.put(arch, shape, self.mesh_name, point.key(), rec)
            base = self._base(arch, shape, point, srcs[i], iteration)
            results[i] = self._rec_to_datapoint(rec, wl, base)
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    def _gate_prune(self, gate, pending: List[Tuple[int, PlanPoint]], *,
                    wl: Dict[str, float], incumbent_bound: Optional[float],
                    srcs: Sequence[str], arch: str, shape: str,
                    iteration: int,
                    results: List[Optional[DataPoint]],
                    ) -> List[Tuple[int, PlanPoint]]:
        """Tier-0 surrogate gate, shared by the plan and kernel evaluation
        paths. The gate only sees candidates that would actually compile:
        cache hits are free and template rejections are already negative
        points. Pruned candidates are written into ``results`` as
        ``status="pruned"`` rows with the prediction; returns the
        still-pending subset."""
        if gate is None or not pending:
            return pending
        verdicts = gate.prune_verdicts([pt for _, pt in pending], wl,
                                       incumbent_bound)
        still: List[Tuple[int, PlanPoint]] = []
        for (i, pt), v in zip(pending, verdicts):
            if v is None:
                still.append((i, pt))
                continue
            pred, pfeas = v
            self.pruned_count += 1
            base = self._base(arch, shape, pt, srcs[i], iteration)
            # the threshold in force, annealing included — not the
            # configured maximum (audit rows must match the decision).
            # ``effective_factor`` is part of the gate protocol contract
            # (see SurrogateGate): ladder subclasses inherit it, so no
            # duck-typed fallback here.
            factor = gate.effective_factor
            results[i] = DataPoint(
                **base, status="pruned",
                reason=(f"surrogate gate: predicted {pred:.3g}s > "
                        f"{factor:g}x incumbent {incumbent_bound:.3g}s"),
                metrics={"workload": wl, "predicted_bound_s": pred,
                         "predicted_p_feasible": pfeas,
                         "gate_factor": factor})
        return still

    # ------------------------------------------------------------------
    def measure(self, arch: str, shape: str, point: PlanPoint, *,
                runs: Optional[int] = None,
                modeled_bound_s: Optional[float] = None) -> DataPoint:
        """Tier-2 promotion: execute the compiled step for ``point`` and time
        it (``repro.launch.measure.measure_cell``), returning a
        ``fidelity="measured"`` data point.

        Exactly-once semantics ride on ``measured_cache``: a hit replays the
        recorded timing (``measured_replayed``) and — because the DataPoint
        is built *solely* from the cached record, ``ts`` included — the
        replayed row serializes byte-identically to the original, so stolen
        or re-leased cells and duplicate shards all converge on one canonical
        row after merge. Only deterministic outcomes (``ok``/``skipped``)
        are cached; errors stay retryable. ``modeled_bound_s`` (the row's
        analytical bound) is recorded alongside the wall clock so
        modeled-vs-real error is auditable per row."""
        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        wl = workload_features(cfg, cell)
        rec = (self.measured_cache.get(arch, shape, self.mesh_name, point.key())
               if self.measured_cache is not None else None)
        if rec is not None:
            self.measured_replayed += 1
        else:
            from repro.launch import measure as measure_mod  # needs jax

            plan = point_to_plan(cfg, cell, point,
                                 multi_pod="pod" in self.mesh.shape)
            rec = measure_mod.measure_cell(
                arch, shape, self.mesh, self.mesh_name, plan,
                runs=runs if runs is not None else self.measure_runs,
                cfg=cfg, cell=cell)
            self.measured_count += 1
            if (self.measured_cache is not None
                    and rec.get("status") in ("ok", "skipped")):
                self.measured_cache.put(arch, shape, self.mesh_name,
                                        point.key(), rec)
        base = self._base(arch, shape, point, "ladder", -1)
        base.update(fidelity="measured", ts=rec["measured_at"])
        if rec["status"] == "skipped":
            return DataPoint(**base, status="rejected", reason=rec["reason"],
                             metrics={"workload": wl})
        if rec["status"] == "error":
            return DataPoint(**base, status="error", reason=rec["error"],
                             metrics={"workload": wl})
        metrics = {
            "workload": wl,
            "measured_s": rec["measured_s"],
            "measured_us": rec["measured_s"] * 1e6,
            "n": rec["n"],
            "warm_s": rec["warm_s"],
            "backend": rec["backend"],
        }
        if modeled_bound_s is not None:
            # deliberately NOT "bound_s": measured rows must never rank in
            # bound-keyed queries (best/winners exclude them anyway)
            metrics["bound_s_modeled"] = modeled_bound_s
        return DataPoint(**base, status="ok", metrics=metrics)

    # ------------------------------------------------------------------
    def _base(self, arch: str, shape: str, point: PlanPoint,
              source: str, iteration: int) -> Dict[str, Any]:
        return dict(arch=arch, shape=shape, mesh=self.mesh_name,
                    point={**point.to_dict(), "__key__": point.key()},
                    source=source, iteration=iteration)

    def _adir(self):
        from pathlib import Path

        from repro.launch import dryrun

        # sibling of the roofline artifact dir, NOT inside it — the artifact
        # completeness check treats artifacts/dryrun as the production set
        return (Path(self.artifact_dir) if self.artifact_dir
                else dryrun.ARTIFACT_DIR.parent / "dse")

    def _run_serial(self, arch: str, shape: str, cfg, cell,
                    point: PlanPoint) -> Dict[str, Any]:
        from repro.launch import dryrun  # deferred: needs jax initialised

        plan = point_to_plan(cfg, cell, point, multi_pod="pod" in self.mesh.shape)
        return dryrun.run_cell(arch, shape, self.mesh,
                               f"{self.mesh_name}-{point.key()}", plan=plan,
                               artifact_dir=self._adir(), cfg=cfg, cell=cell)

    def _run_pool(self, arch: str, shape: str, cfg, cell,
                  pending: Sequence[Tuple[int, PlanPoint]],
                  n_workers: int) -> List[Dict[str, Any]]:
        import multiprocessing as mp
        from concurrent.futures import ProcessPoolExecutor

        adir = str(self._adir())
        mesh_axes = [(a, int(s)) for a, s in dict(self.mesh.shape).items()]
        payloads = [dict(arch=arch, shape=shape, cfg=cfg, cell=cell,
                         dims=dict(pt.dims), mesh_axes=mesh_axes,
                         run_name=f"{self.mesh_name}-{pt.key()}",
                         artifact_dir=adir)
                    for _, pt in pending]
        recs: List[Dict[str, Any]] = []
        ctx = mp.get_context("spawn")  # fresh interpreters: XLA_FLAGS still settable
        with ProcessPoolExecutor(max_workers=n_workers, mp_context=ctx,
                                 initializer=_pool_worker_init,
                                 initargs=(int(self.mesh.size),)) as pool:
            futures = [pool.submit(_pool_worker_evaluate, p) for p in payloads]
            for fut, payload in zip(futures, payloads):
                try:
                    recs.append(fut.result())
                except Exception as e:  # noqa: BLE001 — a dead worker is a negative datapoint
                    recs.append({"status": "worker-failed",
                                 "error": f"{type(e).__name__}: {e}"})
        return recs

    def _rec_to_datapoint(self, rec: Dict[str, Any], wl: Dict[str, float],
                          base: Dict[str, Any]) -> DataPoint:
        if rec["status"] == "skipped":
            return DataPoint(**base, status="rejected", reason=rec["reason"],
                             metrics={"workload": wl})
        if rec["status"] in ("error", "worker-failed"):
            return DataPoint(**base, status="error", reason=rec["error"],
                             metrics={"workload": wl})
        r = rec["roofline"]
        fits = rec["memory"]["fits_hbm"]
        metrics = {
            "workload": wl,
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bound_s": r["bound_s"],
            "dominant": r["dominant"],
            "fits_hbm": fits,
            "per_device_gib": rec["memory"]["per_device_bytes"] / 2**30,
            "flops_per_dev": rec["hlo"]["flops"],
            "wire_bytes": rec["hlo"]["wire_bytes_total"],
            "hbm_bytes": rec["hlo"]["hbm_bytes"],
            "model_flops_per_dev": rec["model_flops_per_dev"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "mfu_at_bound": rec["model_flops_per_dev"] / (
                max(r["bound_s"], 1e-9) * self.device.peak_flops_bf16),
            "compile_s": rec["compile_s"],
        }
        # per-row objective storage for Pareto campaigns; built from the
        # metric dict either way, so cache replays stamp identically
        metrics["objectives"] = derive_objectives(metrics)
        status = "ok" if fits else "infeasible"
        reason = "" if fits else (
            f"per-device {metrics['per_device_gib']:.1f} GiB exceeds "
            f"{self.device.hbm_bytes/2**30:.0f} GiB HBM")
        return DataPoint(**base, status=status, reason=reason, metrics=metrics)


@dataclass
class KernelEvaluator(Evaluator):
    """Kernel-cell evaluation: the same multi-fidelity surface as
    :class:`Evaluator`, but the design space is a Pallas kernel's tile dims.

    The tier mapping for kernel cells:

    * dry-run tier — run the kernel **in interpret mode** on deterministic
      inputs, check it element-wise against the ``kernels.ref`` oracle
      (the correctness gate), and take ``bound_s`` from the analytic
      ``kernels.resource_model`` roofline (``est_latency_us``). A candidate
      that computes the wrong answer becomes a ``status="infeasible"`` row
      with ``max_abs_err`` recorded — never a winner, no matter how fast
      its bound claims it is.
    * measured tier — ``measure`` times real executions via
      ``launch.measure.measure_kernel_cell`` (min over ``measure_runs``
      timed calls after a warm call), re-checking correctness on the warm
      output; ``measured_cache`` replay keeps measurement exactly-once
      with byte-identical rows, exactly like plan cells.

    ``arch`` is the encoded ``kernel:<name>`` column and ``shape`` a
    ``KERNEL_SHAPES`` registry name, so the CostDB/queue/merge plumbing is
    untouched. ``mesh`` is unused (kernels are single-device); pass None.
    Evaluation is serial — interpret-mode candidates run in milliseconds,
    so a spawn pool would cost more than it saves.
    """

    interpret: Optional[bool] = True

    def evaluate_batch(self, arch: str, shape: str,
                       points: Sequence[PlanPoint], *,
                       source: str | Sequence[str] = "explorer",
                       iteration: int = -1,
                       workers: Optional[int] = None,
                       gate=None,
                       incumbent_bound: Optional[float] = None,
                       ) -> List[DataPoint]:
        """Evaluate kernel candidates (order-preserving): template
        rejections inline, cache hits replayed, surrogate-gate pruning,
        then interpret-mode execution + correctness check + analytic bound
        for the rest. ``workers`` is accepted for interface parity and
        ignored (see class docstring)."""
        from repro.core.design_space import KernelTemplate
        from repro.core.kernel_space import (KERNEL_SHAPE_BY_NAME,
                                             kernel_workload,
                                             parse_kernel_arch)

        srcs = ([source] * len(points) if isinstance(source, str)
                else list(source))
        if len(srcs) != len(points):
            raise ValueError(f"{len(srcs)} sources for {len(points)} points")
        kernel = parse_kernel_arch(arch)
        if kernel is None:
            raise ValueError(
                f"KernelEvaluator expects a 'kernel:<name>' arch, got {arch!r}")
        kshape = KERNEL_SHAPE_BY_NAME[shape]
        template = KernelTemplate(kshape, self.device)
        wl = kernel_workload(kshape)

        results: List[Optional[DataPoint]] = [None] * len(points)
        pending: List[Tuple[int, PlanPoint]] = []
        for i, point in enumerate(points):
            base = self._base(arch, shape, point, srcs[i], iteration)
            ok, why = template.validate(point)
            if not ok:
                results[i] = DataPoint(**base, status="rejected", reason=why,
                                       metrics={"workload": wl})
                continue
            rec = (self.cache.get(arch, shape, self.mesh_name, point.key())
                   if self.cache is not None else None)
            if rec is not None:
                results[i] = self._kernel_rec_to_datapoint(rec, wl, base)
                continue
            pending.append((i, point))

        pending = self._gate_prune(gate, pending, wl=wl,
                                   incumbent_bound=incumbent_bound,
                                   srcs=srcs, arch=arch, shape=shape,
                                   iteration=iteration, results=results)

        if pending:
            from repro.kernels import conformance  # deferred: needs jax

            inputs = conformance.make_inputs(kshape)
            for i, point in pending:
                rec = self._run_kernel(kshape, point, inputs, conformance)
                if rec.get("status") != "skipped":
                    self.compile_count += 1
                # errors stay retryable; correctness verdicts are
                # deterministic and replay forever
                if self.cache is not None and rec.get("status") == "ok":
                    self.cache.put(arch, shape, self.mesh_name, point.key(),
                                   rec)
                base = self._base(arch, shape, point, srcs[i], iteration)
                results[i] = self._kernel_rec_to_datapoint(rec, wl, base)
        return results  # type: ignore[return-value]

    def measure(self, arch: str, shape: str, point: PlanPoint, *,
                runs: Optional[int] = None,
                modeled_bound_s: Optional[float] = None) -> DataPoint:
        """Tier-2 promotion for a kernel cell: time real executions of the
        Pallas kernel (``launch.measure.measure_kernel_cell``) and re-run
        the correctness gate on the executed output. Same exactly-once
        ``measured_cache`` replay contract as the plan path: the DataPoint
        is built solely from the cached record (``ts`` included), so
        replayed rows serialize byte-identically."""
        from repro.core.kernel_space import (KERNEL_SHAPE_BY_NAME,
                                             kernel_workload)

        kshape = KERNEL_SHAPE_BY_NAME[shape]
        wl = kernel_workload(kshape)
        rec = (self.measured_cache.get(arch, shape, self.mesh_name,
                                       point.key())
               if self.measured_cache is not None else None)
        if rec is not None:
            self.measured_replayed += 1
        else:
            from repro.launch import measure as measure_mod  # needs jax

            rec = measure_mod.measure_kernel_cell(
                kshape, dict(point.dims), mesh_name=self.mesh_name,
                runs=runs if runs is not None else self.measure_runs,
                interpret=self.interpret)
            self.measured_count += 1
            if (self.measured_cache is not None
                    and rec.get("status") in ("ok", "incorrect")):
                self.measured_cache.put(arch, shape, self.mesh_name,
                                        point.key(), rec)
        base = self._base(arch, shape, point, "ladder", -1)
        base.update(fidelity="measured", ts=rec["measured_at"])
        if rec["status"] == "error":
            return DataPoint(**base, status="error", reason=rec["error"],
                             metrics={"workload": wl})
        metrics = {
            "workload": wl,
            "measured_s": rec["measured_s"],
            "measured_us": rec["measured_s"] * 1e6,
            "n": rec["n"],
            "warm_s": rec["warm_s"],
            "backend": rec["backend"],
            "max_abs_err": rec["max_abs_err"],
            "tol": rec["tol"],
        }
        if modeled_bound_s is not None:
            metrics["bound_s_modeled"] = modeled_bound_s
        if rec["status"] == "incorrect":
            return DataPoint(
                **base, status="infeasible",
                reason=(f"correctness gate: max|err| {rec['max_abs_err']:.3g}"
                        f" > tol {rec['tol']:.3g} vs kernels.ref"),
                metrics=metrics)
        return DataPoint(**base, status="ok", metrics=metrics)

    # ------------------------------------------------------------------
    def _run_kernel(self, kshape, point: PlanPoint, inputs,
                    conformance) -> Dict[str, Any]:
        """One dry-run-tier kernel evaluation record: correctness check +
        analytic resources. Never raises — a crashed interpret run is a
        negative datapoint."""
        import time
        import traceback

        from repro.core.kernel_space import kernel_resources

        t0 = time.perf_counter()
        try:
            check = conformance.check_candidate(
                kshape, point.dims, interpret=self.interpret, inputs=inputs)
        except Exception as e:  # noqa: BLE001 — negative datapoint
            return {"status": "error", "error": f"{type(e).__name__}: {e}",
                    "trace": traceback.format_exc()[-2000:]}
        res = kernel_resources(kshape, point.dims, self.device)
        return {"status": "ok", "check": check, "resources": res.to_dict(),
                "run_s": round(time.perf_counter() - t0, 4)}

    def _kernel_rec_to_datapoint(self, rec: Dict[str, Any],
                                 wl: Dict[str, float],
                                 base: Dict[str, Any]) -> DataPoint:
        """Map a kernel evaluation record onto the DataPoint contract: a
        failed correctness check is ``infeasible`` (with the error pinned
        in the reason), a passing one ranks on the analytic ``bound_s``."""
        if rec["status"] in ("error", "worker-failed"):
            return DataPoint(**base, status="error", reason=rec["error"],
                             metrics={"workload": wl})
        res = rec["resources"]
        check = rec["check"]
        metrics = {
            "workload": wl,
            "bound_s": res["est_latency_us"] / 1e6,
            "est_latency_us": res["est_latency_us"],
            "est_cycles_per_block": res["est_cycles_per_block"],
            "vmem_util": res["vmem_util"],
            "mxu_aligned": res["mxu_aligned"],
            "vpu_aligned": res["vpu_aligned"],
            "fits_hbm": res["feasible"],
            "max_abs_err": check["max_abs_err"],
            "tol": check["tol"],
            "correct": check["passed"],
            "run_s": rec.get("run_s"),
        }
        metrics["objectives"] = derive_objectives(metrics)
        if not check["passed"]:
            return DataPoint(
                **base, status="infeasible",
                reason=(f"correctness gate: max|err| "
                        f"{check['max_abs_err']:.3g} > tol "
                        f"{check['tol']:.3g} vs kernels.ref"),
                metrics=metrics)
        return DataPoint(**base, status="ok", metrics=metrics)
