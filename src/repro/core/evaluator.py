"""Evaluation module (paper §3.2.2): simulation-first design assessment.

The 'SystemC simulation' is the XLA dry-run compile (lower+compile+HLO cost
extraction, see ``launch/dryrun.py``); the 'hardware resource limits' gate is
the per-device HBM budget + kernel VMEM resource model. Designs that fail
compile, violate budgets, or fall outside the template are returned as
*negative* data points — never silently dropped.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import DataPoint, workload_features
from repro.core.design_space import PlanPoint, PlanTemplate, point_to_plan
from repro.core.device import TPU_V5E, DeviceModel


@dataclass
class Evaluator:
    mesh: Any  # jax Mesh (production or reduced)
    mesh_name: str
    device: DeviceModel = TPU_V5E
    artifact_dir: Optional[str] = None

    def evaluate(self, arch: str, shape: str, point: PlanPoint,
                 *, source: str = "explorer", iteration: int = -1) -> DataPoint:
        from repro.launch import dryrun  # deferred: needs jax initialised

        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(self.mesh.shape), self.device)
        ok, why = template.validate(point)
        base = dict(arch=arch, shape=shape, mesh=self.mesh_name,
                    point={**point.to_dict(), "__key__": point.key()},
                    source=source, iteration=iteration)
        if not ok:
            return DataPoint(**base, status="rejected", reason=why,
                             metrics={"workload": workload_features(cfg, cell)})

        plan = point_to_plan(cfg, cell, point, multi_pod="pod" in self.mesh.shape)
        from pathlib import Path

        adir = Path(self.artifact_dir) if self.artifact_dir else dryrun.ARTIFACT_DIR / "dse"
        rec = dryrun.run_cell(arch, shape, self.mesh, f"{self.mesh_name}-{point.key()}",
                              plan=plan, artifact_dir=adir)
        wl = workload_features(cfg, cell)
        if rec["status"] == "skipped":
            return DataPoint(**base, status="rejected", reason=rec["reason"],
                             metrics={"workload": wl})
        if rec["status"] == "error":
            return DataPoint(**base, status="error", reason=rec["error"],
                             metrics={"workload": wl})
        r = rec["roofline"]
        fits = rec["memory"]["fits_hbm"]
        metrics = {
            "workload": wl,
            "compute_s": r["compute_s"],
            "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bound_s": r["bound_s"],
            "dominant": r["dominant"],
            "fits_hbm": fits,
            "per_device_gib": rec["memory"]["per_device_bytes"] / 2**30,
            "flops_per_dev": rec["hlo"]["flops"],
            "wire_bytes": rec["hlo"]["wire_bytes_total"],
            "hbm_bytes": rec["hlo"]["hbm_bytes"],
            "model_flops_per_dev": rec["model_flops_per_dev"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "mfu_at_bound": rec["model_flops_per_dev"] / (
                max(r["bound_s"], 1e-9) * self.device.peak_flops_bf16),
            "compile_s": rec["compile_s"],
        }
        status = "ok" if fits else "infeasible"
        reason = "" if fits else (
            f"per-device {metrics['per_device_gib']:.1f} GiB exceeds "
            f"{self.device.hbm_bytes/2**30:.0f} GiB HBM")
        return DataPoint(**base, status=status, reason=reason, metrics=metrics)
