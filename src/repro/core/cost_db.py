"""Cost-model database: the append-only store of hardware data points.

Every evaluated design — successful or *negative* (infeasible / failed) — is
one JSONL record. The DB feeds (i) RAG retrieval of similar prior designs,
(ii) the learned cost model's (LoRA) fine-tuning set, (iii) EXPERIMENTS.md.
"""
from __future__ import annotations

import hashlib
import json
import math
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class DataPoint:
    """One hardware data point (paper §3.1: summarized results + config)."""

    arch: str
    shape: str
    mesh: str
    point: Dict[str, Any]  # PlanPoint dims
    status: str  # ok | infeasible | error | rejected | pruned
    metrics: Dict[str, Any] = field(default_factory=dict)
    reason: str = ""
    source: str = "explorer"  # explorer | llm | expert | search:<strategy>
    # ``search:<strategy>`` tags record which proposal engine produced the
    # design — the Ensemble's bandit credit ledger is rebuilt from them
    iteration: int = -1
    ts: float = field(default_factory=time.time)
    # evaluation tier that produced the row: ``dryrun`` = analytical
    # roofline bound from a dry-run compile (every row before the
    # promotion ladder existed), ``measured`` = wall-clock execution of
    # the compiled computation (``metrics["measured_s"]``, see
    # ``repro.launch.measure``). Measured rows are first-class datapoints
    # but are *not* surrogate training targets and never rank as a cell's
    # "best" design — the bound stays the leaderboard's ranking key, with
    # the measurement reported alongside.
    fidelity: str = "dryrun"

    def negative(self) -> bool:
        return self.status != "ok"

    def to_json(self) -> str:
        return json.dumps(self.__dict__, sort_keys=True, default=str)

    @staticmethod
    def from_json(line: str) -> "DataPoint":
        d = {k: json.loads(line).get(k) for k in
             ("arch", "shape", "mesh", "point", "status", "metrics",
              "reason", "source", "iteration", "ts", "fidelity")}
        if d.get("fidelity") is None:  # pre-ladder rows are all dry-run
            d["fidelity"] = "dryrun"
        return DataPoint(**d)


# featurization used by both RAG retrieval and the learned cost model
_CATEGORICAL = {
    "batch_rule": ("data", "data+model"),
    "seq_rule": (None, "model"),
    "attn_rule": ("heads", "head_dim", "heads_pad", "none"),
    "ffn_rule": ("model", None),
    "vocab_rule": ("model", None),
    "expert_rule": ("experts", "expert_ffn", "none"),
    "embed_rule": (None, "data"),
    "seq_kv_rule": ("model", None, "kv_heads"),
    "remat": ("none", "dots", "full"),
    "grad_compress": ("none", "int8", "topk"),
    "decode_attn": ("gspmd", "sp_shardmap"),
    "attn_impl": ("chunked", "tri"),
}
_NUMERIC = ("microbatches", "loss_chunk",
            # kernel-space tile dims (plan points simply featurize to zero
            # here, and vice versa — one surrogate serves both spaces)
            "block_q", "block_k", "block_rows", "chunk", "block")
_BOOLEAN = ("zero1", "opt_int8", "causal")


def featurize(point: Dict[str, Any], workload: Dict[str, float]) -> np.ndarray:
    """Plan dims + workload context -> dense feature vector."""
    feats: List[float] = []
    for k, vals in _CATEGORICAL.items():
        v = point.get(k)
        for cand in vals:
            feats.append(1.0 if v == cand else 0.0)
    for k in _NUMERIC:
        feats.append(math.log2(1 + float(point.get(k) or 0)))
    for k in _BOOLEAN:
        feats.append(1.0 if point.get(k) else 0.0)
    for k in ("n_params", "seq_len", "global_batch", "n_layers", "d_model",
              "vocab", "n_experts", "is_train", "is_decode"):
        feats.append(math.log10(1 + float(workload.get(k, 0.0))))
    return np.asarray(feats, np.float32)


def workload_features(cfg, cell) -> Dict[str, float]:
    return {
        "n_params": cfg.n_params(),
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "vocab": cfg.vocab,
        "n_experts": cfg.moe.n_experts if cfg.moe else 0,
        "is_train": 1.0 if cell.kind == "train" else 0.0,
        "is_decode": 1.0 if cell.kind == "decode" else 0.0,
    }


#: objective keys where larger is better — ``pareto_rows`` negates them
#: when building minimization vectors, and scalarization weights score
#: them inverted (see ``repro.search.base.weighted_objective``)
MAXIMIZE_OBJECTIVES = frozenset({"flops_util"})


def derive_objectives(metrics: Dict[str, Any]) -> Dict[str, float]:
    """Objective vector for one row's metric dict, derived from the metrics
    every evaluator already records (so pre-refactor DB rows rank in Pareto
    campaigns too). Returns ``{}`` for rows with no bound (errors,
    rejections, pruned predictions).

    Plan rows: ``bound_s`` (s), ``hbm_bytes`` (HLO HBM traffic),
    ``vmem_bytes`` (per-device working set, ``per_device_gib * 2**30``),
    ``flops_util`` (``mfu_at_bound``, maximized). Kernel rows (detected by
    ``est_latency_us``): ``bound_s``, ``vmem_util`` (resource-model VMEM
    pressure), ``flops_util`` (mean MXU/VPU alignment, maximized)."""
    bound = metrics.get("bound_s")
    if not bound:
        return {}
    obj: Dict[str, float] = {"bound_s": float(bound)}
    if "est_latency_us" in metrics:  # kernel-cell row: resource-model vector
        if metrics.get("vmem_util") is not None:
            obj["vmem_util"] = float(metrics["vmem_util"])
        mxu, vpu = metrics.get("mxu_aligned"), metrics.get("vpu_aligned")
        if mxu is not None and vpu is not None:
            obj["flops_util"] = (float(mxu) + float(vpu)) / 2.0
        return obj
    if metrics.get("hbm_bytes") is not None:
        obj["hbm_bytes"] = float(metrics["hbm_bytes"])
    if metrics.get("per_device_gib") is not None:
        obj["vmem_bytes"] = float(metrics["per_device_gib"]) * 2**30
    if metrics.get("mfu_at_bound") is not None:
        obj["flops_util"] = float(metrics["mfu_at_bound"])
    return obj


def objectives_of(dp: "DataPoint") -> Dict[str, float]:
    """The row's stored objective vector (``metrics["objectives"]``,
    stamped by the evaluators) with a derived fallback for rows written
    before objective storage existed."""
    stored = dp.metrics.get("objectives")
    if isinstance(stored, dict) and stored:
        return {k: float(v) for k, v in stored.items() if v is not None}
    return derive_objectives(dp.metrics)


def objective_value(dp: "DataPoint", key: str = "bound_s",
                    ) -> Optional[float]:
    """Shared objective extraction behind every ranking query (``best``,
    ``winners``, ``pareto_rows``): one code path for plan rows, kernel
    rows (``kernel:<name>`` archs), and measured rows. Returns None when
    the row must not rank — measured fidelity (wall clocks measure a
    different quantity than the modeled bound), failed resource gate
    (``fits_hbm``), or no such objective on the row."""
    if dp.fidelity == "measured":
        return None
    if not dp.metrics.get("fits_hbm", True):
        return None
    if key in dp.metrics:
        v = dp.metrics.get(key)
        return None if v is None else v
    v = objectives_of(dp).get(key)
    return None if v is None else v


def pareto_rows(rows: Sequence["DataPoint"],
                ) -> List[Tuple["DataPoint", int, float, Dict[str, float]]]:
    """Deterministic Pareto ordering of one cell's rows: ``(row, rank,
    crowding, objectives)`` tuples sorted by ``(rank, -crowding, ts,
    serialized row)``. A pure function of the row *set* — any insertion
    order (shard merges, queue steals, kill/heal replays) yields the same
    sequence, which is what keeps merged Pareto leaderboards
    byte-identical.

    Eligibility matches ``winners``: ``status == "ok"``, dry-run fidelity,
    ``fits_hbm``, truthy bound; one row per design key (earliest
    ``(ts, to_json())`` wins, mirroring ``merge_cost_dbs``). Vectors are
    aligned over the sorted union of objective keys — a missing objective
    is ``+inf`` (never better), maximize-objectives are negated."""
    from repro.core.pareto import front_order

    eligible = [d for d in rows
                if d.status == "ok" and objective_value(d, "bound_s")]
    by_key: Dict[str, DataPoint] = {}
    for d in sorted(eligible, key=lambda d: (d.ts or 0.0, d.to_json())):
        by_key.setdefault(d.point.get("__key__") or d.to_json(), d)
    deduped = list(by_key.values())
    if not deduped:
        return []
    objs = [objectives_of(d) for d in deduped]
    keys = sorted({k for o in objs for k in o})
    vectors = [tuple(
        float("inf") if o.get(k) is None
        else -float(o[k]) if k in MAXIMIZE_OBJECTIVES
        else float(o[k])
        for k in keys) for o in objs]
    tiebreaks = [(d.ts or 0.0, d.to_json()) for d in deduped]
    order, ranks, crowding = front_order(vectors, tiebreaks)
    return [(deduped[i], ranks[i], crowding[i], objs[i]) for i in order]


def _val_row(point_key: str) -> bool:
    """Deterministic ~20% held-out split by point-key hash: ``val`` rows are
    never used for surrogate training, so the gate's calibration error is
    measured on genuinely unseen designs (stable across processes/shards)."""
    h = hashlib.sha1(point_key.encode()).hexdigest()
    return int(h[:8], 16) % 5 == 0


class CostDB:
    def __init__(self, path: Path | str):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._cache: Optional[List[DataPoint]] = None
        # per-(arch, shape) {design key -> status} index, kept current by
        # append_many — dedupe is O(batch), not O(DB), per loop iteration,
        # and the status lets callers treat gate-pruned designs (predicted,
        # never measured) as still proposable
        self._key_index: Optional[Dict[Tuple[str, str], Dict[str, str]]] = None

    def append(self, dp: DataPoint) -> None:
        self.append_many([dp])

    def append_many(self, dps: Sequence[DataPoint]) -> None:
        """One write syscall per batch — campaign cells append whole
        evaluation batches at a time."""
        if not dps:
            return
        with self.path.open("a") as f:
            f.write("".join(dp.to_json() + "\n" for dp in dps))
        if self._cache is not None:
            self._cache.extend(dps)
        if self._key_index is not None:
            for d in dps:
                self._index_one(d)

    def _index_one(self, d: DataPoint) -> None:
        k = d.point.get("__key__")
        if not k:
            return
        cell = self._key_index.setdefault((d.arch, d.shape), {})
        # a measured status never regresses to 'pruned' (a pruned row is
        # only a surrogate prediction, not an outcome)
        if cell.get(k) is None or cell[k] == "pruned":
            cell[k] = d.status

    def all(self) -> List[DataPoint]:
        """Every row, file order, cached in memory after the first read.
        Unparseable lines (e.g. a torn tail line after a SIGKILL mid-append)
        are skipped with a warning, never raised — a campaign must always be
        able to resume over its own crash debris."""
        if self._cache is None:
            self._cache = []
            if self.path.exists():
                for line in self.path.read_text().splitlines():
                    if not line.strip():
                        continue
                    try:
                        self._cache.append(DataPoint.from_json(line))
                    except (json.JSONDecodeError, TypeError, AttributeError):
                        print(f"cost_db: skipping unreadable row in {self.path}")
        return list(self._cache)

    def query(self, arch: Optional[str] = None, shape: Optional[str] = None,
              status: Optional[str] = None,
              mesh: Optional[str] = None) -> List[DataPoint]:
        out = self.all()
        if arch:
            out = [d for d in out if d.arch == arch]
        if shape:
            out = [d for d in out if d.shape == shape]
        if status:
            out = [d for d in out if d.status == status]
        if mesh:
            out = [d for d in out if d.mesh == mesh]
        return out

    def best(self, arch: str, shape: str, key: str = "bound_s",
             mesh: Optional[str] = None) -> Optional[DataPoint]:
        # measured rows carry wall-clock timings, not the full roofline
        # metric set — ranking stays on the dry-run bound, measurement rides
        # alongside (see build_leaderboard's measured_us column). The
        # eligibility/extraction rules live in ``objective_value`` so plan,
        # kernel, and measured rows share one code path with ``winners``
        # and ``pareto_rows``.
        ok = [(objective_value(d, key), d)
              for d in self.query(arch, shape, "ok", mesh)]
        ok = [(v, d) for v, d in ok if v is not None]
        return min(ok, key=lambda vd: vd[0])[1] if ok else None

    def keys(self, arch: str, shape: str, *,
             include_pruned: bool = True) -> set:
        """Recorded design keys for one cell, from the cached index (built
        lazily from disk once, then maintained incrementally by append_many).
        ``include_pruned=False`` returns only *measured* designs — the right
        dedupe set for proposal selection, so a design the surrogate gate
        once skipped stays reachable if the gate relaxes or improves."""
        if self._key_index is None:
            self._key_index = {}
            for d in self.all():
                self._index_one(d)
        cell = self._key_index.get((arch, shape), {})
        if include_pruned:
            return set(cell)
        return {k for k, st in cell.items() if st != "pruned"}

    def seen(self, arch: str, shape: str, point_key: str) -> bool:
        return point_key in self.keys(arch, shape)

    def cells(self) -> List[Tuple[str, str, str]]:
        """Distinct (arch, shape, mesh) cells present — the campaign engine's
        view of which workloads already hold data."""
        return sorted({(d.arch, d.shape, d.mesh) for d in self.all()})

    def winners(self, arch: str, shape: str, k: int = 3,
                mesh: Optional[str] = None) -> List[DataPoint]:
        """The cell's ``k`` fastest *feasible* designs, one row per design key.

        Sorted by measured ``bound_s`` ascending (seconds), ties broken by
        earliest ``ts`` then append order — deterministic for a fixed DB
        file. Rows without a ``bound_s`` metric or failing ``fits_hbm`` are
        excluded; an empty list means the cell has no feasible design yet.
        This is the donor query behind cross-workload transfer seeding
        (:class:`repro.search.transfer.TransferSeeded`)."""
        ok = [(objective_value(d), d)
              for d in self.query(arch, shape, "ok", mesh)]
        ok = [(v, d) for v, d in ok if v]  # truthy: a zero bound never ranks
        ok.sort(key=lambda vd: (vd[0], vd[1].ts or 0.0))
        seen, out = set(), []
        for _, d in ok:
            key = d.point.get("__key__")
            if key is not None and key in seen:
                continue
            seen.add(key)
            out.append(d)
            if len(out) == k:
                break
        return out

    def pareto(self, arch: str, shape: str, mesh: Optional[str] = None,
               ) -> List[Tuple[DataPoint, int, float, Dict[str, float]]]:
        """The cell's rows in deterministic Pareto order: ``(row, rank,
        crowding, objectives)`` per unique feasible design, rank 0 = the
        non-dominated front (see :func:`pareto_rows` for the ordering and
        byte-stability contract)."""
        return pareto_rows(self.query(arch, shape, "ok", mesh))

    def front(self, arch: str, shape: str, k: Optional[int] = 3,
              mesh: Optional[str] = None) -> List[DataPoint]:
        """The cell's ``k`` leading designs in Pareto front order — the
        multi-objective analog of :meth:`winners`, and the promotion
        ladder's head query under ``--objective pareto``: rank-0 boundary
        points first, so measured execution covers the front's extremes
        before its interior. ``k=None`` returns every ranked design."""
        heads = [d for d, _, _, _ in self.pareto(arch, shape, mesh)]
        return heads if k is None else heads[:k]

    def measured_rows(self, arch: Optional[str] = None,
                      shape: Optional[str] = None,
                      mesh: Optional[str] = None) -> List[DataPoint]:
        """Every tier-2 (``fidelity == "measured"``) row, optionally
        restricted to one cell — the promotion planner's dedupe source and
        the leaderboard's ``measured_us`` lookup."""
        return [d for d in self.query(arch, shape, mesh=mesh)
                if d.fidelity == "measured"]

    def iteration_batches(self, arch: str, shape: str,
                          mesh: Optional[str] = None,
                          ) -> List[Tuple[int, List[DataPoint]]]:
        """The cell's rows grouped by loop iteration, ascending, preserving
        append order within each group — the provenance replay stream
        :meth:`repro.search.ensemble.Ensemble.rebuild_credit` consumes to
        reconstruct bandit credit from the ``source`` field alone. Rows with
        no recorded iteration sort first under index ``-1``."""
        groups: Dict[int, List[DataPoint]] = {}
        for d in self.query(arch, shape, mesh=mesh):
            it = int(d.iteration) if d.iteration is not None else -1
            groups.setdefault(it, []).append(d)
        return sorted(groups.items())

    def count(self, arch: Optional[str] = None, shape: Optional[str] = None,
              status: Optional[str] = None, mesh: Optional[str] = None) -> int:
        return len(self.query(arch, shape, status, mesh))

    def training_set(self, split: Optional[str] = None, *,
                     arch: Optional[str] = None, shape: Optional[str] = None,
                     mesh: Optional[str] = None,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(features, targets [log10 bound_s], feasible mask) for the surrogate.

        ``split``: None = every usable row (legacy behavior); ``"train"`` /
        ``"val"`` = the deterministic ~80/20 key-hash partition (``val`` rows
        back the SurrogateGate's calibration guard, see ``_val_row``).
        ``arch``/``shape``/``mesh`` restrict to one cell's rows — the
        gate's per-cell calibration measures validation error on exactly
        the workload it is about to prune for. ``pruned`` rows are always
        skipped: they carry only a surrogate *prediction*, never a measured
        outcome, and training on them would let the gate teach the model
        its own mistakes.
        """
        X, y, feas = [], [], []
        for d in self.all():
            if ((arch is not None and d.arch != arch)
                    or (shape is not None and d.shape != shape)
                    or (mesh is not None and d.mesh != mesh)):
                continue
            wl = d.metrics.get("workload")
            if not wl or d.status == "pruned":
                continue
            # measured rows are wall-clock outcomes of a *different*
            # quantity than the analytical bound the surrogate models —
            # they calibrate the model (measured_calibration), never
            # train it
            if d.fidelity == "measured":
                continue
            if split is not None:
                key = d.point.get("__key__") or json.dumps(
                    {k: v for k, v in sorted(d.point.items())}, default=str)
                if _val_row(key) != (split == "val"):
                    continue
            X.append(featurize(d.point, wl))
            b = d.metrics.get("bound_s")
            ok = d.status == "ok" and d.metrics.get("fits_hbm", False)
            y.append(math.log10(max(b, 1e-6)) if (b and ok) else 3.0)
            feas.append(1.0 if ok else 0.0)
        if not X:
            z = np.zeros((0,), np.float32)
            return z.reshape(0, 1), z, z
        return np.stack(X), np.asarray(y, np.float32), np.asarray(feas, np.float32)
