"""Cost-model database: the append-only store of hardware data points.

Every evaluated design — successful or *negative* (infeasible / failed) — is
one JSONL record. The DB feeds (i) RAG retrieval of similar prior designs,
(ii) the learned cost model's (LoRA) fine-tuning set, (iii) EXPERIMENTS.md.
"""
from __future__ import annotations

import hashlib
import json
import math
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class DataPoint:
    """One hardware data point (paper §3.1: summarized results + config)."""

    arch: str
    shape: str
    mesh: str
    point: Dict[str, Any]  # PlanPoint dims
    status: str  # ok | infeasible | error | rejected | pruned
    metrics: Dict[str, Any] = field(default_factory=dict)
    reason: str = ""
    source: str = "explorer"  # explorer | llm | expert | search:<strategy>
    # ``search:<strategy>`` tags record which proposal engine produced the
    # design — the Ensemble's bandit credit ledger is rebuilt from them
    iteration: int = -1
    ts: float = field(default_factory=time.time)
    # evaluation tier that produced the row: ``dryrun`` = analytical
    # roofline bound from a dry-run compile (every row before the
    # promotion ladder existed), ``measured`` = wall-clock execution of
    # the compiled computation (``metrics["measured_s"]``, see
    # ``repro.launch.measure``). Measured rows are first-class datapoints
    # but are *not* surrogate training targets and never rank as a cell's
    # "best" design — the bound stays the leaderboard's ranking key, with
    # the measurement reported alongside.
    fidelity: str = "dryrun"

    def negative(self) -> bool:
        return self.status != "ok"

    def to_json(self) -> str:
        return json.dumps(self.__dict__, sort_keys=True, default=str)

    @staticmethod
    def from_json(line: str) -> "DataPoint":
        d = {k: json.loads(line).get(k) for k in
             ("arch", "shape", "mesh", "point", "status", "metrics",
              "reason", "source", "iteration", "ts", "fidelity")}
        if d.get("fidelity") is None:  # pre-ladder rows are all dry-run
            d["fidelity"] = "dryrun"
        return DataPoint(**d)


# featurization used by both RAG retrieval and the learned cost model
_CATEGORICAL = {
    "batch_rule": ("data", "data+model"),
    "seq_rule": (None, "model"),
    "attn_rule": ("heads", "head_dim", "heads_pad", "none"),
    "ffn_rule": ("model", None),
    "vocab_rule": ("model", None),
    "expert_rule": ("experts", "expert_ffn", "none"),
    "embed_rule": (None, "data"),
    "seq_kv_rule": ("model", None, "kv_heads"),
    "remat": ("none", "dots", "full"),
    "grad_compress": ("none", "int8", "topk"),
    "decode_attn": ("gspmd", "sp_shardmap"),
    "attn_impl": ("chunked", "tri"),
}
_NUMERIC = ("microbatches", "loss_chunk",
            # kernel-space tile dims (plan points simply featurize to zero
            # here, and vice versa — one surrogate serves both spaces)
            "block_q", "block_k", "block_rows", "chunk", "block")
_BOOLEAN = ("zero1", "opt_int8", "causal")


def featurize(point: Dict[str, Any], workload: Dict[str, float]) -> np.ndarray:
    """Plan dims + workload context -> dense feature vector."""
    feats: List[float] = []
    for k, vals in _CATEGORICAL.items():
        v = point.get(k)
        for cand in vals:
            feats.append(1.0 if v == cand else 0.0)
    for k in _NUMERIC:
        feats.append(math.log2(1 + float(point.get(k) or 0)))
    for k in _BOOLEAN:
        feats.append(1.0 if point.get(k) else 0.0)
    for k in ("n_params", "seq_len", "global_batch", "n_layers", "d_model",
              "vocab", "n_experts", "is_train", "is_decode"):
        feats.append(math.log10(1 + float(workload.get(k, 0.0))))
    return np.asarray(feats, np.float32)


def workload_features(cfg, cell) -> Dict[str, float]:
    return {
        "n_params": cfg.n_params(),
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "n_layers": cfg.n_layers,
        "d_model": cfg.d_model,
        "vocab": cfg.vocab,
        "n_experts": cfg.moe.n_experts if cfg.moe else 0,
        "is_train": 1.0 if cell.kind == "train" else 0.0,
        "is_decode": 1.0 if cell.kind == "decode" else 0.0,
    }


def _val_row(point_key: str) -> bool:
    """Deterministic ~20% held-out split by point-key hash: ``val`` rows are
    never used for surrogate training, so the gate's calibration error is
    measured on genuinely unseen designs (stable across processes/shards)."""
    h = hashlib.sha1(point_key.encode()).hexdigest()
    return int(h[:8], 16) % 5 == 0


class CostDB:
    def __init__(self, path: Path | str):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._cache: Optional[List[DataPoint]] = None
        # per-(arch, shape) {design key -> status} index, kept current by
        # append_many — dedupe is O(batch), not O(DB), per loop iteration,
        # and the status lets callers treat gate-pruned designs (predicted,
        # never measured) as still proposable
        self._key_index: Optional[Dict[Tuple[str, str], Dict[str, str]]] = None

    def append(self, dp: DataPoint) -> None:
        self.append_many([dp])

    def append_many(self, dps: Sequence[DataPoint]) -> None:
        """One write syscall per batch — campaign cells append whole
        evaluation batches at a time."""
        if not dps:
            return
        with self.path.open("a") as f:
            f.write("".join(dp.to_json() + "\n" for dp in dps))
        if self._cache is not None:
            self._cache.extend(dps)
        if self._key_index is not None:
            for d in dps:
                self._index_one(d)

    def _index_one(self, d: DataPoint) -> None:
        k = d.point.get("__key__")
        if not k:
            return
        cell = self._key_index.setdefault((d.arch, d.shape), {})
        # a measured status never regresses to 'pruned' (a pruned row is
        # only a surrogate prediction, not an outcome)
        if cell.get(k) is None or cell[k] == "pruned":
            cell[k] = d.status

    def all(self) -> List[DataPoint]:
        """Every row, file order, cached in memory after the first read.
        Unparseable lines (e.g. a torn tail line after a SIGKILL mid-append)
        are skipped with a warning, never raised — a campaign must always be
        able to resume over its own crash debris."""
        if self._cache is None:
            self._cache = []
            if self.path.exists():
                for line in self.path.read_text().splitlines():
                    if not line.strip():
                        continue
                    try:
                        self._cache.append(DataPoint.from_json(line))
                    except (json.JSONDecodeError, TypeError, AttributeError):
                        print(f"cost_db: skipping unreadable row in {self.path}")
        return list(self._cache)

    def query(self, arch: Optional[str] = None, shape: Optional[str] = None,
              status: Optional[str] = None,
              mesh: Optional[str] = None) -> List[DataPoint]:
        out = self.all()
        if arch:
            out = [d for d in out if d.arch == arch]
        if shape:
            out = [d for d in out if d.shape == shape]
        if status:
            out = [d for d in out if d.status == status]
        if mesh:
            out = [d for d in out if d.mesh == mesh]
        return out

    def best(self, arch: str, shape: str, key: str = "bound_s",
             mesh: Optional[str] = None) -> Optional[DataPoint]:
        # measured rows carry wall-clock timings, not the full roofline
        # metric set — ranking stays on the dry-run bound, measurement rides
        # alongside (see build_leaderboard's measured_us column)
        ok = [d for d in self.query(arch, shape, "ok", mesh)
              if d.fidelity != "measured"
              and d.metrics.get(key) is not None
              and d.metrics.get("fits_hbm", True)]
        return min(ok, key=lambda d: d.metrics[key]) if ok else None

    def keys(self, arch: str, shape: str, *,
             include_pruned: bool = True) -> set:
        """Recorded design keys for one cell, from the cached index (built
        lazily from disk once, then maintained incrementally by append_many).
        ``include_pruned=False`` returns only *measured* designs — the right
        dedupe set for proposal selection, so a design the surrogate gate
        once skipped stays reachable if the gate relaxes or improves."""
        if self._key_index is None:
            self._key_index = {}
            for d in self.all():
                self._index_one(d)
        cell = self._key_index.get((arch, shape), {})
        if include_pruned:
            return set(cell)
        return {k for k, st in cell.items() if st != "pruned"}

    def seen(self, arch: str, shape: str, point_key: str) -> bool:
        return point_key in self.keys(arch, shape)

    def cells(self) -> List[Tuple[str, str, str]]:
        """Distinct (arch, shape, mesh) cells present — the campaign engine's
        view of which workloads already hold data."""
        return sorted({(d.arch, d.shape, d.mesh) for d in self.all()})

    def winners(self, arch: str, shape: str, k: int = 3,
                mesh: Optional[str] = None) -> List[DataPoint]:
        """The cell's ``k`` fastest *feasible* designs, one row per design key.

        Sorted by measured ``bound_s`` ascending (seconds), ties broken by
        earliest ``ts`` then append order — deterministic for a fixed DB
        file. Rows without a ``bound_s`` metric or failing ``fits_hbm`` are
        excluded; an empty list means the cell has no feasible design yet.
        This is the donor query behind cross-workload transfer seeding
        (:class:`repro.search.transfer.TransferSeeded`)."""
        ok = [d for d in self.query(arch, shape, "ok", mesh)
              if d.fidelity != "measured"
              and d.metrics.get("bound_s") and d.metrics.get("fits_hbm", True)]
        ok.sort(key=lambda d: (d.metrics["bound_s"], d.ts or 0.0))
        seen, out = set(), []
        for d in ok:
            key = d.point.get("__key__")
            if key is not None and key in seen:
                continue
            seen.add(key)
            out.append(d)
            if len(out) == k:
                break
        return out

    def measured_rows(self, arch: Optional[str] = None,
                      shape: Optional[str] = None,
                      mesh: Optional[str] = None) -> List[DataPoint]:
        """Every tier-2 (``fidelity == "measured"``) row, optionally
        restricted to one cell — the promotion planner's dedupe source and
        the leaderboard's ``measured_us`` lookup."""
        return [d for d in self.query(arch, shape, mesh=mesh)
                if d.fidelity == "measured"]

    def iteration_batches(self, arch: str, shape: str,
                          mesh: Optional[str] = None,
                          ) -> List[Tuple[int, List[DataPoint]]]:
        """The cell's rows grouped by loop iteration, ascending, preserving
        append order within each group — the provenance replay stream
        :meth:`repro.search.ensemble.Ensemble.rebuild_credit` consumes to
        reconstruct bandit credit from the ``source`` field alone. Rows with
        no recorded iteration sort first under index ``-1``."""
        groups: Dict[int, List[DataPoint]] = {}
        for d in self.query(arch, shape, mesh=mesh):
            it = int(d.iteration) if d.iteration is not None else -1
            groups.setdefault(it, []).append(d)
        return sorted(groups.items())

    def count(self, arch: Optional[str] = None, shape: Optional[str] = None,
              status: Optional[str] = None, mesh: Optional[str] = None) -> int:
        return len(self.query(arch, shape, status, mesh))

    def training_set(self, split: Optional[str] = None, *,
                     arch: Optional[str] = None, shape: Optional[str] = None,
                     mesh: Optional[str] = None,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(features, targets [log10 bound_s], feasible mask) for the surrogate.

        ``split``: None = every usable row (legacy behavior); ``"train"`` /
        ``"val"`` = the deterministic ~80/20 key-hash partition (``val`` rows
        back the SurrogateGate's calibration guard, see ``_val_row``).
        ``arch``/``shape``/``mesh`` restrict to one cell's rows — the
        gate's per-cell calibration measures validation error on exactly
        the workload it is about to prune for. ``pruned`` rows are always
        skipped: they carry only a surrogate *prediction*, never a measured
        outcome, and training on them would let the gate teach the model
        its own mistakes.
        """
        X, y, feas = [], [], []
        for d in self.all():
            if ((arch is not None and d.arch != arch)
                    or (shape is not None and d.shape != shape)
                    or (mesh is not None and d.mesh != mesh)):
                continue
            wl = d.metrics.get("workload")
            if not wl or d.status == "pruned":
                continue
            # measured rows are wall-clock outcomes of a *different*
            # quantity than the analytical bound the surrogate models —
            # they calibrate the model (measured_calibration), never
            # train it
            if d.fidelity == "measured":
                continue
            if split is not None:
                key = d.point.get("__key__") or json.dumps(
                    {k: v for k, v in sorted(d.point.items())}, default=str)
                if _val_row(key) != (split == "val"):
                    continue
            X.append(featurize(d.point, wl))
            b = d.metrics.get("bound_s")
            ok = d.status == "ok" and d.metrics.get("fits_hbm", False)
            y.append(math.log10(max(b, 1e-6)) if (b and ok) else 3.0)
            feas.append(1.0 if ok else 0.0)
        if not X:
            z = np.zeros((0,), np.float32)
            return z.reshape(0, 1), z, z
        return np.stack(X), np.asarray(y, np.float32), np.asarray(feas, np.float32)
