"""Exact cost extraction from compiled HLO text.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE (XLA HloCostAnalysis
does not multiply by trip count), which under-reports FLOPs/bytes by ~L x for
scan-over-layers programs. This module re-derives exact per-device costs from
``compiled.as_text()``:

* builds the computation call graph (fusion/call/while edges),
* multiplies every computation's cost by the product of enclosing
  ``known_trip_count`` s,
* counts matmul FLOPs exactly from ``dot`` shapes (2 * prod(result) *
  prod(contracting)),
* sums collective payloads per kind with ring-model wire bytes using the
  parsed ``replica_groups`` size.

This is the SECDA-DSE "SystemC simulator" equivalent: a cheap, pre-hardware,
per-design cost evaluation read from the toolchain artifact.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_WHILE = re.compile(r"\bwhile\(")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_REPL_IOTA = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
_REPL_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"\(([^)]*)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-model wire bytes per device, as a multiple of the RESULT buffer size
def _wire_factor(kind: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return (g - 1) / g
    if kind == "reduce-scatter":
        return float(g - 1)  # result is 1/g of the reduced input
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-to-all":
        return (g - 1) / g
    if kind == "collective-permute":
        return 1.0
    return 1.0


@dataclass
class CompCost:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    hbm_bytes: float = 0.0  # approx HBM traffic of this computation's own ops
    collect_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    wire_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    # (callee, multiplier, kind) edges; kind in {"while", "fusion", "call"}
    edges: List[Tuple[str, float, str]] = field(default_factory=list)


# ops whose top-level operand/result traffic is NOT real HBM movement
_NO_TRAFFIC_OPS = (
    "tuple(", "get-tuple-element(", "parameter(", "bitcast(", "while(",
    "conditional(", "constant(", "after-all(", "partition-id(", "replica-id(",
    "copy-start(", "copy-done(",
)


def _fusion_param_charges(lines: List[str]) -> Tuple[List[float], float, bool]:
    """Per-parameter HBM charge for a fusion computation.

    A parameter consumed ONLY via dynamic-slice is charged the slice bytes
    (times #slices), not the full buffer — this is what makes scan-over-layers
    param reads count as one layer per iteration, not the whole stack.
    Returns (param charges in header order, extra slice reads, root_is_dus).
    """
    m = _COMP_HEADER.match(lines[0])
    params: List[Tuple[str, str]] = []
    if m:
        for part in m.group(3).split(","):
            if ":" in part:
                nm, ty = part.split(":", 1)
                params.append((nm.strip().lstrip("%"), ty.strip()))
    uses: Dict[str, List[str]] = {nm: [] for nm, _ in params}
    slice_bytes: Dict[str, float] = {nm: 0.0 for nm, _ in params}
    root_is_dus = False
    dus_update_bytes = 0.0
    shapes: Dict[str, str] = {nm: ty for nm, ty in params}
    for line in lines[1:]:
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, rhs = om.group(2), om.group(3)
        shapes[name] = _result_type(rhs)
        opm = _OPERANDS.search(rhs)
        ops = []
        if opm:
            ops = [o.strip().lstrip("%") for o in opm.group(1).split(",") if o.strip()]
        is_dyn_slice = "dynamic-slice(" in rhs and "dynamic-update-slice(" not in rhs
        for o in ops:
            if o in uses:
                uses[o].append("dynamic-slice" if is_dyn_slice else "other")
                if is_dyn_slice and o == ops[0]:
                    sm = re.search(r"dynamic_slice_sizes=\{([0-9,]*)\}", rhs)
                    if sm:
                        n = 1
                        for d in sm.group(1).split(","):
                            if d:
                                n *= int(d)
                        dt = _SHAPE.findall(shapes.get(o, ""))
                        bpe = _DTYPE_BYTES.get(dt[0][0], 4) if dt else 4
                        slice_bytes[o] += n * bpe
        if om.group(1):  # ROOT
            if "dynamic-update-slice(" in rhs:
                root_is_dus = True
                if len(ops) >= 2:
                    _, dus_update_bytes = _shape_elems_bytes(shapes.get(ops[1], ""))
    charges = []
    for nm, ty in params:
        kinds = set(uses.get(nm, []))
        if kinds and kinds <= {"dynamic-slice"}:
            charges.append(slice_bytes[nm])
        else:
            _, full = _shape_elems_bytes(ty)
            charges.append(full)
    return charges, dus_update_bytes, root_is_dus


def _shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    elems = bytes_ = 0.0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


def _parse_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = [line]
            if m.group(1):
                entry = cur
            continue
        if cur is not None:
            comps[cur].append(line)
            if line.strip() == "}":
                cur = None
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def _result_type(rhs: str) -> str:
    """The result type is everything before the op name token."""
    # e.g. "f32[64,128]{1,0} dot(%a, %b), ..." or "(f32[..], s32[]) tuple(...)"
    m = re.match(r"\s*(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+\w", rhs)
    return m.group(1) if m else ""


def _group_size(line: str, default: int) -> int:
    m = _REPL_IOTA.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",") if x]
        return dims[-1] if dims else default
    m = _REPL_LIST.search(line)
    if m:
        first = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(first), 1)
    return default


def _operand_names(rhs: str) -> List[str]:
    opm = _OPERANDS.search(rhs)
    if not opm:
        return []
    inner = opm.group(1)
    # older XLA prints typed operands — "dot(f32[64,64]{1,0} %a, ...)" — where
    # a naive comma split breaks inside the shape brackets; the %-sigil tokens
    # are the operand names in that dialect
    sigils = re.findall(r"%([\w\.\-]+)", inner)
    if sigils:
        return sigils
    return [o.strip().lstrip("%") for o in inner.split(",") if o.strip()]


def _comp_cost(lines: List[str], n_devices: int,
               comps: Dict[str, List[str]]) -> CompCost:
    cost = CompCost()
    shapes: Dict[str, str] = {}
    m = _COMP_HEADER.match(lines[0])
    if m:
        for part in m.group(3).split(","):
            if ":" in part:
                nm, ty = part.split(":", 1)
                shapes[nm.strip().lstrip("%")] = ty.strip()

    def operand_bytes(ops: List[str]) -> float:
        return sum(_shape_elems_bytes(shapes.get(o, ""))[1] for o in ops)

    for line in lines[1:]:
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, rhs = om.group(2), om.group(3)
        rtype = _result_type(rhs)
        shapes[name] = rtype
        _, rbytes = _shape_elems_bytes(rtype)

        if " dot(" in rhs or rhs.lstrip().startswith("dot("):
            relems, _ = _shape_elems_bytes(rtype)
            cm = _CONTRACT.search(rhs)
            contract_elems = 1.0
            if cm is not None:
                ops = _operand_names(rhs)
                lhs_ty = shapes.get(ops[0], "") if ops else ""
                sm = _SHAPE.findall(lhs_ty)
                if sm:
                    dims = [int(x) for x in sm[0][1].split(",") if x]
                    for ci in (int(x) for x in cm.group(1).split(",") if x):
                        if ci < len(dims):
                            contract_elems *= dims[ci]
            cost.dot_flops += 2.0 * relems * contract_elems
            cost.hbm_bytes += operand_bytes(_operand_names(rhs)) + rbytes
            continue

        if " convolution(" in rhs:
            relems, _ = _shape_elems_bytes(rtype)
            cost.conv_flops += 2.0 * relems  # lower bound; convs unused here
            cost.hbm_bytes += operand_bytes(_operand_names(rhs)) + rbytes
            continue

        hit = None
        for kind in COLLECTIVES:
            if f" {kind}(" in rhs or rhs.lstrip().startswith(f"{kind}(") \
               or f"{kind}-start(" in rhs:
                hit = kind
                break
        if hit and "-done(" not in rhs:
            g = _group_size(line, n_devices)
            cost.collect_bytes[hit] += rbytes
            cost.wire_bytes[hit] += rbytes * _wire_factor(hit, g)
            cost.hbm_bytes += rbytes
            continue

        if _WHILE.search(rhs):
            body = _BODY.search(rhs)
            trip = _TRIP.search(line)
            n = float(trip.group(1)) if trip else 1.0
            if body:
                cost.edges.append((body.group(1), n, "while"))
            cond = _COND.search(rhs)
            if cond:
                cost.edges.append((cond.group(1), n + 1.0, "while"))
            continue

        cm = _CALLS.search(rhs)
        if cm:
            callee = cm.group(1)
            is_fusion = " fusion(" in rhs or rhs.lstrip().startswith("fusion(")
            cost.edges.append((callee, 1.0, "fusion" if is_fusion else "call"))
            if is_fusion and callee in comps:
                charges, dus_bytes, root_is_dus = _fusion_param_charges(comps[callee])
                ops = _operand_names(rhs)
                if len(charges) == len(ops):
                    inb = sum(charges)
                else:
                    inb = operand_bytes(ops)
                outb = dus_bytes if root_is_dus else rbytes
                cost.hbm_bytes += inb + outb
            else:
                cost.hbm_bytes += operand_bytes(_operand_names(rhs)) + rbytes
            continue

        if any(t in rhs for t in _NO_TRAFFIC_OPS):
            continue
        # top-level dynamic-(update-)slice: true traffic is slice-sized —
        # the big buffer is aliased in place, not re-read
        if "dynamic-update-slice(" in rhs:
            ops = _operand_names(rhs)
            ub = _shape_elems_bytes(shapes.get(ops[1], ""))[1] if len(ops) > 1 else rbytes
            cost.hbm_bytes += 2 * ub
            continue
        if "dynamic-slice(" in rhs:
            cost.hbm_bytes += 2 * rbytes
            continue
        cost.hbm_bytes += operand_bytes(_operand_names(rhs)) + rbytes
    return cost


def top_hbm_contributors(text: str, n_devices: int = 1, k: int = 12):
    """Largest per-computation HBM charges (multiplier-weighted) — the
    profiler view used when a roofline term looks implausible."""
    comps = _parse_computations(text)
    entry_lines = comps.get("__entry__")
    entry_name = _COMP_HEADER.match(entry_lines[0]).group(2)
    costs = {name: _comp_cost(lines, n_devices, comps)
             for name, lines in comps.items() if name != "__entry__"}
    fusion_callees = {c for cc in costs.values() for c, _, kind in cc.edges
                      if kind == "fusion"}
    mult: Dict[str, float] = defaultdict(float)
    mult[entry_name] = 1.0
    order, seen, i = [entry_name], {entry_name}, 0
    while i < len(order):
        cur = order[i]
        i += 1
        for callee, kk, _kind in costs.get(cur, CompCost()).edges:
            mult[callee] += mult[cur] * kk
            if callee not in seen and callee in costs:
                seen.add(callee)
                order.append(callee)
    rows = [(name, mult.get(name, 0.0) * c.hbm_bytes, mult.get(name, 0.0))
            for name, c in costs.items()
            if name not in fusion_callees and mult.get(name, 0.0) * c.hbm_bytes > 0]
    rows.sort(key=lambda r: -r[1])
    return rows[:k]


def analyze_hlo(text: str, n_devices: int = 1) -> Dict:
    """Exact per-device cost summary of a compiled HLO module."""
    comps = _parse_computations(text)
    entry_lines = comps.get("__entry__")
    if entry_lines is None:
        raise ValueError("no ENTRY computation found")
    entry_name = _COMP_HEADER.match(entry_lines[0]).group(2)

    costs = {name: _comp_cost(lines, n_devices, comps)
             for name, lines in comps.items() if name != "__entry__"}

    fusion_callees = {
        callee
        for c in costs.values()
        for callee, _, kind in c.edges
        if kind == "fusion"
    }

    # multiplier per computation via BFS over the call graph
    mult: Dict[str, float] = defaultdict(float)
    mult[entry_name] = 1.0
    order = [entry_name]
    seen = {entry_name}
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        for callee, k, _kind in costs.get(cur, CompCost()).edges:
            mult[callee] += mult[cur] * k
            if callee not in seen and callee in costs:
                seen.add(callee)
                order.append(callee)

    total = {
        "dot_flops": 0.0,
        "conv_flops": 0.0,
        "hbm_bytes": 0.0,
        "collect_bytes": defaultdict(float),
        "wire_bytes": defaultdict(float),
    }
    for name, c in costs.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total["dot_flops"] += m * c.dot_flops
        total["conv_flops"] += m * c.conv_flops
        if name not in fusion_callees:
            # fusion internals are charged at the call site
            total["hbm_bytes"] += m * c.hbm_bytes
        for k, v in c.collect_bytes.items():
            total["collect_bytes"][k] += m * v
        for k, v in c.wire_bytes.items():
            total["wire_bytes"][k] += m * v
    total["collect_bytes"] = dict(total["collect_bytes"])
    total["wire_bytes"] = dict(total["wire_bytes"])
    total["collective_bytes_total"] = sum(total["collect_bytes"].values())
    total["wire_bytes_total"] = sum(total["wire_bytes"].values())
    total["flops"] = total["dot_flops"] + total["conv_flops"]
    return total
