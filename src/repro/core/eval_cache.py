"""Content-addressed dry-run result cache.

Dry-run compiles dominate DSE wall-clock (seconds-to-minutes per design vs
microseconds for everything else in the loop). Every design is fully
described by ``(arch, shape, mesh_name, point.key())`` — the compile is a
pure function of that tuple — so its ``run_cell`` record can be memoized
across iterations, loop restarts, and whole campaigns.

The cache is a directory of one JSON file per design, keyed by the SHA-256
of the identity tuple, living next to the cost DB (``DryRunCache.beside``)
so a campaign's DB and cache travel together. Writes are atomic
(tmp + rename) so concurrent campaign processes sharing a cache never read
torn records.
"""
from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Any, Dict, Optional


class DryRunCache:
    def __init__(self, root: Path | str):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._mem: Dict[str, Dict[str, Any]] = {}
        self.hits = 0
        self.misses = 0

    @classmethod
    def beside(cls, db_path: Path | str) -> "DryRunCache":
        """The canonical cache location for a cost DB: a sibling directory."""
        return cls(Path(db_path).parent / "dryrun_cache")

    @staticmethod
    def key_for(arch: str, shape: str, mesh_name: str, point_key: str) -> str:
        blob = json.dumps([arch, shape, mesh_name, point_key])
        return hashlib.sha256(blob.encode()).hexdigest()[:32]

    def get(self, arch: str, shape: str, mesh_name: str,
            point_key: str) -> Optional[Dict[str, Any]]:
        key = self.key_for(arch, shape, mesh_name, point_key)
        rec = self._mem.get(key)
        if rec is None:
            f = self.root / f"{key}.json"
            if f.exists():
                try:
                    rec = json.loads(f.read_text())
                except (OSError, json.JSONDecodeError):
                    rec = None
                else:
                    self._mem[key] = rec
        if rec is None:
            self.misses += 1
            return None
        self.hits += 1
        return rec

    def put(self, arch: str, shape: str, mesh_name: str, point_key: str,
            rec: Dict[str, Any]) -> None:
        key = self.key_for(arch, shape, mesh_name, point_key)
        self._mem[key] = rec
        f = self.root / f"{key}.json"
        tmp = f.with_suffix(f".tmp.{os.getpid()}")
        tmp.write_text(json.dumps(rec, default=str))
        tmp.replace(f)

    def size(self) -> int:
        return len(list(self.root.glob("*.json")))

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses, "entries": self.size()}
