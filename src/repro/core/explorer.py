"""DSE Explorer (paper §3.1): structured candidate generation + evaluation.

Per iteration the Explorer takes the incumbent design, generates the
permutation set (single-dimension mutations within the template's
device-aware ranges plus LLM-stack refinements), pre-ranks candidates with
the learned cost model to bound expensive simulations, evaluates the top
candidates through the Evaluation module, and emits summarized hardware data
points into the cost DB. Each evaluation leaves a 'design run folder'
artifact (JSON next to the dry-run HLO summaries).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint, featurize, workload_features
from repro.core.cost_model import CostModel
from repro.core.design_space import PlanPoint, PlanTemplate
from repro.core.evaluator import Evaluator


@dataclass
class Explorer:
    evaluator: Evaluator
    db: CostDB
    cost_model: Optional[CostModel] = None
    seed: int = 0
    # exploration diversity (paper §3.2.2): evaluate a few random template
    # points alongside the greedy neighborhood to avoid local optima
    n_random: int = 1

    def _rank(self, cfg, cell, cands: Sequence[PlanPoint]) -> List[PlanPoint]:
        if self.cost_model is None or not self.cost_model.trained or not cands:
            return list(cands)
        wl = workload_features(cfg, cell)
        feats = np.stack([featurize(dict(c.dims), wl) for c in cands])
        order = self.cost_model.rank_candidates(feats)
        return [cands[i] for i in order]

    def explore(self, arch: str, shape: str, seeds: Sequence[PlanPoint],
                *, budget: int = 4, iteration: int = 0,
                extra_candidates: Sequence[PlanPoint] = ()) -> List[DataPoint]:
        """Evaluate up to ``budget`` new candidates derived from ``seeds``."""
        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(self.evaluator.mesh.shape))
        rng = random.Random(self.seed + iteration)

        cands: List[PlanPoint] = list(extra_candidates)
        for seed in seeds:
            cands.extend(template.neighbors(seed))
        cands.extend(template.random_points(rng, self.n_random))

        # dedupe + drop already-evaluated designs
        seen_keys = {d.point.get("__key__") for d in self.db.query(arch, shape)}
        uniq: Dict[str, PlanPoint] = {}
        for c in cands:
            k = c.key()
            if k not in seen_keys and k not in uniq:
                uniq[k] = c
        ranked = self._rank(cfg, cell, list(uniq.values()))

        # the whole ranked budget goes down as ONE batch: cache hits return
        # instantly and the remaining compiles share the evaluator's pool
        out = self.evaluator.evaluate_batch(arch, shape, ranked[:budget],
                                            source="explorer",
                                            iteration=iteration)
        self.db.append_many(out)
        return out
