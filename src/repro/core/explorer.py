"""DSE Explorer (paper §3.1) — compatibility facade over the search package.

The greedy candidate-generation policy that used to live here is now
:class:`~repro.search.greedy.GreedyNeighborhood`; ``Explorer`` keeps the
historical one-call API (generate -> dedupe -> rank -> batch-evaluate ->
record) for scripts and notebooks that drive exploration without a
``DSELoop``. Dedupe uses the cost DB's cached per-cell key index
(``CostDB.keys``) instead of rescanning ``db.query(arch, shape)`` on every
call — O(batch) per iteration, not O(DB).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.configs import SHAPE_BY_NAME, get_config
from repro.core.cost_db import CostDB, DataPoint, workload_features
from repro.core.cost_model import CostModel
from repro.core.design_space import PlanPoint, PlanTemplate
from repro.core.evaluator import Evaluator
from repro.search.base import Candidate, SearchState, select_candidates


@dataclass
class Explorer:
    evaluator: Evaluator
    db: CostDB
    cost_model: Optional[CostModel] = None
    seed: int = 0
    # exploration diversity (paper §3.2.2): evaluate a few random template
    # points alongside the greedy neighborhood to avoid local optima
    n_random: int = 1

    def explore(self, arch: str, shape: str, seeds: Sequence[PlanPoint],
                *, budget: int = 4, iteration: int = 0,
                extra_candidates: Sequence[PlanPoint] = ()) -> List[DataPoint]:
        """Evaluate up to ``budget`` new candidates derived from ``seeds``."""
        cfg = get_config(arch)
        cell = SHAPE_BY_NAME[shape]
        template = PlanTemplate(cfg, cell, dict(self.evaluator.mesh.shape))
        rng = random.Random(self.seed + iteration)

        cands: List[Candidate] = [Candidate(p, "llm") for p in extra_candidates]
        for seed in seeds:
            cands += [Candidate(p, "explorer") for p in template.neighbors(seed)]
        cands += [Candidate(p, "explorer")
                  for p in template.random_points(rng, self.n_random)]

        state = SearchState(arch=arch, shape=shape, cfg=cfg, cell=cell,
                            template=template, db=self.db, iteration=iteration,
                            budget=budget, incumbent=None,
                            cost_model=self.cost_model,
                            workload=workload_features(cfg, cell))
        # shared pipeline: key-index dedupe + in-batch dedupe + rank + budget
        ranked = select_candidates(state, cands)

        # the whole ranked budget goes down as ONE batch: cache hits return
        # instantly and the remaining compiles share the evaluator's pool
        out = self.evaluator.evaluate_batch(arch, shape,
                                            [c.point for c in ranked],
                                            source=[c.source for c in ranked],
                                            iteration=iteration)
        self.db.append_many(out)
        return out
