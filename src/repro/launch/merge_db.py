"""Merge per-shard campaign outputs into one canonical campaign directory.

A sharded campaign (``repro.launch.campaign --shard i/n``) leaves n disjoint
output dirs, each with its own ``cost_db.jsonl``, ``reports/`` and
``dryrun_cache/``. This CLI folds them into one:

* **cost DB** — records deduplicated by ``(arch, shape, mesh,
  point.__key__)``, keeping the *earliest* record (by timestamp, then input
  order); the merged JSONL is timestamp-sorted so the result reads like one
  chronological campaign;
* **reports** — per-cell report JSONs copied over (shards own disjoint
  cells; on a collision the earliest-mtime report wins and a warning is
  printed);
* **dryrun cache** — content-addressed entries unioned (existing entries are
  never overwritten — they are identical by construction);
* **leaderboard** — rebuilt from the merged DB + the merged report set,
  using the same ranking/serialization as ``run_campaign``. With the
  deterministic mock LLM this reproduces the single-process
  ``leaderboard.json`` byte-for-byte (tier-1 asserts it).

Usage:

    PYTHONPATH=src python -m repro.launch.merge_db \\
        artifacts/shard0 artifacts/shard1 --out artifacts/campaign

Pure file manipulation — no jax import, safe to run anywhere.
"""
from __future__ import annotations

import argparse
import json
import shutil
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from repro.core.cost_db import CostDB, DataPoint
from repro.launch.campaign import build_leaderboard, write_json_atomic


def merge_cost_dbs(shard_dbs: Sequence[Path], out_db: Path,
                   ) -> Tuple[int, int]:
    """Merge shard JSONL DBs into ``out_db``; returns (kept, dropped_dups).
    Identity is ``(arch, shape, mesh, point.__key__, status)``; the earliest
    record (timestamp, then input order) wins. Status is part of the
    identity so a gate-``pruned`` prediction and the later *measured* row
    for the same design both survive — exactly the pair a single-process
    campaign's DB holds when the gate relaxes and a once-pruned design gets
    compiled. Unreadable lines are skipped."""
    rows: List[DataPoint] = []
    for p in shard_dbs:
        if not p.exists():
            continue
        for line in p.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rows.append(DataPoint.from_json(line))
            except (json.JSONDecodeError, TypeError):
                print(f"merge_db: skipping unreadable row in {p}")
    rows.sort(key=lambda d: d.ts or 0.0)  # stable: input order breaks ties
    seen = set()
    kept: List[DataPoint] = []
    for d in rows:
        ident = (d.arch, d.shape, d.mesh, d.point.get("__key__"), d.status)
        if ident[3] is not None and ident in seen:
            continue
        seen.add(ident)
        kept.append(d)
    out_db.parent.mkdir(parents=True, exist_ok=True)
    with out_db.open("w") as f:
        f.write("".join(d.to_json() + "\n" for d in kept))
    return len(kept), len(rows) - len(kept)


def merge_reports(shard_dirs: Sequence[Path], out_dir: Path) -> List[Path]:
    """Copy per-cell report JSONs into ``out_dir/reports``. Shards own
    disjoint cells; on a collision the earliest-mtime file wins."""
    dest = out_dir / "reports"
    dest.mkdir(parents=True, exist_ok=True)
    srcs: Dict[str, Path] = {}
    for sd in shard_dirs:
        for f in sorted((sd / "reports").glob("*.json")):
            prev = srcs.get(f.name)
            if prev is None:
                srcs[f.name] = f
            else:
                keep, drop = ((prev, f) if prev.stat().st_mtime <= f.stat().st_mtime
                              else (f, prev))
                print(f"merge_db: duplicate report {f.name}: keeping "
                      f"{keep} (earlier), ignoring {drop}")
                srcs[f.name] = keep
    out = []
    for name, src in sorted(srcs.items()):
        shutil.copyfile(src, dest / name)
        out.append(dest / name)
    return out


def merge_caches(shard_dirs: Sequence[Path], out_dir: Path) -> int:
    """Union the content-addressed dry-run caches (same key = same record,
    so existing entries are never overwritten). Returns entries copied."""
    dest = out_dir / "dryrun_cache"
    dest.mkdir(parents=True, exist_ok=True)
    n = 0
    for sd in shard_dirs:
        for f in sorted((sd / "dryrun_cache").glob("*.json")):
            target = dest / f.name
            if not target.exists():
                shutil.copyfile(f, target)
                n += 1
    return n


def rebuild_leaderboard(out_dir: Path) -> Path:
    """Reconstruct cell rows from the merged report set and rank them with
    the same ``build_leaderboard`` + serialization as ``run_campaign``."""
    rows: List[Dict] = []
    for f in (out_dir / "reports").glob("*.json"):
        parts = f.stem.split("__")
        if len(parts) != 3:
            print(f"merge_db: skipping unrecognized report name {f.name}")
            continue
        arch, shape, mesh = parts
        d = json.loads(f.read_text())
        rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                     "status": d.get("status", "complete"),
                     "improvement": d.get("improvement")})
    rows.sort(key=lambda c: (c["arch"], c["shape"], c["mesh"]))
    db = CostDB(out_dir / "cost_db.jsonl")
    # same serialization as run_campaign, and atomic for the same reason:
    # a reader (or a killed merge) must never see a torn leaderboard
    return write_json_atomic(out_dir / "leaderboard.json",
                             build_leaderboard(db, rows))


def merge(shard_dirs: Sequence[Path | str], out_dir: Path | str,
          verbose: bool = True) -> Dict:
    """Fold the shard dirs into ``out_dir`` (DB dedup + reports + caches +
    rebuilt leaderboard, see module docstring); returns the merge summary.
    Raises ``FileNotFoundError`` for a missing shard dir and ``ValueError``
    when ``out_dir`` aliases a shard dir. Deterministic: the same shard
    contents produce byte-identical merged outputs regardless of input
    order (identity dedup is timestamp-, then input-order-stable)."""
    shard_dirs = [Path(s) for s in shard_dirs]
    out_dir = Path(out_dir)
    for sd in shard_dirs:
        if not sd.is_dir():
            raise FileNotFoundError(f"shard dir {sd} does not exist")
    if out_dir in shard_dirs:
        raise ValueError("--out must not be one of the shard dirs")
    kept, dups = merge_cost_dbs([sd / "cost_db.jsonl" for sd in shard_dirs],
                                out_dir / "cost_db.jsonl")
    reports = merge_reports(shard_dirs, out_dir)
    cached = merge_caches(shard_dirs, out_dir)
    lb_path = rebuild_leaderboard(out_dir)
    summary = {
        "shards": [str(s) for s in shard_dirs],
        "out": str(out_dir),
        "datapoints": kept, "duplicates_dropped": dups,
        "reports": len(reports), "cache_entries_copied": cached,
        "leaderboard": str(lb_path),
    }
    if verbose:
        print(f"merge_db: {summary}")
    return summary


def build_parser() -> argparse.ArgumentParser:
    """The merge CLI surface, importable without touching jax (the
    quickstart drift checker parses documented commands against it)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.merge_db",
        description="merge sharded campaign outputs (cost DBs, reports, "
                    "dry-run caches) and rebuild one leaderboard")
    ap.add_argument("shards", nargs="+", help="per-shard campaign --out dirs")
    ap.add_argument("--out", required=True, help="merged campaign dir")
    return ap


def main():
    """CLI entry: merge the given shard dirs into ``--out``. Exits nonzero
    (FileNotFoundError/ValueError) on missing shard dirs or ``--out``
    aliasing a shard dir."""
    args = build_parser().parse_args()
    merge(args.shards, args.out)


if __name__ == "__main__":
    main()
