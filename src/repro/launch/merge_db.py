"""Merge per-shard campaign outputs into one canonical campaign directory.

A sharded campaign (``repro.launch.campaign --shard i/n``) leaves n disjoint
output dirs, each with its own ``cost_db.jsonl``, ``reports/`` and
``dryrun_cache/``. This CLI folds them into one:

* **cost DB** — records deduplicated by ``(arch, shape, mesh,
  point.__key__, status, fidelity)``, keeping the *earliest* record (by
  timestamp, then serialized content); the merged JSONL is timestamp-sorted
  so the result reads like one chronological campaign. Fidelity in the
  identity keeps a design's dry-run row and its tier-2 *measured* row as
  two first-class records, while duplicate measurements of one design
  (a stolen cell promoted by two owners — byte-identical by the measured
  cache's replay contract) collapse to the one canonical row;
* **reports** — per-cell report JSONs copied over (shards own disjoint
  cells; on a collision the earliest-mtime report wins and a warning is
  printed);
* **caches** — content-addressed ``dryrun_cache/`` and ``measured_cache/``
  entries unioned (existing entries are never overwritten — they are
  identical by construction);
* **leaderboard** — rebuilt from the merged DB + the merged report set,
  using the same ranking/serialization as ``run_campaign``. With the
  deterministic mock LLM this reproduces the single-process
  ``leaderboard.json`` byte-for-byte (tier-1 asserts it).

Usage:

    PYTHONPATH=src python -m repro.launch.merge_db \\
        artifacts/shard0 artifacts/shard1 --out artifacts/campaign

Pure file manipulation — no jax import, safe to run anywhere.
"""
from __future__ import annotations

import argparse
import json
import shutil
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost_db import CostDB, DataPoint
from repro.launch.campaign import (OBJECTIVE_CHOICES, build_leaderboard,
                                   validate_objective_args)
from repro.launch.ioutil import write_json_atomic


def merge_cost_dbs(shard_dbs: Sequence[Path], out_db: Path,
                   ) -> Tuple[int, int]:
    """Merge shard JSONL DBs into ``out_db``; returns (kept, dropped_dups).
    Identity is ``(arch, shape, mesh, point.__key__, status, fidelity)``;
    the earliest record (timestamp, then serialized content — NOT input
    order, so the merge is **order-invariant**: any permutation of the
    shard list yields byte-identical output, which tier-1 property-tests)
    wins. Status is part of the identity so a gate-``pruned`` prediction
    and the later evaluated row for the same design both survive — exactly
    the pair a single-process campaign's DB holds when the gate relaxes
    and a once-pruned design gets compiled. Fidelity is part of it so a
    design's dry-run bound and its tier-2 measured timing coexist, while
    duplicate measurements (one per owner of a stolen cell, byte-identical
    via the measured-cache replay) dedupe to one. Unreadable lines are
    skipped."""
    rows: List[DataPoint] = []
    for p in shard_dbs:
        if not p.exists():
            continue
        for line in p.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rows.append(DataPoint.from_json(line))
            except (json.JSONDecodeError, TypeError):
                print(f"merge_db: skipping unreadable row in {p}")
    # ties broken by serialized content, never input order: two shards
    # carrying equal-timestamp rows for one identity (a stolen cell run
    # twice, clock granularity) must merge the same whichever came first
    rows.sort(key=lambda d: (d.ts or 0.0, d.to_json()))
    seen = set()
    kept: List[DataPoint] = []
    for d in rows:
        ident = (d.arch, d.shape, d.mesh, d.point.get("__key__"), d.status,
                 d.fidelity)
        if ident[3] is not None and ident in seen:
            continue
        seen.add(ident)
        kept.append(d)
    out_db.parent.mkdir(parents=True, exist_ok=True)
    with out_db.open("w") as f:
        f.write("".join(d.to_json() + "\n" for d in kept))
    return len(kept), len(rows) - len(kept)


def merge_reports(shard_dirs: Sequence[Path], out_dir: Path) -> List[Path]:
    """Copy per-cell report JSONs into ``out_dir/reports``. Statically-cut
    shards own disjoint cells, but queue-mode steals legitimately leave the
    same cell reported by two shards; on a collision the earliest-mtime
    file wins, with ties broken by content bytes (never input order, so
    the merge stays order-invariant)."""
    dest = out_dir / "reports"
    dest.mkdir(parents=True, exist_ok=True)
    srcs: Dict[str, Path] = {}
    for sd in shard_dirs:
        for f in sorted((sd / "reports").glob("*.json")):
            prev = srcs.get(f.name)
            if prev is None:
                srcs[f.name] = f
            elif _report_rank(f) < _report_rank(prev):
                print(f"merge_db: duplicate report {f.name}: keeping "
                      f"{f} (earlier), ignoring {prev}")
                srcs[f.name] = f
            else:
                print(f"merge_db: duplicate report {f.name}: keeping "
                      f"{prev} (earlier), ignoring {f}")
    out = []
    for name, src in sorted(srcs.items()):
        shutil.copyfile(src, dest / name)
        out.append(dest / name)
    return out


def _report_rank(path: Path) -> Tuple[float, bytes]:
    """Collision ordering for duplicate reports: earliest mtime first,
    content bytes as the order-independent tie-break."""
    return (path.stat().st_mtime, path.read_bytes())


def merge_caches(shard_dirs: Sequence[Path], out_dir: Path,
                 extra_cache_dirs: Optional[Sequence[Path]] = None) -> int:
    """Union the content-addressed caches — ``dryrun_cache/`` (compiles)
    and ``measured_cache/`` (tier-2 timings) — per subdirectory (same key =
    same record, so existing entries are never overwritten).
    ``extra_cache_dirs`` names cache directories *directly* (not shard
    dirs) — queue-mode campaigns share their caches inside the queue dir,
    and the merge folds them in so the merged campaign dir resumes for
    free; an extra dir named ``measured_cache`` routes to the measured
    union, anything else to the dry-run union. Returns entries copied."""
    extras = [Path(c) for c in (extra_cache_dirs or [])]
    n = 0
    for sub in ("dryrun_cache", "measured_cache"):
        dest = out_dir / sub
        dest.mkdir(parents=True, exist_ok=True)
        caches = [sd / sub for sd in shard_dirs]
        caches += [c for c in extras
                   if (c.name == "measured_cache") == (sub == "measured_cache")]
        for cd in caches:
            for f in sorted(cd.glob("*.json")):
                target = dest / f.name
                if not target.exists():
                    shutil.copyfile(f, target)
                    n += 1
    return n


def rebuild_leaderboard(out_dir: Path, objective: str = "bound_s") -> Path:
    """Reconstruct cell rows from the merged report set and rank them with
    the same ``build_leaderboard`` + serialization as ``run_campaign``.
    ``objective="pareto"`` rebuilds dominance-ranked fronts instead of the
    scalar heads — because ``pareto_rows`` is a pure function of the merged
    row *set* (dedupe + canonical front ordering), the rebuilt front is
    byte-identical under any shard permutation, same as scalar mode."""
    rows: List[Dict] = []
    for f in (out_dir / "reports").glob("*.json"):
        parts = f.stem.split("__")
        if len(parts) != 3:
            print(f"merge_db: skipping unrecognized report name {f.name}")
            continue
        arch, shape, mesh = parts
        d = json.loads(f.read_text())
        rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                     "status": d.get("status", "complete"),
                     "improvement": d.get("improvement")})
    rows.sort(key=lambda c: (c["arch"], c["shape"], c["mesh"]))
    db = CostDB(out_dir / "cost_db.jsonl")
    # same serialization as run_campaign, and atomic for the same reason:
    # a reader (or a killed merge) must never see a torn leaderboard
    return write_json_atomic(out_dir / "leaderboard.json",
                             build_leaderboard(db, rows, objective=objective))


def merge(shard_dirs: Sequence[Path | str], out_dir: Path | str,
          verbose: bool = True,
          extra_cache_dirs: Optional[Sequence[Path | str]] = None,
          objective: str = "bound_s") -> Dict:
    """Fold the shard dirs into ``out_dir`` (DB dedup + reports + caches +
    rebuilt leaderboard, see module docstring); returns the merge summary.
    ``extra_cache_dirs`` folds additional content-addressed cache dirs in
    (the queue-shared cache of a ``--queue`` campaign). Raises
    ``FileNotFoundError`` for a missing shard dir and ``ValueError`` when
    ``out_dir`` aliases a shard dir. Deterministic AND order-invariant:
    the same shard contents produce byte-identical merged outputs under
    any permutation of ``shard_dirs`` (row dedup ties break on serialized
    content, report collisions on (mtime, content)) — tier-1
    property-tests both."""
    err = validate_objective_args(objective)
    if err:
        raise ValueError(err)
    shard_dirs = [Path(s) for s in shard_dirs]
    out_dir = Path(out_dir)
    for sd in shard_dirs:
        if not sd.is_dir():
            raise FileNotFoundError(f"shard dir {sd} does not exist")
    if out_dir in shard_dirs:
        raise ValueError("--out must not be one of the shard dirs")
    kept, dups = merge_cost_dbs([sd / "cost_db.jsonl" for sd in shard_dirs],
                                out_dir / "cost_db.jsonl")
    reports = merge_reports(shard_dirs, out_dir)
    cached = merge_caches(shard_dirs, out_dir,
                          [Path(c) for c in (extra_cache_dirs or [])])
    lb_path = rebuild_leaderboard(out_dir, objective=objective)
    summary = {
        "shards": [str(s) for s in shard_dirs],
        "out": str(out_dir),
        "datapoints": kept, "duplicates_dropped": dups,
        "reports": len(reports), "cache_entries_copied": cached,
        "leaderboard": str(lb_path),
    }
    if verbose:
        print(f"merge_db: {summary}")
    return summary


def build_parser() -> argparse.ArgumentParser:
    """The merge CLI surface, importable without touching jax (the
    quickstart drift checker parses documented commands against it)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.merge_db",
        description="merge sharded campaign outputs (cost DBs, reports, "
                    "dry-run caches) and rebuild one leaderboard")
    ap.add_argument("shards", nargs="+", help="per-shard campaign --out dirs")
    ap.add_argument("--out", required=True, help="merged campaign dir")
    ap.add_argument("--extra-cache", action="append", default=None,
                    metavar="DIR",
                    help="additional content-addressed cache dir(s) to fold "
                         "in (e.g. a queue-mode campaign's shared "
                         "QUEUE/dryrun_cache or QUEUE/measured_cache; a dir "
                         "named measured_cache routes to the measured "
                         "union); repeatable")
    ap.add_argument("--objective", choices=list(OBJECTIVE_CHOICES),
                    default="bound_s",
                    help="ranking mode for the rebuilt leaderboard: scalar "
                         "bound_s heads (default) or dominance-ranked "
                         "pareto fronts")
    return ap


def main():
    """CLI entry: merge the given shard dirs into ``--out``. Exits nonzero
    (FileNotFoundError/ValueError) on missing shard dirs or ``--out``
    aliasing a shard dir."""
    args = build_parser().parse_args()
    merge(args.shards, args.out, extra_cache_dirs=args.extra_cache,
          objective=args.objective)


if __name__ == "__main__":
    main()
