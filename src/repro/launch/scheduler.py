"""Crash-safe, file-backed cell queue with leases — the work-stealing substrate.

Static ``--shard i/n`` partitioning makes the campaign's wall-clock the
slowest shard's wall-clock: one slow host drags the run while fast shards
sit idle. :class:`CellQueue` replaces the static cut with a *dynamic* queue:
every ``(arch, shape)`` cell is a **ticket** (one JSON file) that moves
through three state directories under the queue root,

    pending/{arch}__{shape}.json            up for grabs
    leased/{arch}__{shape}.json.lease-OWNER owned, deadline-bounded
    done/{arch}__{shape}.json               finished (status recorded)

and every state transition is a single atomic ``os.rename`` on one file, so

* a ticket is in exactly one state at any instant, even under SIGKILL —
  there is no multi-file transaction to tear;
* two contending claimants cannot both win: POSIX ``rename`` succeeds for
  exactly one of them (the loser sees ``ENOENT`` and moves on);
* the lease *owner* is encoded in the leased **filename**, so completing a
  ticket (``rename leased/X.lease-me -> done/X``) is a compare-and-swap:
  if the lease was stolen or re-leased meanwhile, the rename fails and
  :meth:`CellQueue.complete` reports the loss instead of clobbering the
  new owner's claim.

Ticket content (JSON, sorted keys) carries the audit trail: ``attempt``
(number of leases ever granted — a re-leased ticket shows ``attempt >= 2``),
``steals`` (forced lease expiries), ``owner`` / ``leased_at`` / ``deadline``
while leased, and ``status`` / ``done_at`` once finished. Content rewrites
happen *after* the state-claiming rename and are **never-creating**
in-place writes (``O_WRONLY`` without ``O_CREAT``): a writer that lost a
rename race in the meantime — a renewal racing a steal, an acquirer racing
a reclaim — cannot resurrect the file it no longer owns, so one cell can
never exist in two states. A crash between rename and rewrite (or a reader
catching the in-place write torn) leaves a ticket whose filename (state +
owner) is right and whose content is stale/unreadable — readers fall back
to file mtime for the deadline, so such a ticket is reclaimed like any
other expired lease.

Lease semantics: a lease carries a ``deadline`` (``leased_at + lease_s``,
refreshed by :meth:`CellQueue.renew` — campaigns renew on every heartbeat).
A leased ticket past its deadline is presumed orphaned (owner crashed or
lost) and any caller of :meth:`CellQueue.reclaim_expired` — acquirers do it
automatically — moves it back to ``pending``. A supervisor that *knows* an
owner died (nonzero exit) calls :meth:`CellQueue.release_owner` to reclaim
immediately instead of waiting out the deadline, and a supervisor that
decides an owner is too slow calls :meth:`CellQueue.steal` — same
transition, but counted on the ticket so post-mortems can tell a crash
reclaim from a rebalancing steal.

Shared across owners: the queue root also hosts the content-addressed
dry-run cache (:attr:`CellQueue.cache_dir`). Queue-mode campaigns point
their evaluator at it, so when a stolen cell is re-run by another shard
every compile the first owner already paid for replays as a cache hit —
completed work is never redone, only re-read.

Every file-system touch goes through an injectable :class:`QueueFS` seam
(:class:`LocalFS` by default — plain stdlib calls). The seam exists for the
``repro.analysis.race`` model checker, which substitutes an instrumented
in-memory filesystem and exhaustively explores interleavings of the queue
protocol's atomic steps; production behavior is byte-identical to the
direct stdlib calls the seam replaced.

Pure stdlib file manipulation — no jax import, safe anywhere.
"""
from __future__ import annotations

import json
import os
import re
import time
from contextlib import contextmanager
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

PENDING, LEASED, DONE = "pending", "leased", "done"
STATES = (PENDING, LEASED, DONE)
LEASE_INFIX = ".lease-"
# no dots: an owner containing ".tmp" would make its lease files look like
# atomic-write debris and vanish from every directory scan
_OWNER_RE = re.compile(r"[^A-Za-z0-9_-]+")
_TMP_RE = re.compile(r"\.tmp\d+$")


def sanitize_owner(owner: str) -> str:
    """Make an owner id filename-safe (it is embedded in lease filenames;
    dots are excluded so an owner can never collide with the ``.tmp<pid>``
    atomic-write suffix); raises ``ValueError`` for an owner that
    sanitizes to nothing."""
    clean = _OWNER_RE.sub("_", owner)
    if not clean:
        raise ValueError(f"owner {owner!r} has no filename-safe characters")
    return clean


class LocalFS:
    """The queue's filesystem primitives, one thin method per atomic step.

    :class:`CellQueue` performs **every** disk touch through one of these
    methods so that the race explorer (``repro.analysis.race``) can swap in
    an instrumented in-memory implementation and schedule the protocol's
    atomic steps one at a time. Each method is a single stdlib call (plus
    the error contract noted in its docstring) — there is deliberately no
    logic here, because anything above the primitives would run *between*
    atomic steps and escape the model checker.
    """

    def mkdirs(self, path: Path) -> None:
        """``mkdir -p``: create ``path`` and parents, exist_ok."""
        Path(path).mkdir(parents=True, exist_ok=True)

    def mkdir_exclusive(self, path: Path) -> None:
        """Atomic lock-style create; raises ``FileExistsError`` when held."""
        os.mkdir(path)

    def rmdir(self, path: Path) -> None:
        """Remove an empty directory; raises ``OSError`` when gone/nonempty."""
        os.rmdir(path)

    def glob(self, dir_path: Path, pattern: str) -> List[Path]:
        """Sorted shell-glob match of ``pattern`` within ``dir_path``
        (non-recursive); an unreadable/missing directory yields ``[]``."""
        return sorted(Path(dir_path).glob(pattern))

    def exists(self, path: Path) -> bool:
        """Whether ``path`` currently exists."""
        return Path(path).exists()

    def rename(self, src: Path, dst: Path) -> None:
        """The protocol's atomic state transition; raises
        ``FileNotFoundError`` when ``src`` is gone (the caller lost the
        race) and silently replaces an existing ``dst``."""
        os.rename(src, dst)

    def link(self, src: Path, dst: Path) -> None:
        """Exclusive hard-link create; raises ``FileExistsError`` when
        ``dst`` exists (the seeding race loser's signal)."""
        os.link(src, dst)

    def unlink(self, path: Path, missing_ok: bool = False) -> None:
        """Remove a file; ``missing_ok`` swallows only ENOENT."""
        Path(path).unlink(missing_ok=missing_ok)

    def read_text(self, path: Path) -> str:
        """Read a file's content; raises ``OSError`` when missing."""
        return Path(path).read_text()

    def write_text(self, path: Path, text: str) -> None:
        """Create-or-truncate write — legal ONLY for private ``.tmp`` paths
        that a later :meth:`link`/:meth:`replace` publishes (the invariant
        linter's RPR005 rule enforces exactly that)."""
        Path(path).write_text(text)

    def replace(self, src: Path, dst: Path) -> None:
        """Atomic clobbering rename (``os.replace``): publish a tmp file."""
        os.replace(src, dst)

    def rewrite_nocreate(self, path: Path, text: str) -> bool:
        """In-place content rewrite of a file that must ALREADY exist:
        ``O_WRONLY`` **without** ``O_CREAT``, so a writer that lost a
        state-rename race cannot resurrect the file. Returns ``False``
        (touching nothing) when ``path`` does not exist. Not atomic — the
        queue's readers tolerate torn content by falling back to mtime."""
        try:
            fd = os.open(path, os.O_WRONLY)  # no O_CREAT, by design
        except FileNotFoundError:
            return False
        try:
            os.ftruncate(fd, 0)
            os.write(fd, text.encode())
        finally:
            os.close(fd)
        return True

    def mtime(self, path: Path) -> float:
        """``st_mtime`` of ``path``; raises ``OSError`` when gone."""
        return Path(path).stat().st_mtime


@dataclass
class Ticket:
    """One cell's queue state: identity (``arch``/``shape``/``mesh``), the
    lease audit trail (``attempt`` = leases ever granted, ``steals`` =
    forced expiries), the live lease (``owner``/``leased_at``/``deadline``,
    ``None`` unless leased), and the outcome (``status``/``done_at``, set
    on completion). Serialized with sorted keys so ticket files are
    byte-stable for a given state."""

    arch: str
    shape: str
    mesh: Optional[str] = None
    attempt: int = 0
    steals: int = 0
    owner: Optional[str] = None
    leased_at: Optional[float] = None
    deadline: Optional[float] = None
    status: Optional[str] = None
    done_at: Optional[float] = None

    @property
    def cell(self) -> str:
        """The human-readable cell id, ``"arch/shape"``."""
        return f"{self.arch}/{self.shape}"

    @property
    def file_name(self) -> str:
        """Canonical ticket file name in ``pending/`` and ``done/``."""
        return f"{self.arch}__{self.shape}.json"

    def duration(self) -> Optional[float]:
        """Wall seconds the finishing lease held the ticket (``done_at -
        leased_at``), or ``None`` when either timestamp is missing."""
        if self.done_at is None or self.leased_at is None:
            return None
        return max(self.done_at - self.leased_at, 0.0)

    def to_json(self) -> str:
        """Sorted-key JSON serialization (one ticket file's content)."""
        return json.dumps(asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Ticket":
        """Parse a ticket file's content; raises on malformed JSON."""
        return cls(**json.loads(text))


class CellQueue:
    """The file-backed lease queue (see module docstring). One instance per
    process is cheap — all state lives on disk; concurrent instances over
    the same root coordinate purely through atomic renames."""

    def __init__(self, root: Path | str, *, lease_s: float = 300.0,
                 fs: Optional[LocalFS] = None):
        """Open (creating if needed) the queue at ``root``. ``lease_s`` is
        the lease length this instance grants/renews — it never rewrites
        other owners' deadlines. ``fs`` substitutes the filesystem seam
        (default: the real local filesystem) — the race explorer injects an
        instrumented in-memory one."""
        self.root = Path(root)
        if lease_s <= 0:
            raise ValueError(f"lease_s must be > 0, got {lease_s}")
        self.lease_s = float(lease_s)
        self._fs = fs if fs is not None else LocalFS()
        for state in STATES:
            self._fs.mkdirs(self.root / state)

    # -- layout ------------------------------------------------------------
    @property
    def cache_dir(self) -> Path:
        """The shared content-addressed dry-run cache directory: every
        owner points its evaluator here, so a stolen cell's compiles
        replay instead of re-running."""
        return self.root / "dryrun_cache"

    @property
    def measured_dir(self) -> Path:
        """The shared content-addressed *measured-timing* cache (promotion
        ladder tier 2), beside :attr:`cache_dir`: a re-leased or stolen
        cell replays its recorded wall clocks instead of re-timing, which
        is what makes measurement exactly-once per design across owners."""
        return self.root / "measured_cache"

    def _state_dir(self, state: str) -> Path:
        return self.root / state

    def _lease_path(self, file_name: str, owner: str) -> Path:
        return self.root / LEASED / f"{file_name}{LEASE_INFIX}{owner}"

    @staticmethod
    def _split_lease_name(name: str) -> Optional[Tuple[str, str]]:
        """``(ticket_file_name, owner)`` from a leased filename, or ``None``
        for a foreign file (tmp debris etc.)."""
        if LEASE_INFIX not in name:
            return None
        file_name, owner = name.rsplit(LEASE_INFIX, 1)
        if not file_name.endswith(".json") or not owner:
            return None
        return file_name, owner

    def _read(self, path: Path) -> Optional[Ticket]:
        """Best-effort ticket read; ``None`` for a missing/torn file."""
        try:
            return Ticket.from_json(self._fs.read_text(path))
        except (OSError, json.JSONDecodeError, TypeError):
            return None

    def _write(self, path: Path, ticket: Ticket) -> None:
        """Atomic content write for a path this caller may CREATE (seeding
        only): tmp file + ``os.replace``. The tmp name is pid-qualified so
        concurrent writers never collide."""
        tmp = path.with_name(f"{path.name}.tmp{os.getpid()}")
        self._fs.write_text(tmp, ticket.to_json())
        self._fs.replace(tmp, path)

    def _rewrite_existing(self, path: Path, ticket: Ticket) -> bool:
        """Rewrite the content of a ticket file that must ALREADY exist;
        returns False (touching nothing) when it does not. Every content
        update that follows a state-claiming rename — and every lease
        renewal — goes through this, because a create-if-missing write
        (tmp + replace) would *resurrect* a file whose state rename this
        writer lost a race for, putting one cell in two states at once.
        The in-place write is not atomic, but a reader catching it torn
        treats the ticket as content-less and falls back to file mtime —
        which this write just refreshed — so the lease semantics hold."""
        return self._fs.rewrite_nocreate(path, ticket.to_json())

    # -- seeding -----------------------------------------------------------
    def seed(self, cells: Sequence[Tuple[str, str]],
             mesh: Optional[str] = None) -> int:
        """Create a pending ticket per ``(arch, shape)`` cell; idempotent —
        cells whose ticket already exists in *any* state are left alone, so
        re-seeding a half-finished queue (supervisor restart, a late
        cooperating worker) never resurrects claimed or completed work.
        Concurrent seeders are serialized by a lock directory, each cell
        is existence-checked immediately before its create, and the create
        itself is an exclusive hard-link (never a clobbering replace) — so
        a seeder racing an acquire/steal on the same cell loses the race
        instead of forking the ticket into two states. Returns the number
        of tickets created."""
        created = 0
        with self._seed_lock():
            for arch, shape in sorted(set(cells)):
                t = Ticket(arch=arch, shape=shape, mesh=mesh)
                if self._ticket_exists(t.file_name):
                    continue
                dst = self.root / PENDING / t.file_name
                tmp = dst.with_name(f"{dst.name}.tmp{os.getpid()}")
                self._fs.write_text(tmp, t.to_json())
                try:
                    # exclusive: EEXIST if anyone beat us
                    self._fs.link(tmp, dst)
                    created += 1
                except FileExistsError:
                    pass
                finally:
                    self._fs.unlink(tmp, missing_ok=True)
        return created

    def _ticket_exists(self, file_name: str) -> bool:
        """Whether ``file_name`` currently exists in any state directory
        (the leased check matches any owner's lease of it). Checks follow
        the ticket's *movement order* — pending, leased, done — so a
        forward rename racing this check (an acquire's pending->leased, a
        completion's leased->done) is always caught in either its source
        or its destination; a confirming second scan narrows the backward
        (steal/reclaim, leased->pending) race to a double coincidence."""
        def scan() -> bool:
            return (self._fs.exists(self.root / PENDING / file_name)
                    or bool(self._fs.glob(self._state_dir(LEASED),
                                          f"{file_name}{LEASE_INFIX}*"))
                    or self._fs.exists(self.root / DONE / file_name))
        return scan() or scan()

    @contextmanager
    def _seed_lock(self, timeout: float = 60.0):
        """Mutual exclusion between seeders: an atomically-created lock
        directory, broken when its mtime says the holder died mid-seed
        (seeding a full grid takes milliseconds, so ``timeout`` is
        generous). Raises ``TimeoutError`` if the lock never frees."""
        lock = self.root / "seed.lock"
        deadline = time.time() + 2 * timeout
        while True:
            try:
                self._fs.mkdir_exclusive(lock)
                break
            except FileExistsError:
                try:
                    if time.time() - self._fs.mtime(lock) > timeout:
                        self._fs.rmdir(lock)  # stale: holder died mid-seed
                        continue
                except OSError:
                    continue  # lock vanished or not yet stat-able: retry
                if time.time() > deadline:
                    raise TimeoutError(f"seed lock {lock} never freed")
                time.sleep(0.05)
        try:
            yield
        finally:
            try:
                self._fs.rmdir(lock)
            except OSError:
                pass

    # -- introspection -----------------------------------------------------
    def tickets(self, state: Optional[str] = None) -> List[Ticket]:
        """Tickets in ``state`` (or all states), sorted by cell identity.
        Leased tickets whose content rewrite was lost to a crash still
        report their owner (recovered from the lease filename)."""
        states = [state] if state else list(STATES)
        out: List[Ticket] = []
        for s in states:
            for f in self._fs.glob(self._state_dir(s), "*.json*"):
                if _TMP_RE.search(f.name):
                    continue
                if s == LEASED:
                    parsed = self._split_lease_name(f.name)
                    if parsed is None:
                        continue
                t = self._read(f)
                if t is None:
                    continue
                if s == LEASED and t.owner is None:
                    # crash between claim-rename and content rewrite: the
                    # filename is the authoritative owner record
                    t.owner = parsed[1]
                out.append(t)
        out.sort(key=lambda t: (t.arch, t.shape))
        return out

    def counts(self) -> Dict[str, int]:
        """``{"pending": n, "leased": n, "done": n}`` — one directory scan
        each; cheap enough for per-heartbeat calls on campaign-sized
        queues."""
        return {s: sum(1 for f in self._fs.glob(self._state_dir(s), "*.json*")
                       if not _TMP_RE.search(f.name)) for s in STATES}

    def total(self) -> int:
        """Total tickets across all states (the campaign's cell universe)."""
        return sum(self.counts().values())

    def drained(self) -> bool:
        """True when nothing is pending or leased — every cell is done, so
        queue-mode workers can exit."""
        c = self.counts()
        return c[PENDING] == 0 and c[LEASED] == 0

    # -- the lease lifecycle -----------------------------------------------
    def acquire(self, owner: str, now: Optional[float] = None,
                ) -> Optional[Ticket]:
        """Claim the first available pending ticket for ``owner`` (cells in
        sorted order, so contending workers drain the grid front-to-back).
        Reclaims expired leases first. Returns the leased ticket — its
        ``attempt`` already incremented and deadline stamped — or ``None``
        when nothing is pending (the queue may still have cells leased to
        other owners; poll :meth:`drained` to decide whether to wait)."""
        owner = sanitize_owner(owner)
        now = time.time() if now is None else now
        self.reclaim_expired(now)
        for f in self._fs.glob(self._state_dir(PENDING), "*.json"):
            target = self._lease_path(f.name, owner)
            try:
                self._fs.rename(f, target)
            except FileNotFoundError:
                continue  # another owner won this ticket; try the next
            t = self._read(target) or Ticket(*self._cell_of(f.name))
            t.attempt += 1
            t.owner, t.leased_at = owner, now
            t.deadline = now + self.lease_s
            t.status, t.done_at = None, None
            if not self._rewrite_existing(target, t):
                continue  # claim stolen/reclaimed in the rename window
            return t
        return None

    def renew(self, ticket: Ticket, now: Optional[float] = None) -> bool:
        """Push the lease deadline out another ``lease_s`` seconds; returns
        False (without touching anything) when ``ticket``'s lease is gone —
        stolen, reclaimed, or completed — which the owner should treat as
        'stop expecting to complete this cell'. Never creates the lease
        file: a renewal racing a steal must not resurrect the lease."""
        now = time.time() if now is None else now
        ticket.deadline = now + self.lease_s
        return self._rewrite_existing(
            self._lease_path(ticket.file_name, ticket.owner or ""), ticket)

    def complete(self, ticket: Ticket, status: str = "complete",
                 now: Optional[float] = None) -> bool:
        """Finish ``ticket``: atomically move *this owner's* lease to
        ``done/`` and record the outcome. Returns False when the lease no
        longer exists under this owner (stolen or reclaimed) — the caller's
        local results are still valid (the merge dedupes), but the queue's
        completion credit went elsewhere."""
        now = time.time() if now is None else now
        src = self._lease_path(ticket.file_name, ticket.owner or "")
        dst = self.root / DONE / ticket.file_name
        try:
            self._fs.rename(src, dst)
        except FileNotFoundError:
            return False
        ticket.status, ticket.done_at = status, now
        ticket.deadline = None
        self._rewrite_existing(dst, ticket)  # done files never move again
        return True

    # -- reclaiming and stealing -------------------------------------------
    def _expire_lease(self, lease_file: Path, *, steal: bool,
                      now: float) -> Optional[Ticket]:
        """Move one leased ticket back to pending (the shared tail of
        reclaim/release/steal): the claim is the atomic rename; the content
        rewrite clears the lease and, for a steal, bumps ``steals``.
        Returns the pending ticket, or ``None`` when the rename lost a race
        (the owner completed, or another reclaimer got there first)."""
        parsed = self._split_lease_name(lease_file.name)
        if parsed is None:
            return None
        file_name, owner = parsed
        t = self._read(lease_file)
        dst = self.root / PENDING / file_name
        try:
            self._fs.rename(lease_file, dst)
        except FileNotFoundError:
            return None
        if t is None:
            t = Ticket(*self._cell_of(file_name), attempt=1)
        if steal:
            t.steals += 1
        t.owner, t.leased_at, t.deadline = None, None, None
        t.status, t.done_at = None, None
        # no-create: if an acquirer claimed the pending file in this
        # window, rewriting would fork the ticket into two states (the
        # steal/reclaim accounting for this instant is forfeited instead)
        self._rewrite_existing(dst, t)
        return t

    def reclaim_expired(self, now: Optional[float] = None) -> List[Ticket]:
        """Move every leased ticket whose deadline has passed back to
        ``pending`` (presumed-orphaned lease — see module docstring; a
        content-less lease falls back to file mtime + this queue's
        ``lease_s``). Returns the reclaimed tickets."""
        now = time.time() if now is None else now
        out = []
        for f in self._fs.glob(self._state_dir(LEASED), "*.json*"):
            if ".tmp" in f.name:
                continue
            t = self._read(f)
            deadline = t.deadline if t is not None else None
            if deadline is None:
                try:
                    deadline = self._fs.mtime(f) + self.lease_s
                except OSError:
                    continue
            if now > deadline:
                r = self._expire_lease(f, steal=False, now=now)
                if r is not None:
                    out.append(r)
        return out

    def release_owner(self, owner: str, now: Optional[float] = None,
                      ) -> List[Ticket]:
        """Immediately reclaim every lease held by ``owner`` — the
        supervisor's move when it *knows* the owner died (nonzero exit /
        hang kill) and waiting out the deadline would idle the fleet.
        Returns the released tickets."""
        owner = sanitize_owner(owner)
        now = time.time() if now is None else now
        out = []
        for f in self._fs.glob(self._state_dir(LEASED),
                               f"*{LEASE_INFIX}{owner}"):
            r = self._expire_lease(f, steal=False, now=now)
            if r is not None:
                out.append(r)
        return out

    def steal(self, ticket: Ticket, now: Optional[float] = None,
              ) -> Optional[Ticket]:
        """Forcibly expire ``ticket``'s current lease (work rebalancing: the
        owner is alive but far behind the fleet — see the orchestrator's
        steal rule). The ticket returns to ``pending`` with ``steals``
        bumped, ready for an idle owner to acquire; the slow owner's
        eventual :meth:`complete` will return False. Returns the pending
        ticket, or ``None`` when the owner completed first (steal lost the
        race — that is the correct outcome, not an error)."""
        now = time.time() if now is None else now
        if ticket.owner is None:
            return None
        return self._expire_lease(
            self._lease_path(ticket.file_name, ticket.owner),
            steal=True, now=now)

    @staticmethod
    def _cell_of(file_name: str) -> Tuple[str, str]:
        """``(arch, shape)`` parsed back out of a ticket file name."""
        stem = file_name[:-len(".json")]
        arch, _, shape = stem.partition("__")
        return arch, shape
