"""Pluggable shard-executor backends for the campaign orchestrator.

``run_orchestrator`` supervises n shard subprocesses but does not care *where*
they run. This module owns the shard lifecycle behind the
:class:`ShardExecutor` protocol — ``spawn`` / ``poll`` / ``read_heartbeat`` /
``signal`` / ``collect`` — so the healing and merge logic in
``repro.launch.orchestrator`` stays executor-agnostic:

* :class:`LocalProcessExecutor` — today's behavior: each shard is a local
  ``python -m repro.launch.campaign`` subprocess in its own session/process
  group (so a kill reaches the evaluator pool workers too), heartbeats read
  from the shard dir's ``progress.json``;
* :class:`SSHExecutor` — the same campaign argv dispatched to a remote host
  over ``ssh``: the remote process group is tracked via a ``shard.pid`` file,
  heartbeats are fetched by ``cat``-ing the remote ``progress.json``, and the
  remote shard dir is rsync'd back into ``OUT/shards/shard{i}`` before the
  merge, so ``merge_db`` never knows the shard ran elsewhere. Requires: the
  repo checked out on every host (``remote_repo``, default: this checkout's
  path), passwordless ssh, and ``rsync`` on both ends. Exit codes propagate
  through ssh, so crash detection is identical to the local backend;
* :class:`LoopbackExecutor` — SSHExecutor with its transport stubbed to
  local ``/bin/sh`` (and the copy-back to ``cp``): every remote-dispatch code
  path — command templating, pid-file group kill, heartbeat fetch, collect —
  runs on this machine with no network, so tests and CI exercise the ssh
  seam on every PR.

Selected by ``--executor local|ssh|loopback`` (+ ``--hosts h0,h1,...``) on
``repro.launch.orchestrator``. Pure supervision: never imports jax.
"""
from __future__ import annotations

import json
import os
import shlex
import subprocess
import sys
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Protocol, Sequence

from repro.launch.campaign import read_progress

PID_FILE = "shard.pid"
#: env vars forwarded into remote shard processes (test/CI hooks + the
#: dry-run device-count override); everything else stays host-local
FORWARD_ENV_PREFIXES = ("REPRO_",)
FORWARD_ENV_NAMES = ("DRYRUN_XLA_FLAGS",)


@dataclass
class ShardProc:
    """Supervisor-side state for one shard: its launch command, local output
    dir, the live local process handle (the campaign itself, or the ssh
    client driving a remote one), restart count, and the last heartbeat
    payload/time used for hang detection. Lifecycle behavior lives in the
    :class:`ShardExecutor` that owns the shard."""

    index: int
    out_dir: Path
    cmd: List[str]
    env: Dict[str, str]
    proc: Optional[subprocess.Popen] = None
    log_handle: Optional[object] = None
    restarts: int = 0
    done: bool = False
    failed: bool = False
    last_beat: float = field(default_factory=time.time)
    last_payload: Dict = field(default_factory=dict)

    @property
    def log_path(self) -> Path:
        """The shard's combined stdout+stderr log (appended across restarts,
        so post-mortems see every attempt; for remote shards this captures
        the ssh client's view of the remote output)."""
        return self.out_dir / "shard.log"

    def spawn_local(self, argv: List[str]) -> None:
        """(Re)launch ``argv`` as a local subprocess, appending to the log
        file. The child leads its own session/process group so
        :meth:`signal_group` reaches its evaluator pool workers too."""
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self.log_handle = self.log_path.open("ab")
        self.proc = subprocess.Popen(argv, stdout=self.log_handle,
                                     stderr=subprocess.STDOUT, env=self.env,
                                     start_new_session=True)
        self.last_beat = time.time()

    def signal_group(self, sig: int) -> None:
        """Deliver ``sig`` to the local process group (the campaign process
        AND its spawned compile-pool workers — killing only the leader would
        orphan workers that keep burning CPU against the restarted attempt).
        Falls back to signalling the leader alone if the group is already
        gone; a fully-reaped shard is a no-op."""
        if self.proc is None:
            return
        try:
            os.killpg(self.proc.pid, sig)  # pgid == pid (start_new_session)
        except (ProcessLookupError, PermissionError):
            try:
                self.proc.send_signal(sig)
            except (ProcessLookupError, OSError):
                pass

    def close_log(self) -> None:
        """Close the log handle (idempotent)."""
        if self.log_handle is not None:
            self.log_handle.close()
            self.log_handle = None


class ShardExecutor(Protocol):
    """Where and how shard campaigns run. ``run_orchestrator`` drives the
    whole heal/merge contract through these five calls, so a backend only
    has to answer: start the shard, is it alive, what does its heartbeat
    say, kill it (and its process group), and bring its output dir local."""

    name: str

    def spawn(self, shard: ShardProc) -> None:
        """(Re)launch the shard; must reset its heartbeat clock."""
        ...

    def poll(self, shard: ShardProc) -> Optional[int]:
        """Exit code if the shard finished, else ``None`` (still running)."""
        ...

    def read_heartbeat(self, shard: ShardProc) -> Dict:
        """Best-effort read of the shard's live ``progress.json`` payload;
        ``{}`` means no news (missing/torn/unreachable), never a crash."""
        ...

    def signal(self, shard: ShardProc, sig: int) -> None:
        """Deliver ``sig`` to the shard's whole process group, wherever it
        runs; a no-op for an already-reaped shard."""
        ...

    def collect(self, shard: ShardProc) -> None:
        """Make the shard's output dir available at ``shard.out_dir`` on
        this machine (no-op when it already is) so ``merge_db`` can fold it
        in without knowing the backend."""
        ...


@dataclass
class LocalProcessExecutor:
    """Shards as local subprocesses — the original ``run_orchestrator``
    behavior (own session per shard, process-group kill, heartbeat file
    read straight from the shard dir)."""

    name: str = "local"

    def spawn(self, shard: ShardProc) -> None:
        """Launch the shard's campaign argv locally (fresh attempt appends
        to the same log)."""
        shard.spawn_local(shard.cmd)

    def poll(self, shard: ShardProc) -> Optional[int]:
        """Local ``Popen.poll``."""
        return shard.proc.poll() if shard.proc is not None else None

    def read_heartbeat(self, shard: ShardProc) -> Dict:
        """Read ``progress.json`` from the local shard dir."""
        return read_progress(shard.out_dir)

    def signal(self, shard: ShardProc, sig: int) -> None:
        """Process-group kill (see :meth:`ShardProc.signal_group`)."""
        shard.signal_group(sig)

    def collect(self, shard: ShardProc) -> None:
        """No-op: the shard already ran in ``shard.out_dir``."""


@dataclass
class SSHExecutor:
    """Shards dispatched to remote hosts over ssh (see module docstring).

    Host assignment is round-robin over ``hosts`` by shard index. The remote
    shard dir is ``{remote_root}/shard{i}`` when ``remote_root`` is set,
    else the *same absolute path* as the local shard dir (the shared-FS /
    identical-layout convention). The remote command writes the campaign's
    pid (a ``setsid`` session leader) to ``shard.pid`` so :meth:`signal`
    can kill the whole remote process group; ssh propagates the campaign's
    exit code, so :meth:`poll` is just the local client's ``Popen.poll``.
    Restart-with-resume works unchanged: the remote dir persists between
    attempts, so completed cells skip and cached compiles replay."""

    hosts: Sequence[str]
    remote_root: Optional[str] = None
    remote_repo: Optional[str] = None  # default: this checkout's path
    python: str = "python3"
    ssh_options: Sequence[str] = ("-o", "BatchMode=yes",
                                  "-o", "ConnectTimeout=5")
    transport_timeout: float = 5.0  # seconds per heartbeat/kill round-trip;
    #   heartbeat fetches run serially in the supervisor poll loop, so one
    #   dead host must not stall the other shards' hang clocks for long
    name: str = "ssh"
    #: whether the "remote" command actually runs on this machine (the
    #: loopback subclass): local transports may combine a relocated
    #: remote_root with a campaign --queue path, genuinely remote ones
    #: may not (the queue must sit at one shared-filesystem path)
    transport_is_local: bool = False

    def __post_init__(self):
        """Validate hosts and default the remote repo to this checkout."""
        if not self.hosts:
            raise ValueError("SSHExecutor needs at least one host "
                             "(--hosts h0,h1,...)")
        if self.remote_repo is None:
            self.remote_repo = str(Path(__file__).resolve().parents[3])

    # -- transport seam (LoopbackExecutor overrides exactly these two) -----
    def _transport_argv(self, host: str, command: str) -> List[str]:
        """The local argv that runs ``command`` in a shell on ``host``."""
        return ["ssh", *self.ssh_options, host, command]

    def _copy_back_argv(self, host: str, remote_dir: str,
                        local_dir: str) -> List[str]:
        """The local argv that mirrors ``host:remote_dir`` into
        ``local_dir`` (trailing-slash rsync semantics: contents, not the
        dir itself)."""
        return ["rsync", "-a", f"{host}:{remote_dir}/", f"{local_dir}/"]

    # ----------------------------------------------------------------------
    def host_for(self, shard: ShardProc) -> str:
        """Round-robin host assignment, stable across restarts."""
        return self.hosts[shard.index % len(self.hosts)]

    def remote_dir(self, shard: ShardProc) -> str:
        """The shard's output dir on its host (see class docstring)."""
        if self.remote_root:
            return f"{self.remote_root.rstrip('/')}/shard{shard.index}"
        return str(Path(shard.out_dir).resolve())

    def _forward_env(self, shard: ShardProc) -> Dict[str, str]:
        """The env slice shipped to the remote process: test/CI hooks
        (``REPRO_*``, ``DRYRUN_XLA_FLAGS`` — their values must be valid on
        the remote host) plus a PYTHONPATH pointing at the remote checkout."""
        env = {k: v for k, v in shard.env.items()
               if k.startswith(FORWARD_ENV_PREFIXES) or k in FORWARD_ENV_NAMES}
        env["PYTHONPATH"] = f"{self.remote_repo}/src"
        return env

    def remote_command(self, shard: ShardProc) -> str:
        """The one-line shell command ssh runs on the host: create the shard
        dir, kill any stale process group from a previous attempt (a
        restart may follow a :meth:`signal` whose transport round-trip was
        lost — two campaigns must never share a shard dir), then ``setsid
        -w`` the campaign (pid recorded to ``shard.pid``, ``exec`` so pid
        == session/group leader, ``-w`` so the exit code propagates back
        through ssh) with the shard's argv re-targeted at the remote
        python and remote ``--out`` dir."""
        rdir = self.remote_dir(shard)
        qdir = shlex.quote(rdir)
        argv = list(shard.cmd)
        argv[0] = self.python
        argv[argv.index("--out") + 1] = rdir
        if ("--queue" in argv and self.remote_root
                and not self.transport_is_local):
            # --out relocates per-host, but the lease queue is the shards'
            # rendezvous: it must resolve to ONE shared-filesystem path
            # everywhere, which remote_root relocation cannot guarantee
            raise RuntimeError(
                f"shard{shard.index}: campaign --queue cannot combine with "
                f"remote_root={self.remote_root!r} on a remote transport — "
                f"the queue dir must be one shared path on every host")
        env = " ".join(f"{k}={shlex.quote(v)}"
                       for k, v in sorted(self._forward_env(shard).items()))
        inner = (f"echo $$ > {qdir}/{PID_FILE}; "
                 f"exec env {env} {shlex.join(argv)}")
        # no `--` before the negative pgid: dash's builtin kill rejects it
        stale = (f"if [ -f {qdir}/{PID_FILE} ]; then "
                 f"kill -9 \"-$(cat {qdir}/{PID_FILE})\" 2>/dev/null "
                 f"|| true; fi")
        return (f"mkdir -p {qdir} && {stale} && "
                f"exec setsid -w bash -c {shlex.quote(inner)}")

    def _run_transport(self, shard: ShardProc, command: str,
                       ) -> Optional[subprocess.CompletedProcess]:
        """Run a short remote command (heartbeat fetch, kill); ``None`` on
        timeout/transport failure — the caller treats that as no news."""
        try:
            return subprocess.run(
                self._transport_argv(self.host_for(shard), command),
                capture_output=True, text=True,
                timeout=self.transport_timeout)
        except (subprocess.TimeoutExpired, OSError):
            return None

    # -- ShardExecutor protocol --------------------------------------------
    def spawn(self, shard: ShardProc) -> None:
        """Launch the ssh client driving the remote campaign; its combined
        output (the remote stdout+stderr) appends to the local shard log."""
        shard.spawn_local(
            self._transport_argv(self.host_for(shard),
                                 self.remote_command(shard)))

    def poll(self, shard: ShardProc) -> Optional[int]:
        """Local client ``poll`` — ssh exits with the remote exit code."""
        return shard.proc.poll() if shard.proc is not None else None

    def read_heartbeat(self, shard: ShardProc) -> Dict:
        """Fetch and parse the remote ``progress.json``; ``{}`` for a
        missing/torn file or an unreachable host (no news, never a crash)."""
        r = self._run_transport(
            shard, f"cat {shlex.quote(self.remote_dir(shard))}/progress.json")
        if r is None or r.returncode != 0:
            return {}
        try:
            return json.loads(r.stdout)
        except json.JSONDecodeError:
            return {}

    def signal(self, shard: ShardProc, sig: int) -> None:
        """Kill the remote process group via the recorded ``shard.pid``
        (session leader ⇒ pgid == pid), then the local ssh client's group —
        both best-effort, so a dead host or reaped client is a no-op."""
        pid_file = f"{shlex.quote(self.remote_dir(shard))}/{PID_FILE}"
        # no `--` before the negative pgid: dash's builtin kill rejects it
        self._run_transport(
            shard, f"kill -{int(sig)} \"-$(cat {pid_file})\" 2>/dev/null")
        shard.signal_group(sig)

    def collect(self, shard: ShardProc) -> None:
        """Mirror the remote shard dir into the local ``shard.out_dir`` so
        the merge (and post-mortems) read local files only. Skipped when
        the two are already the same path on this machine; raises
        ``RuntimeError`` when the copy-back fails (a merge over a missing
        shard would silently drop its cells)."""
        rdir = self.remote_dir(shard)
        if self._is_local_alias(shard, rdir):
            return
        shard.out_dir.mkdir(parents=True, exist_ok=True)
        argv = self._copy_back_argv(self.host_for(shard), rdir,
                                    str(shard.out_dir))
        r = subprocess.run(argv, capture_output=True, text=True)
        if r.returncode != 0:
            raise RuntimeError(
                f"shard{shard.index}: collect failed ({shlex.join(argv)}): "
                f"{(r.stderr or r.stdout).strip()}")

    def _is_local_alias(self, shard: ShardProc, rdir: str) -> bool:
        """Whether the remote dir IS the local shard dir (loopback with no
        ``remote_root``: copying a dir onto itself would be destructive)."""
        return False  # a genuinely remote path never aliases a local one


@dataclass
class LoopbackExecutor(SSHExecutor):
    """:class:`SSHExecutor` with the network stubbed out — the "remote"
    command runs under local ``/bin/sh`` and the copy-back is a local
    ``cp -a``, everything else (command templating, pid-file group kill,
    heartbeat fetch, collect-before-merge) is the real ssh code path. This
    is the executor CI runs so the remote-dispatch seam cannot rot between
    PRs; it is also a correct single-machine backend in its own right."""

    hosts: Sequence[str] = ("loopback",)
    python: str = sys.executable
    name: str = "loopback"
    transport_is_local: bool = True

    def _transport_argv(self, host: str, command: str) -> List[str]:
        """Run the would-be-remote shell command locally."""
        return ["/bin/sh", "-c", command]

    def _copy_back_argv(self, host: str, remote_dir: str,
                        local_dir: str) -> List[str]:
        """Local ``cp -a`` with rsync's contents-into-dir semantics."""
        return ["/bin/sh", "-c",
                f"cp -a {shlex.quote(remote_dir)}/. {shlex.quote(local_dir)}/"]

    def _is_local_alias(self, shard: ShardProc, rdir: str) -> bool:
        """With no ``remote_root`` the shard already ran in its local dir."""
        return Path(rdir).resolve() == Path(shard.out_dir).resolve()


EXECUTOR_CHOICES = ("local", "ssh", "loopback")


def make_executor(kind: str, *, hosts: Optional[Sequence[str]] = None,
                  remote_root: Optional[str] = None,
                  remote_repo: Optional[str] = None,
                  remote_python: str = "python3") -> ShardExecutor:
    """Build the shard executor for an ``--executor`` choice. ``ssh``
    requires ``hosts``; ``local`` ignores every remote option; ``loopback``
    defaults its single pseudo-host and this interpreter. Raises
    ``ValueError`` on an unknown kind or a host-less ssh request."""
    if kind == "local":
        return LocalProcessExecutor()
    if kind == "ssh":
        return SSHExecutor(hosts=list(hosts or []), remote_root=remote_root,
                           remote_repo=remote_repo, python=remote_python)
    if kind == "loopback":
        return LoopbackExecutor(remote_root=remote_root,
                                remote_repo=remote_repo)
    raise ValueError(
        f"unknown executor {kind!r}; choose from {EXECUTOR_CHOICES}")
