"""Multi-workload DSE campaign engine.

Sweeps an ``arch x shape x mesh`` grid of SECDA-DSE loops with shared
infrastructure: one cost DB (so the surrogate cost model and RAG retrieval
learn across workloads), one content-addressed dry-run cache (so designs
re-proposed in another cell never recompile), and one process pool sizing
knob. Every cell writes a loop-report JSON; the campaign is *resumable* —
re-running the same command skips cells whose reports exist and re-serves
cached dry-runs for partially-explored cells — and finishes with a
leaderboard JSON ranking the best design found per cell.

Quickstart:

    PYTHONPATH=src python -m repro.launch.campaign \\
        --archs qwen3-0.6b,stablelm-3b --shapes train_4k,decode_32k \\
        --mesh small --iterations 2 --budget 3 --workers 2 \\
        --out artifacts/campaign

    # interrupted? same command again: completed cells are skipped, the
    # shared dry-run cache makes re-entered cells near-instant
    PYTHONPATH=src python -m repro.launch.campaign ... (same args)

Search policy and surrogate gating (see ``repro.search``):

    --strategy {greedy,llm,anneal,evolve,ensemble}
        proposal engine per cell (default ``ensemble``: budget split across
        all strategies with bandit credit reallocation, provenance in the
        cost DB ``source`` field)
    --gate-factor F
        enable the SurrogateGate: candidates whose *predicted* bound is
        > F x the incumbent are recorded as ``pruned`` data points instead
        of compiled; auto-disabled until the surrogate's held-out
        validation RMSE clears the calibration guard
    --measure-top-k K
        promotion ladder tier 2 (``repro.search.ladder``): after a cell
        finishes, its K best designs are *executed and timed* — measured
        rows land in the cost DB (``fidelity="measured"``), surface as the
        leaderboard's ``measured_us`` column, replay from the shared
        ``measured_cache/`` on resume/steal (exactly-once measurement), and
        feed prediction-vs-measured RMSE back into the gate's factor
        annealing; with ``--gate-factor`` set the gate is the
        :class:`~repro.search.ladder.PromotionLadder`
    --objective {bound_s,pareto}
        leaderboard ranking mode: ``bound_s`` (default) keeps the scalar
        bound and produces byte-identical leaderboards to pre-Pareto
        campaigns; ``pareto`` ranks designs by objective-vector dominance
        (``repro.core.pareto``), emits each cell's non-dominated front,
        promotes the measured tier along the front, and adds
        scalarization-weight arms to the ensemble

Scale-out over processes/hosts — shard the grid, then merge (or let
``repro.launch.orchestrator`` spawn, supervise, and merge the shards for
you in one command):

    # shard i/n deterministically partitions the sorted arch x shape grid
    PYTHONPATH=src python -m repro.launch.campaign ... \\
        --out artifacts/shard0 --shard 0/2
    PYTHONPATH=src python -m repro.launch.campaign ... \\
        --out artifacts/shard1 --shard 1/2

    # merge shard DBs + reports + caches, rebuild one leaderboard
    # (dedup by (arch, shape, mesh, design key), earliest record wins)
    PYTHONPATH=src python -m repro.launch.merge_db \\
        artifacts/shard0 artifacts/shard1 --out artifacts/campaign

Dynamic scale-out — ``--queue DIR`` replaces the static cut with a
crash-safe file-backed cell queue (``repro.launch.scheduler``): each worker
pulls its next cell from the queue under a deadline-bounded lease instead
of iterating a pre-cut slice, so fast workers drain more of the grid and a
slow or dead worker's cell is re-leased (or stolen by the orchestrator)
instead of stalling the campaign. Identical commands cooperate: the first
to start seeds the queue (idempotent), every worker shares the queue-side
dry-run cache (a re-leased cell's compiles replay instead of re-running),
and the merge is the same ``merge_db`` flow:

    PYTHONPATH=src python -m repro.launch.campaign ... \\
        --out artifacts/shard0 --queue artifacts/queue --queue-owner w0
    PYTHONPATH=src python -m repro.launch.campaign ... \\
        --out artifacts/shard1 --queue artifacts/queue --queue-owner w1

With the deterministic mock LLM, an untrained (or cell-local) surrogate,
and a transfer-free strategy, a sharded run + merge reproduces the
single-process ``leaderboard.json`` byte-for-byte — tier-1 asserts it
(``tests/test_merge_db.py``). The ``transfer`` / ``ensemble+transfer``
strategies deliberately couple cells through the shared DB (warm starts
from similar cells), so with them a shard layout is its own experiment.

Outputs under --out:
    cost_db.jsonl                     shared hardware-datapoint DB
    dryrun_cache/                     content-addressed compile cache
    measured_cache/                   content-addressed tier-2 timing cache
                                      (queue mode: lives in the queue dir)
    reports/{arch}__{shape}__{mesh}.json   per-cell loop reports
    leaderboard.json                  cells ranked by best bound_s
    BENCH_ladder.json                 auditable ladder trajectory: per-tier
                                      eval counts, calibration RMSE
                                      (validation + measured), incumbent
                                      bound per iteration per cell
    progress.json                     live heartbeat (atomically replaced
                                      after every loop iteration, every
                                      completed evaluation batch, and every
                                      cell boundary; the orchestrator's
                                      hang detection and leaderboard
                                      aggregation read it)

Heartbeat payload contract (what the orchestrator and dashboards rely on):
``evaluations`` / ``compiles`` / ``pruned`` are *run-local* — they count
only this attempt's work, so a shard restarted with resume never appears to
redo the cells it skipped; the cumulative view (prior attempts included)
lives under ``evaluations_total`` / ``compiles_total`` / ``pruned_total``.
``cell_in_progress`` ("arch/shape") and ``iteration`` identify the work
mid-cell (both null at cell boundaries), and ``iter_evaluated`` /
``iter_compiled`` / ``iter_pruned`` / ``iter_cache_hits`` carry the last
iteration's deltas. Because the heartbeat moves at proposal/batch/
iteration granularity, a supervisor hang timeout only has to exceed the
slowest single iteration step, never a whole cell. In queue mode the
payload gains a ``queue`` sub-dict (pending/leased/done counts, this
worker's ``owner`` id, ``stolen`` = leases this worker lost mid-cell), the
``status`` value ``waiting`` marks an idle worker polling for cells still
leased elsewhere (the orchestrator's steal rule keys off it), and every
beat renews the worker's current lease.

Test/CI hooks (environment variables, ignored when unset):
    REPRO_CAMPAIGN_PRELUDE      path to a python file exec()d by ``main()``
                                before any jax-touching import — CI shrinks
                                configs to 64-token cells this way so shard
                                subprocesses compile in seconds
    REPRO_CAMPAIGN_CRASH_TOKEN  one-shot crash injection: once the file at
                                this path exists and CRASH_AFTER_CELLS cells
                                finished, the file is unlinked and the
                                process dies via os._exit(86) at a cell
                                boundary (the orchestrator restart test)

Unlike the other launchers this module is import-safe (tests import
``build_leaderboard``/``run_campaign``): XLA_FLAGS is set inside ``main()``,
before the first jax-touching import, never at import time.
"""
import argparse
import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.ioutil import write_json_atomic
from repro.launch.scheduler import CellQueue, sanitize_owner

__all__ = [
    "build_leaderboard", "build_parser", "cell_report_path",
    "make_campaign_mesh", "parse_shard", "read_progress", "resolve_grid",
    "run_campaign", "shard_cells", "validate_gate_args",
    "validate_measure_args", "validate_objective_args", "write_json_atomic",
    "write_progress",
]

PROGRESS_FILE = "progress.json"
MESH_CHOICES = ("tiny", "small", "pod", "multipod")
STRATEGY_CHOICES = ("greedy", "llm", "anneal", "evolve", "transfer",
                    "ensemble", "ensemble+transfer")
#: leaderboard ranking modes: the scalar bound (byte-compatible with every
#: pre-Pareto campaign) or the dominance-ranked multi-objective front
OBJECTIVE_CHOICES = ("bound_s", "pareto")


def cell_report_path(out_dir: Path, arch: str, shape: str, mesh_name: str) -> Path:
    """Canonical per-cell report location: ``reports/{arch}__{shape}__{mesh}.json``
    under the campaign dir (``merge_db`` parses cells back out of the name)."""
    return Path(out_dir) / "reports" / f"{arch}__{shape}__{mesh_name}.json"


def resolve_grid(archs: str, shapes: str) -> Tuple[List[str], List[str]]:
    """Expand the CLI ``--archs`` / ``--shapes`` strings (comma-separated ids
    or the literal ``all``) into validated name lists. Raises ``ValueError``
    naming every unknown id — shared by the campaign and orchestrator CLIs so
    the two can never drift."""
    from repro.configs import ARCH_NAMES, SHAPES

    arch_list = list(ARCH_NAMES) if archs == "all" else archs.split(",")
    shape_list = ([s.name for s in SHAPES] if shapes == "all"
                  else shapes.split(","))
    unknown = [a for a in arch_list if a not in ARCH_NAMES]
    unknown += [s for s in shape_list if s not in {c.name for c in SHAPES}]
    if unknown:
        raise ValueError(f"unknown arch/shape: {unknown}")
    return arch_list, shape_list


def make_campaign_mesh(name: str):
    """Build the jax mesh for a ``--mesh`` choice; returns ``(mesh,
    mesh_name)``. Must only be called after XLA_FLAGS is pinned (jax locks
    the device count at first init); ``tiny`` (1x1) exists so smoke tests
    and CI runs need a single device."""
    from repro.launch.mesh import make_mesh, make_production_mesh

    if name == "pod":
        return make_production_mesh(), "pod16x16"
    if name == "multipod":
        return make_production_mesh(multi_pod=True), "multipod2x16x16"
    if name == "tiny":
        return make_mesh((1, 1), ("data", "model")), "tiny1x1"
    return make_mesh((2, 4), ("data", "model")), "small2x4"


def shard_cells(archs: Sequence[str], shapes: Sequence[str],
                shard: Optional[Tuple[int, int]] = None,
                ) -> List[Tuple[str, str]]:
    """The campaign's (arch, shape) work list: the full grid in sorted order
    (so every shard agrees on cell numbering), optionally keeping only cells
    whose index ``% n == i`` for ``shard=(i, n)``. Disjoint and exhaustive:
    the union over all shards is exactly the unsharded list."""
    cells = sorted({(a, s) for a in archs for s in shapes})
    if shard is None:
        return cells
    i, n = shard
    if not (0 <= i < n):
        raise ValueError(f"shard index {i} outside 0..{n - 1}")
    return cells[i::n]


def _cell_report(report) -> Dict:
    return {
        "arch": report.arch, "shape": report.shape,
        "baseline": report.baseline.__dict__ if report.baseline else None,
        "best": report.best.__dict__ if report.best else None,
        "iterations": report.iterations,
        "improvement": report.improvement(),
    }


def build_leaderboard(db, cell_rows: Sequence[Dict],
                      objective: str = "bound_s") -> List[Dict]:
    """Rank completed cells by their best achieved bound (fastest first);
    cells with no feasible design sink to the bottom with their failure
    mode preserved. Cells with tier-2 rows report ``measured_us`` (and the
    backend that produced it) alongside the analytical bound, preferring
    the measurement of the cell's best design, so modeled-vs-real error is
    visible per row; ranking stays on the bound.

    ``objective="pareto"`` ranks each cell's designs by objective-vector
    dominance instead (``CostDB.pareto``): the representative design
    becomes the deterministic front head, and every row gains
    ``objective`` / ``front`` (the rank-0 non-dominated set, each entry
    ``{point, objectives, crowding}`` with boundary ``inf`` crowding
    serialized as null) / ``front_size``. The default scalar mode adds no
    keys and reorders nothing — its output is byte-identical to
    pre-Pareto leaderboards, which CI pins against a committed fixture."""
    from repro.core.promotion import select_measured_row  # jax-free

    err = validate_objective_args(objective)
    if err:
        raise ValueError(err)
    pareto = objective == "pareto"
    rows = []
    for c in cell_rows:
        front = []
        if pareto:
            ranked = db.pareto(c["arch"], c["shape"], mesh=c["mesh"])
            front = [(d, crowd, objs) for d, rank, crowd, objs in ranked
                     if rank == 0]
            best = ranked[0][0] if ranked else None
        else:
            best = db.best(c["arch"], c["shape"], mesh=c["mesh"])
        feasible = best is not None
        if best is None:
            # negative datapoints still rank: the fastest *infeasible* design
            # tells the reader how far off the memory budget this cell is
            cands = [d for d in db.query(c["arch"], c["shape"], mesh=c["mesh"])
                     if d.metrics.get("bound_s")]
            best = (min(cands, key=lambda d: d.metrics["bound_s"])
                    if cands else None)
        row = {
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "status": c["status"],
            "feasible": feasible if best is not None else None,
            # dry-run-evaluated designs only: gate-pruned rows are
            # predictions and tier-2 rows re-time an already-counted design —
            # either would overstate how thoroughly a cell was explored
            "n_points": sum(d.status != "pruned" and d.fidelity != "measured"
                            for d in
                            db.query(c["arch"], c["shape"], mesh=c["mesh"])),
            "improvement": c.get("improvement"),
            "bound_s": None, "mfu_at_bound": None, "dominant": None,
            "per_device_gib": None, "best_point": None,
            "measured_us": None, "measured_backend": None,
        }
        if best is not None:
            row.update(
                bound_s=best.metrics.get("bound_s"),
                mfu_at_bound=best.metrics.get("mfu_at_bound"),
                dominant=best.metrics.get("dominant"),
                per_device_gib=best.metrics.get("per_device_gib"),
                # sorted: identical serialization whether the DB is the live
                # in-memory one or re-read from JSONL (to_json sorts keys),
                # so a sharded run + merge_db reproduces this byte-for-byte
                best_point={k: v for k, v in sorted(best.point.items())
                            if k != "__key__"},
            )
        if pareto:
            row["objective"] = "pareto"
            # rank-0 entries in deterministic front order; inf crowding
            # (boundary points) serializes as null — the file must stay
            # strict-JSON parseable by any reader
            row["front"] = [
                {"point": {k: v for k, v in sorted(d.point.items())
                           if k != "__key__"},
                 "objectives": {k: objs[k] for k in sorted(objs)},
                 "crowding": (None if crowd == float("inf") else crowd)}
                for d, crowd, objs in front]
            row["front_size"] = len(row["front"])
        measured = [d for d in db.measured_rows(c["arch"], c["shape"],
                                                mesh=c["mesh"])
                    if d.status == "ok"]
        if best is not None:
            of_best = [d for d in measured
                       if d.point.get("__key__") == best.point.get("__key__")]
            measured = of_best or measured
        m = select_measured_row(measured)
        if m is not None:
            row.update(measured_us=m.metrics.get("measured_us"),
                       measured_backend=m.metrics.get("backend"))
        rows.append(row)
    rows.sort(key=lambda r: (r["bound_s"] is None, r["feasible"] is not True,
                             r["bound_s"] if r["bound_s"] is not None else 0.0))
    return rows


def validate_gate_args(gate_factor: Optional[float],
                       gate_min_factor: Optional[float]) -> Optional[str]:
    """The one place the surrogate-gate CLI constraints live (returns an
    error string, or ``None`` when valid) — shared by the campaign, dse,
    and orchestrator CLIs *and* by ``run_campaign``'s API validation, so
    the four surfaces can never drift from each other or from
    ``SurrogateGate.__post_init__``'s own check."""
    if gate_factor is not None and gate_factor <= 1.0:
        return (f"gate-factor must be > 1 (got {gate_factor}): the gate "
                "prunes candidates predicted SLOWER than factor x the "
                "incumbent")
    if gate_min_factor is not None:
        if gate_factor is None:
            return ("gate-min-factor requires gate-factor (annealing "
                    "tightens the gate's threshold; there is no gate "
                    "without a factor)")
        if not (1.0 < gate_min_factor <= gate_factor):
            return (f"gate-min-factor must be in (1, {gate_factor}], "
                    f"got {gate_min_factor}")
    return None


def validate_measure_args(measure_top_k: int, measure_runs: int,
                          measure_budget: Optional[int]) -> Optional[str]:
    """The measured-tier CLI constraints (returns an error string, or
    ``None`` when valid) — shared by the campaign, dse, and orchestrator
    CLIs and by ``run_campaign``'s API validation, mirroring
    :func:`validate_gate_args`."""
    if measure_top_k < 0:
        return f"measure-top-k must be >= 0, got {measure_top_k}"
    if measure_runs < 1:
        return f"measure-runs must be >= 1, got {measure_runs}"
    if measure_budget is not None:
        if measure_top_k <= 0:
            return ("measure-budget requires measure-top-k > 0: the budget "
                    "caps tier-2 promotions, and there are none without a "
                    "top-k")
        if measure_budget < 0:
            return f"measure-budget must be >= 0, got {measure_budget}"
    return None


def validate_objective_args(objective: str) -> Optional[str]:
    """The objective-mode CLI constraint (returns an error string, or
    ``None`` when valid) — shared by the campaign, dse, merge, and
    orchestrator CLIs and by ``run_campaign``/``build_leaderboard``'s API
    validation, mirroring :func:`validate_gate_args`."""
    if objective not in OBJECTIVE_CHOICES:
        return (f"objective must be one of {OBJECTIVE_CHOICES}, "
                f"got {objective!r}")
    return None


def write_progress(out_dir: Path, payload: Dict) -> Path:
    """Atomically replace ``progress.json`` under ``out_dir`` (see
    :func:`write_json_atomic`) so a concurrently-polling supervisor never
    reads a torn heartbeat. Returns the progress path."""
    return write_json_atomic(Path(out_dir) / PROGRESS_FILE, payload)


def read_progress(out_dir: Path) -> Dict:
    """Best-effort read of a shard's ``progress.json``: returns ``{}`` for a
    missing, torn, or mid-replace file (the supervisor treats that as 'no
    news', never as a crash)."""
    try:
        return json.loads((Path(out_dir) / PROGRESS_FILE).read_text())
    except (OSError, json.JSONDecodeError):
        return {}


def _injected_crash_hook(cells_done: int) -> None:
    """Test-only one-shot fault injection (see module docstring): when the
    ``REPRO_CAMPAIGN_CRASH_TOKEN`` file exists and ``cells_done`` reached
    ``REPRO_CAMPAIGN_CRASH_AFTER_CELLS`` (default 1), unlink the token and
    die abruptly — ``os._exit(86)``, no summary, no cleanup — at a cell
    boundary. The unlink disarms the fault, so a supervisor restart of the
    same command runs clean."""
    token = os.environ.get("REPRO_CAMPAIGN_CRASH_TOKEN")
    if not token:
        return
    after = int(os.environ.get("REPRO_CAMPAIGN_CRASH_AFTER_CELLS", "1"))
    p = Path(token)
    if cells_done >= after and p.exists():
        p.unlink()
        os._exit(86)


def run_campaign(archs: Sequence[str], shapes: Sequence[str], mesh, mesh_name: str,
                 *, out_dir: Path | str, iterations: int = 2, budget: int = 3,
                 workers: int = 1, llm_client=None, db=None, resume: bool = True,
                 strategy: str = "ensemble", gate_factor: Optional[float] = None,
                 gate_min_factor: Optional[float] = None,
                 measure_top_k: int = 0, measure_runs: int = 3,
                 measure_budget: Optional[int] = None,
                 objective: str = "bound_s",
                 shard: Optional[Tuple[int, int]] = None,
                 queue: Optional[Path | str] = None,
                 queue_owner: Optional[str] = None,
                 queue_lease_s: float = 300.0, queue_poll_s: float = 0.5,
                 verbose: bool = True) -> Dict:
    """Run (or resume) the grid — one deterministic ``shard=(i, n)`` slice
    of it, or (``queue=DIR``) whatever cells this worker wins from the
    shared :class:`~repro.launch.scheduler.CellQueue` — and return the
    campaign summary dict. Each cell gets a *fresh* search strategy
    (strategies carry per-cell state: walker position, population, bandit
    credit); the cost DB, dry-run cache, surrogate cost model, and
    evaluator pool are shared across cells. In queue mode the dry-run
    cache lives *in the queue dir* and is shared across every worker, so a
    re-leased or stolen cell replays its compiles instead of redoing them;
    leases are renewed on every heartbeat and a lease lost mid-cell
    (stolen/reclaimed) is surrendered gracefully — the local results stand
    and the merge dedupes."""
    # argument validation first — these raise before any jax-touching import
    if queue is not None and shard is not None:
        raise ValueError("--queue and --shard are mutually exclusive: the "
                         "queue replaces the static grid cut")
    if queue is not None and queue_poll_s <= 0:
        raise ValueError(f"queue_poll_s must be > 0 (got {queue_poll_s}): "
                         "0 busy-spins the idle-wait loop")
    gate_err = validate_gate_args(gate_factor, gate_min_factor)
    if gate_err:
        raise ValueError(gate_err)
    measure_err = validate_measure_args(measure_top_k, measure_runs,
                                        measure_budget)
    if measure_err:
        raise ValueError(measure_err)
    objective_err = validate_objective_args(objective)
    if objective_err:
        raise ValueError(objective_err)

    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.design_space import PlanPoint
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.core.llm_client import MockLLM
    from repro.core.llm_stack import LLMStack
    from repro.core.loop import DSELoop
    from repro.models import model as M
    from repro.core.promotion import plan_front_promotions, plan_promotions
    from repro.search import PromotionLadder, SurrogateGate, make_strategy

    out_dir = Path(out_dir)
    (out_dir / "reports").mkdir(parents=True, exist_ok=True)
    db = db or CostDB(out_dir / "cost_db.jsonl")
    q = CellQueue(queue, lease_s=queue_lease_s) if queue is not None else None
    owner = (sanitize_owner(queue_owner or f"pid{os.getpid()}")
             if q is not None else None)
    # queue mode shares one content-addressed cache across every worker —
    # that is what makes a stolen cell's "resume" free (compiles replay);
    # the measured cache rides the same mechanism so a stolen cell's tier-2
    # timings replay too (exactly-once measurement per design)
    cache = (DryRunCache(q.cache_dir) if q is not None
             else DryRunCache.beside(db.path))
    measured_cache = DryRunCache(q.measured_dir if q is not None
                                 else Path(db.path).parent / "measured_cache")
    evaluator = Evaluator(mesh, mesh_name, cache=cache,
                          max_workers=max(workers, 1),
                          artifact_dir=str(out_dir / "dryrun"),
                          measured_cache=measured_cache,
                          measure_runs=measure_runs)
    stack = LLMStack(client=llm_client or MockLLM(), db=db)
    cost_model = CostModel.create(in_dim=featurize({}, {}).shape[0])
    # with the measured tier on, the gate is the full promotion ladder:
    # same protocol, plus prediction-vs-measured RMSE in the annealing
    gate_cls = PromotionLadder if measure_top_k > 0 else SurrogateGate
    gate = (gate_cls(cost_model, factor=gate_factor,
                     min_factor=gate_min_factor)
            if gate_factor is not None else None)

    def log(msg):
        if verbose:
            print(f"[campaign {mesh_name}] {msg}", flush=True)

    t0 = time.time()
    cells = shard_cells(archs, shapes, shard) if q is None else []
    if q is not None:
        # idempotent: n identical commands race-free-seed the same queue
        # (cells already pending/leased/done are left alone)
        seeded = q.seed(shard_cells(archs, shapes), mesh=mesh_name)
        if seeded:
            log(f"queue {q.root}: seeded {seeded} cell ticket(s)")
    cell_rows: List[Dict] = []
    cell_best: List[Dict] = []  # {"cell": "arch/shape", "bound_s": float|None}
    counts = {"ran": 0, "resumed": 0, "unsupported": 0}
    qstats = {"stolen": 0}
    mstate = {"budget_left": measure_budget}  # campaign-wide tier-2 budget
    current_ticket: List[Optional[object]] = [None]  # the lease being worked

    # run-local counter baselines: the DB file (and, via the prior
    # heartbeat, the compile/prune totals) persist across supervisor
    # restarts, so raw counters would double-count the work a resumed
    # attempt skipped. The heartbeat reports this attempt's deltas under
    # the headline keys and keeps cumulative totals under *_total.
    prior_hb = read_progress(out_dir)
    evals0 = db.count()
    compiles0 = evaluator.compile_count
    pruned0 = evaluator.pruned_count
    compiles_prior = int(prior_hb.get("compiles_total", 0) or 0)
    pruned_prior = int(prior_hb.get("pruned_total", 0) or 0)

    cells_total = q.total() if q is not None else len(cells)

    def progress(status: str, *, cell: Optional[str] = None,
                 iteration: Optional[int] = None,
                 iter_stats: Optional[Dict] = None) -> None:
        # every beat doubles as a lease renewal: the queue's deadline only
        # expires when the worker has stopped making iteration progress
        if q is not None and current_ticket[0] is not None:
            try:
                q.renew(current_ticket[0])
            except OSError:
                pass
        top = sorted((r for r in cell_best if r["bound_s"] is not None),
                     key=lambda r: r["bound_s"])[:5]
        compiles = evaluator.compile_count - compiles0
        pruned = evaluator.pruned_count - pruned0
        evals = db.count()  # once per beat: count() copies the row cache
        payload = {
            "pid": os.getpid(), "mesh": mesh_name,
            "shard": f"{shard[0]}/{shard[1]}" if shard else None,
            "status": status,
            "cells_total": cells_total, "cells_done": len(cell_rows),
            **counts,
            "cell_in_progress": cell, "iteration": iteration,
            "evaluations": evals - evals0,
            "compiles": compiles, "pruned": pruned,
            "measured": evaluator.measured_count,
            "measured_replayed": evaluator.measured_replayed,
            "evaluations_total": evals,
            "compiles_total": compiles_prior + compiles,
            "pruned_total": pruned_prior + pruned,
            "best": top, "ts": round(time.time(), 3)}
        if q is not None:
            payload["queue"] = {**q.counts(), "owner": owner,
                                "stolen": qstats["stolen"]}
        if iter_stats:
            payload.update({f"iter_{k}": iter_stats.get(k) for k in
                            ("evaluated", "compiled", "pruned", "cache_hits",
                             "phase")})
        write_progress(out_dir, payload)

    def cell_heartbeat(arch: str, shape: str):
        """The per-iteration heartbeat callback threaded into DSELoop.run:
        refreshes progress.json after every loop iteration / evaluation
        batch so the supervisor's hang detection works mid-cell."""
        cell = f"{arch}/{shape}"

        def beat(info: Dict) -> None:
            progress("running", cell=cell, iteration=info.get("iteration"),
                     iter_stats=info)
        return beat

    def promote_heads(arch: str, shape: str) -> None:
        """Tier-2 promotion for one finished cell: measure its (up to)
        ``measure_top_k`` best designs. Runs for *complete and resumed*
        cells alike — on resume the DB already holds the measured rows, so
        ``plan_promotions`` dedupes them to nothing; on a stolen/re-leased
        cell the shard-local DB lacks the rows but the shared measured
        cache replays the timings, appending byte-identical rows that the
        merge dedupes to one. Under ``objective="pareto"`` the heads come
        in Pareto front order (``CostDB.front``) so measured execution
        covers the front, not just the scalar head."""
        if measure_top_k <= 0:
            return
        measured_keys = {d.point.get("__key__")
                         for d in db.measured_rows(arch, shape,
                                                   mesh=mesh_name)}
        if objective == "pareto":
            front = db.front(arch, shape, k=measure_top_k, mesh=mesh_name)
            promos = plan_front_promotions(front, measured_keys,
                                           top_k=measure_top_k,
                                           budget_left=mstate["budget_left"])
        else:
            heads = db.winners(arch, shape, k=measure_top_k, mesh=mesh_name)
            promos = plan_promotions(heads, measured_keys,
                                     top_k=measure_top_k,
                                     budget_left=mstate["budget_left"])
        for head in promos:
            progress("measuring", cell=f"{arch}/{shape}")
            point = PlanPoint(dims={k: v for k, v in head.point.items()
                                    if k != "__key__"})
            dp = evaluator.measure(arch, shape, point,
                                   modeled_bound_s=head.metrics.get("bound_s"))
            db.append(dp)
            if mstate["budget_left"] is not None:
                mstate["budget_left"] -= 1
            if dp.status == "ok":
                us = dp.metrics["measured_us"]
                bound = head.metrics.get("bound_s")
                vs = (f" (bound {bound * 1e6:.0f}us)" if bound else "")
                log(f"{arch}/{shape}: measured {point.key()} = "
                    f"{us:.0f}us{vs} [{dp.metrics.get('backend')}]")
            else:
                log(f"{arch}/{shape}: measurement of {point.key()} -> "
                    f"{dp.status}: {dp.reason}")

    def note_cell(arch: str, shape: str) -> None:
        best = db.best(arch, shape, mesh=mesh_name)
        cell_best.append({"cell": f"{arch}/{shape}",
                          "bound_s": best.metrics.get("bound_s")
                          if best else None})
        progress("running")
        _injected_crash_hook(len(cell_rows))

    def process_cell(arch: str, shape: str) -> str:
        """Run/resume/skip one cell and record it (reports, counters,
        heartbeat); returns the cell status — shared by the static-grid
        and queue drive loops, so the two modes cannot drift. The one-shot
        crash hook inside ``note_cell`` fires *before* the queue ticket is
        completed, so an injected kill always lands mid-lease."""
        rpath = cell_report_path(out_dir, arch, shape, mesh_name)
        prior = None
        if resume and rpath.exists():
            try:
                prior = json.loads(rpath.read_text())
            except json.JSONDecodeError:
                # a torn report (kill mid-write before reports were atomic,
                # or external damage) means the cell never finished: re-run
                log(f"{arch}/{shape}: unreadable report — re-running cell")
        if prior is not None:
            status = ("resumed" if prior.get("status") != "unsupported"
                      else "unsupported")
            counts[status] += 1
            cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                              "status": status,
                              "improvement": prior.get("improvement")})
            log(f"{arch}/{shape}: resumed (report exists)")
            if status == "resumed":
                # heads may still be unmeasured (e.g. the prior attempt died
                # between the report write and its promotions, or top-k grew)
                promote_heads(arch, shape)
            note_cell(arch, shape)
            return status

        from repro.configs import SHAPE_BY_NAME, get_config
        supported, why = M.cell_supported(get_config(arch), SHAPE_BY_NAME[shape])
        if not supported:
            write_json_atomic(rpath,
                              {"arch": arch, "shape": shape,
                               "status": "unsupported", "reason": why})
            counts["unsupported"] += 1
            cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                              "status": "unsupported", "improvement": None})
            log(f"{arch}/{shape}: unsupported ({why})")
            note_cell(arch, shape)
            return "unsupported"

        t_cell = time.time()
        loop = DSELoop(evaluator=evaluator, db=db, llm_stack=stack,
                       cost_model=cost_model, gate=gate,
                       strategy=make_strategy(strategy, llm_stack=stack,
                                              objective=objective))
        report = loop.run(arch, shape, iterations=iterations,
                          eval_budget=budget, verbose=verbose,
                          heartbeat=cell_heartbeat(arch, shape))
        out = _cell_report(report)
        out["status"] = "complete"
        out["wall_s"] = round(time.time() - t_cell, 1)
        # atomic: a SIGKILL (supervisor hang-heal) mid-write must never
        # leave a torn report that poisons every subsequent resume
        write_json_atomic(rpath, out)
        counts["ran"] += 1
        cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                          "status": "complete",
                          "improvement": report.improvement()})
        log(f"{arch}/{shape}: done in {out['wall_s']}s "
            f"(improvement {report.improvement():.2%}, "
            f"cache {cache.stats()})")
        promote_heads(arch, shape)
        note_cell(arch, shape)
        return "complete"

    progress("starting")
    if q is None:
        for arch, shape in cells:
            process_cell(arch, shape)
    else:
        # queue drive: win a lease, work it, complete it; keep polling
        # while other owners still hold leases (their cell may yet be
        # reclaimed or stolen into our lap), exit only when drained
        while True:
            ticket = q.acquire(owner)
            if ticket is None:
                if q.drained():
                    break
                progress("waiting")
                time.sleep(queue_poll_s)
                continue
            current_ticket[0] = ticket
            log(f"{ticket.cell}: leased (attempt {ticket.attempt})")
            status = process_cell(ticket.arch, ticket.shape)
            current_ticket[0] = None
            if not q.complete(ticket, status=status):
                # the lease moved on mid-cell (stolen by the scheduler or
                # reclaimed after expiry): surrender gracefully — the local
                # results are valid and the merge dedupes them
                qstats["stolen"] += 1
                log(f"{ticket.cell}: lease lost before completion "
                    f"(stolen/reclaimed) — results kept, merge dedupes")

    # sorted rows -> deterministic leaderboard tie order, and the exact
    # order merge_db reconstructs from report files after a sharded run
    cell_rows.sort(key=lambda c: (c["arch"], c["shape"], c["mesh"]))
    leaderboard = build_leaderboard(db, cell_rows, objective=objective)
    # atomic like every other campaign artifact: a supervisor SIGKILL (or a
    # reader racing the write) must never see a torn leaderboard
    lb_path = write_json_atomic(out_dir / "leaderboard.json", leaderboard)

    # the auditable ladder trajectory — written unconditionally (an empty
    # measured tier is itself worth auditing). NaN RMSEs become null: the
    # file must stay strict-JSON parseable by any reader.
    def _num(x):
        return None if x is None or x != x else x

    ladder_cells = []
    for c in cell_rows:
        try:
            rep = json.loads(cell_report_path(out_dir, c["arch"], c["shape"],
                                              mesh_name).read_text())
        except (OSError, json.JSONDecodeError):
            rep = {}
        ladder_cells.append({
            "cell": f"{c['arch']}/{c['shape']}",
            "status": c["status"],
            "incumbent_by_iteration": [_num(it.get("best_bound"))
                                       for it in rep.get("iterations") or []],
        })
    bench = {
        "schema": "ladder-v1",
        "mesh": mesh_name,
        "strategy": strategy,
        "measure_top_k": measure_top_k,
        "measure_budget": measure_budget,
        "tiers": {
            "surrogate_pruned": evaluator.pruned_count - pruned0,
            "dryrun_compiles": evaluator.compile_count - compiles0,
            "dryrun_cache": cache.stats(),
            "measured": evaluator.measured_count,
            "measured_replayed": evaluator.measured_replayed,
        },
        "calibration": {
            "val_rmse": _num(gate.last_rmse) if gate else None,
            "val_n": gate.last_val_n if gate else None,
            "measured_rmse": (_num(getattr(gate, "last_measured_rmse", None))
                              if gate else None),
            "measured_n": (getattr(gate, "last_measured_n", None)
                           if gate else None),
            "measured_offset": (_num(getattr(gate, "measured_offset", None))
                                if gate else None),
            "effective_factor": gate.effective_factor if gate else None,
            "gate_active": gate.active if gate else None,
        },
        "cells": ladder_cells,
    }
    bench_path = write_json_atomic(out_dir / "BENCH_ladder.json", bench)

    evals = db.count()
    summary = {
        "mesh": mesh_name, "cells": len(cell_rows), **counts,
        "shard": f"{shard[0]}/{shard[1]}" if shard else None,
        "queue": str(q.root) if q is not None else None,
        "queue_owner": owner,
        "stolen": qstats["stolen"] if q is not None else None,
        "strategy": strategy,
        "objective": objective,
        "wall_s": round(time.time() - t0, 1),
        # run-local work vs cumulative totals: same contract as the
        # heartbeat (a resumed attempt reports only what it actually did)
        "evaluations": evals - evals0,
        "compiles": evaluator.compile_count - compiles0,
        "pruned": evaluator.pruned_count - pruned0,
        "measured": evaluator.measured_count,
        "measured_replayed": evaluator.measured_replayed,
        "measure_top_k": measure_top_k,
        "evaluations_total": evals,
        "compiles_total": compiles_prior + evaluator.compile_count - compiles0,
        "pruned_total": pruned_prior + evaluator.pruned_count - pruned0,
        "cache": cache.stats(),
        "leaderboard": str(lb_path),
        "bench": str(bench_path),
    }
    progress("done")
    log(f"summary: {summary}")
    return summary


def build_parser() -> argparse.ArgumentParser:
    """The campaign CLI surface, importable without touching jax (the
    quickstart drift checker parses documented commands against it)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.campaign",
        description="parallel, cached, resumable multi-workload DSE campaign")
    ap.add_argument("--space", default="plans",
                    choices=["plans", "kernels"],
                    help="design space to explore: 'plans' tunes sharding "
                         "plans over the arch x shape grid; 'kernels' tunes "
                         "Pallas kernel tile configs (--archs become kernel "
                         "names, --shapes KERNEL_SHAPES names, --mesh is "
                         "ignored — kernels are single-device)")
    ap.add_argument("--archs", default="qwen3-0.6b,stablelm-3b",
                    help="comma-separated arch ids, or 'all' "
                         "(--space kernels: kernel names)")
    ap.add_argument("--shapes", default="train_4k,decode_32k",
                    help="comma-separated shape cells, or 'all' "
                         "(--space kernels: kernel shape names)")
    ap.add_argument("--mesh", default="small", choices=list(MESH_CHOICES))
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--budget", type=int, default=3,
                    help="evaluations per loop iteration")
    ap.add_argument("--workers", type=int, default=2,
                    help="parallel dry-run compile processes")
    ap.add_argument("--out", default="artifacts/campaign")
    ap.add_argument("--llm", default="mock", choices=["mock", "ollama"])
    ap.add_argument("--force", action="store_true",
                    help="re-run cells even if their reports exist")
    # literal choices, not repro.search.STRATEGIES: importing the search
    # package pulls jax in, and --help must stay instant
    ap.add_argument("--strategy", default="ensemble",
                    choices=list(STRATEGY_CHOICES),
                    help="search strategy per cell (fresh instance each "
                         "cell); *transfer variants seed cells from similar "
                         "finished cells in the shared DB")
    ap.add_argument("--gate-factor", type=float, default=None,
                    help="enable the surrogate gate: prune candidates whose "
                         "predicted bound is > FACTOR x the incumbent "
                         "(must be > 1)")
    ap.add_argument("--gate-min-factor", type=float, default=None,
                    help="anneal the gate's prune threshold from "
                         "--gate-factor down toward this as the surrogate's "
                         "validation RMSE improves (must be in "
                         "(1, gate-factor]; requires --gate-factor)")
    ap.add_argument("--measure-top-k", type=int, default=0, metavar="K",
                    help="promotion ladder tier 2: after each cell, execute "
                         "and time its K best designs (0 = off); measured "
                         "rows land in the cost DB and the leaderboard's "
                         "measured_us column, and replay from the shared "
                         "measured cache on resume/steal")
    ap.add_argument("--measure-runs", type=int, default=3, metavar="N",
                    help="timed executions per measurement (min reported; "
                         "one warm call first)")
    ap.add_argument("--measure-budget", type=int, default=None, metavar="M",
                    help="campaign-wide cap on tier-2 measurements "
                         "(default: unlimited; requires --measure-top-k)")
    ap.add_argument("--objective", default="bound_s",
                    choices=list(OBJECTIVE_CHOICES),
                    help="leaderboard ranking: 'bound_s' keeps the scalar "
                         "bound (byte-compatible with pre-Pareto "
                         "leaderboards); 'pareto' ranks each cell's designs "
                         "by objective-vector dominance, emits the "
                         "non-dominated front per cell, promotes the "
                         "measured tier along the front, and arms the "
                         "ensemble with scalarization-weight strategies")
    ap.add_argument("--shard", default=None, metavar="I/N",
                    help="run only cells i, i+n, i+2n, ... of the sorted "
                         "arch x shape grid (merge shards with "
                         "repro.launch.merge_db, or let "
                         "repro.launch.orchestrator drive the whole thing)")
    ap.add_argument("--queue", default=None, metavar="DIR",
                    help="dynamic scale-out: pull cells from the crash-safe "
                         "lease queue at DIR instead of iterating a static "
                         "grid slice (seeds the queue idempotently; "
                         "mutually exclusive with --shard; workers share "
                         "the queue-side dry-run cache)")
    ap.add_argument("--queue-owner", default=None, metavar="NAME",
                    help="lease owner id for --queue (default: pid<PID>); "
                         "the orchestrator passes shard<i>")
    ap.add_argument("--queue-lease-s", type=float, default=300.0,
                    help="lease length in seconds for --queue; renewed on "
                         "every heartbeat, so it must exceed the slowest "
                         "single iteration step, never a whole cell")
    ap.add_argument("--queue-poll-s", type=float, default=0.5,
                    help="seconds between queue polls while idle-waiting "
                         "for other owners' leased cells")
    return ap


def parse_shard(spec: Optional[str]) -> Optional[Tuple[int, int]]:
    """Parse an ``i/n`` shard spec into ``(i, n)``; ``None``/empty passes
    through. Raises ``ValueError`` on malformed specs or ``i`` outside
    ``0..n-1`` — shared by the campaign and orchestrator CLIs."""
    if not spec:
        return None
    try:
        i, n = (int(x) for x in spec.split("/"))
    except ValueError:
        raise ValueError(f"shard spec must look like i/n, got {spec!r}")
    if not (0 <= i < n):
        raise ValueError(f"shard index must satisfy 0 <= i < n, got {spec}")
    return (i, n)


def main():
    """CLI entry: pin XLA_FLAGS, run the optional test prelude, validate the
    grid, and hand off to :func:`run_campaign`. Exits 2 on bad arguments."""
    # before any jax-touching import: jax locks the device count at first init
    os.environ["XLA_FLAGS"] = os.environ.get(
        "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    ap = build_parser()
    args = ap.parse_args()

    # test/CI hook: shrink configs (etc.) before anything jax-touching runs —
    # this is how shard subprocesses inherit the suite's tiny workloads
    prelude = os.environ.get("REPRO_CAMPAIGN_PRELUDE")
    if prelude:
        src = Path(prelude).read_text()
        exec(compile(src, prelude, "exec"), {"__name__": "__repro_prelude__"})

    gate_err = validate_gate_args(args.gate_factor, args.gate_min_factor)
    if gate_err:
        ap.error(gate_err)
    measure_err = validate_measure_args(args.measure_top_k, args.measure_runs,
                                        args.measure_budget)
    if measure_err:
        ap.error(measure_err)
    objective_err = validate_objective_args(args.objective)
    if objective_err:
        ap.error(objective_err)
    if args.queue and args.shard:
        ap.error("--queue and --shard are mutually exclusive")
    if args.queue_lease_s <= 0:
        ap.error(f"--queue-lease-s must be > 0, got {args.queue_lease_s}")
    if args.queue_poll_s <= 0:
        ap.error(f"--queue-poll-s must be > 0, got {args.queue_poll_s}")
    try:
        shard = parse_shard(args.shard)
    except ValueError as e:
        ap.error(str(e))
    if args.space == "kernels":
        from repro.launch import kernel_cell

        # the plan-grid defaults are meaningless kernel ids: an untouched
        # --archs/--shapes means "the whole kernel grid", while explicit
        # values go through kernel-space validation unchanged
        kernels = ("all" if args.archs == ap.get_default("archs")
                   else args.archs)
        kshapes = ("all" if args.shapes == ap.get_default("shapes")
                   else args.shapes)
        if args.strategy not in kernel_cell.KERNEL_STRATEGY_CHOICES:
            ap.error(f"--space kernels supports --strategy "
                     f"{kernel_cell.KERNEL_STRATEGY_CHOICES}; llm/transfer "
                     f"variants are plan-coupled (got {args.strategy!r})")
        try:
            kernel_list, shape_list = kernel_cell.resolve_kernel_grid(
                kernels, kshapes)
        except ValueError as e:
            ap.error(str(e))
        kernel_cell.run_kernel_campaign(
            kernel_list, shape_list, out_dir=args.out,
            iterations=args.iterations, budget=args.budget,
            strategy=args.strategy, gate_factor=args.gate_factor,
            gate_min_factor=args.gate_min_factor,
            measure_top_k=args.measure_top_k,
            measure_runs=args.measure_runs,
            measure_budget=args.measure_budget,
            objective=args.objective,
            shard=shard, queue=args.queue, queue_owner=args.queue_owner,
            queue_lease_s=args.queue_lease_s,
            queue_poll_s=args.queue_poll_s, resume=not args.force)
        return

    try:
        archs, shapes = resolve_grid(args.archs, args.shapes)
    except ValueError as e:
        ap.error(str(e))

    mesh, mesh_name = make_campaign_mesh(args.mesh)

    llm_client = None
    if args.llm == "ollama":
        from repro.core.llm_client import OllamaClient

        llm_client = OllamaClient()

    run_campaign(archs, shapes, mesh, mesh_name, out_dir=args.out,
                 iterations=args.iterations, budget=args.budget,
                 workers=args.workers, llm_client=llm_client,
                 strategy=args.strategy, gate_factor=args.gate_factor,
                 gate_min_factor=args.gate_min_factor,
                 measure_top_k=args.measure_top_k,
                 measure_runs=args.measure_runs,
                 measure_budget=args.measure_budget,
                 objective=args.objective,
                 shard=shard, queue=args.queue, queue_owner=args.queue_owner,
                 queue_lease_s=args.queue_lease_s,
                 queue_poll_s=args.queue_poll_s, resume=not args.force)


if __name__ == "__main__":
    main()
