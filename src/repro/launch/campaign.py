"""Multi-workload DSE campaign engine.

Sweeps an ``arch x shape x mesh`` grid of SECDA-DSE loops with shared
infrastructure: one cost DB (so the surrogate cost model and RAG retrieval
learn across workloads), one content-addressed dry-run cache (so designs
re-proposed in another cell never recompile), and one process pool sizing
knob. Every cell writes a loop-report JSON; the campaign is *resumable* —
re-running the same command skips cells whose reports exist and re-serves
cached dry-runs for partially-explored cells — and finishes with a
leaderboard JSON ranking the best design found per cell.

Quickstart:

    PYTHONPATH=src python -m repro.launch.campaign \\
        --archs qwen3-0.6b,stablelm-3b --shapes train_4k,decode_32k \\
        --mesh small --iterations 2 --budget 3 --workers 2 \\
        --out artifacts/campaign

    # interrupted? same command again: completed cells are skipped, the
    # shared dry-run cache makes re-entered cells near-instant
    PYTHONPATH=src python -m repro.launch.campaign ... (same args)

Search policy and surrogate gating (see ``repro.search``):

    --strategy {greedy,llm,anneal,evolve,ensemble}
        proposal engine per cell (default ``ensemble``: budget split across
        all strategies with bandit credit reallocation, provenance in the
        cost DB ``source`` field)
    --gate-factor F
        enable the SurrogateGate: candidates whose *predicted* bound is
        > F x the incumbent are recorded as ``pruned`` data points instead
        of compiled; auto-disabled until the surrogate's held-out
        validation RMSE clears the calibration guard

Scale-out over processes/hosts — shard the grid, then merge:

    # shard i/n deterministically partitions the sorted arch x shape grid
    PYTHONPATH=src python -m repro.launch.campaign ... \\
        --out artifacts/shard0 --shard 0/2
    PYTHONPATH=src python -m repro.launch.campaign ... \\
        --out artifacts/shard1 --shard 1/2

    # merge shard DBs + reports + caches, rebuild one leaderboard
    # (dedup by (arch, shape, mesh, design key), earliest record wins)
    PYTHONPATH=src python -m repro.launch.merge_db \\
        artifacts/shard0 artifacts/shard1 --out artifacts/campaign

With the deterministic mock LLM and an untrained (or cell-local) surrogate,
a sharded run + merge reproduces the single-process ``leaderboard.json``
byte-for-byte — tier-1 asserts it (``tests/test_merge_db.py``).

Outputs under --out:
    cost_db.jsonl                     shared hardware-datapoint DB
    dryrun_cache/                     content-addressed compile cache
    reports/{arch}__{shape}__{mesh}.json   per-cell loop reports
    leaderboard.json                  cells ranked by best bound_s

Unlike the other launchers this module is import-safe (tests import
``build_leaderboard``/``run_campaign``): XLA_FLAGS is set inside ``main()``,
before the first jax-touching import, never at import time.
"""
import argparse
import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple


def cell_report_path(out_dir: Path, arch: str, shape: str, mesh_name: str) -> Path:
    return Path(out_dir) / "reports" / f"{arch}__{shape}__{mesh_name}.json"


def shard_cells(archs: Sequence[str], shapes: Sequence[str],
                shard: Optional[Tuple[int, int]] = None,
                ) -> List[Tuple[str, str]]:
    """The campaign's (arch, shape) work list: the full grid in sorted order
    (so every shard agrees on cell numbering), optionally keeping only cells
    whose index ``% n == i`` for ``shard=(i, n)``. Disjoint and exhaustive:
    the union over all shards is exactly the unsharded list."""
    cells = sorted({(a, s) for a in archs for s in shapes})
    if shard is None:
        return cells
    i, n = shard
    if not (0 <= i < n):
        raise ValueError(f"shard index {i} outside 0..{n - 1}")
    return cells[i::n]


def _cell_report(report) -> Dict:
    return {
        "arch": report.arch, "shape": report.shape,
        "baseline": report.baseline.__dict__ if report.baseline else None,
        "best": report.best.__dict__ if report.best else None,
        "iterations": report.iterations,
        "improvement": report.improvement(),
    }


def build_leaderboard(db, cell_rows: Sequence[Dict]) -> List[Dict]:
    """Rank completed cells by their best achieved bound (fastest first);
    cells with no feasible design sink to the bottom with their failure
    mode preserved."""
    rows = []
    for c in cell_rows:
        best = db.best(c["arch"], c["shape"], mesh=c["mesh"])
        feasible = best is not None
        if best is None:
            # negative datapoints still rank: the fastest *infeasible* design
            # tells the reader how far off the memory budget this cell is
            cands = [d for d in db.query(c["arch"], c["shape"], mesh=c["mesh"])
                     if d.metrics.get("bound_s")]
            best = (min(cands, key=lambda d: d.metrics["bound_s"])
                    if cands else None)
        row = {
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "status": c["status"],
            "feasible": feasible if best is not None else None,
            # measured designs only: gate-pruned rows are predictions, and
            # counting them would overstate how thoroughly a cell was explored
            "n_points": sum(d.status != "pruned" for d in
                            db.query(c["arch"], c["shape"], mesh=c["mesh"])),
            "improvement": c.get("improvement"),
            "bound_s": None, "mfu_at_bound": None, "dominant": None,
            "per_device_gib": None, "best_point": None,
        }
        if best is not None:
            row.update(
                bound_s=best.metrics.get("bound_s"),
                mfu_at_bound=best.metrics.get("mfu_at_bound"),
                dominant=best.metrics.get("dominant"),
                per_device_gib=best.metrics.get("per_device_gib"),
                # sorted: identical serialization whether the DB is the live
                # in-memory one or re-read from JSONL (to_json sorts keys),
                # so a sharded run + merge_db reproduces this byte-for-byte
                best_point={k: v for k, v in sorted(best.point.items())
                            if k != "__key__"},
            )
        rows.append(row)
    rows.sort(key=lambda r: (r["bound_s"] is None, r["feasible"] is not True,
                             r["bound_s"] if r["bound_s"] is not None else 0.0))
    return rows


def run_campaign(archs: Sequence[str], shapes: Sequence[str], mesh, mesh_name: str,
                 *, out_dir: Path | str, iterations: int = 2, budget: int = 3,
                 workers: int = 1, llm_client=None, db=None, resume: bool = True,
                 strategy: str = "ensemble", gate_factor: Optional[float] = None,
                 shard: Optional[Tuple[int, int]] = None,
                 verbose: bool = True) -> Dict:
    """Run (or resume) the grid — or one deterministic ``shard=(i, n)`` slice
    of it — and return the campaign summary dict. Each cell gets a *fresh*
    search strategy (strategies carry per-cell state: walker position,
    population, bandit credit); the cost DB, dry-run cache, surrogate cost
    model, and evaluator pool are shared across cells."""
    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.core.llm_client import MockLLM
    from repro.core.llm_stack import LLMStack
    from repro.core.loop import DSELoop
    from repro.models import model as M
    from repro.search import SurrogateGate, make_strategy

    out_dir = Path(out_dir)
    (out_dir / "reports").mkdir(parents=True, exist_ok=True)
    db = db or CostDB(out_dir / "cost_db.jsonl")
    cache = DryRunCache.beside(db.path)
    evaluator = Evaluator(mesh, mesh_name, cache=cache,
                          max_workers=max(workers, 1),
                          artifact_dir=str(out_dir / "dryrun"))
    stack = LLMStack(client=llm_client or MockLLM(), db=db)
    cost_model = CostModel.create(in_dim=featurize({}, {}).shape[0])
    if gate_factor is not None and gate_factor <= 1.0:
        raise ValueError(f"gate_factor must be > 1 (got {gate_factor}): the "
                         "gate prunes candidates predicted SLOWER than "
                         "factor x the incumbent")
    gate = (SurrogateGate(cost_model, factor=gate_factor)
            if gate_factor is not None else None)

    def log(msg):
        if verbose:
            print(f"[campaign {mesh_name}] {msg}", flush=True)

    t0 = time.time()
    cell_rows: List[Dict] = []
    counts = {"ran": 0, "resumed": 0, "unsupported": 0}
    for arch, shape in shard_cells(archs, shapes, shard):
        rpath = cell_report_path(out_dir, arch, shape, mesh_name)
        if resume and rpath.exists():
            prior = json.loads(rpath.read_text())
            counts["resumed" if prior.get("status") != "unsupported"
                   else "unsupported"] += 1
            cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                              "status": "resumed" if prior.get("status") != "unsupported"
                              else "unsupported",
                              "improvement": prior.get("improvement")})
            log(f"{arch}/{shape}: resumed (report exists)")
            continue

        from repro.configs import SHAPE_BY_NAME, get_config
        supported, why = M.cell_supported(get_config(arch), SHAPE_BY_NAME[shape])
        if not supported:
            rpath.write_text(json.dumps(
                {"arch": arch, "shape": shape, "status": "unsupported",
                 "reason": why}, indent=1))
            counts["unsupported"] += 1
            cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                              "status": "unsupported", "improvement": None})
            log(f"{arch}/{shape}: unsupported ({why})")
            continue

        t_cell = time.time()
        loop = DSELoop(evaluator=evaluator, db=db, llm_stack=stack,
                       cost_model=cost_model, gate=gate,
                       strategy=make_strategy(strategy, llm_stack=stack))
        report = loop.run(arch, shape, iterations=iterations,
                          eval_budget=budget, verbose=verbose)
        out = _cell_report(report)
        out["status"] = "complete"
        out["wall_s"] = round(time.time() - t_cell, 1)
        rpath.write_text(json.dumps(out, indent=1, default=str))
        counts["ran"] += 1
        cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                          "status": "complete",
                          "improvement": report.improvement()})
        log(f"{arch}/{shape}: done in {out['wall_s']}s "
            f"(improvement {report.improvement():.2%}, "
            f"cache {cache.stats()})")

    # sorted rows -> deterministic leaderboard tie order, and the exact
    # order merge_db reconstructs from report files after a sharded run
    cell_rows.sort(key=lambda c: (c["arch"], c["shape"], c["mesh"]))
    leaderboard = build_leaderboard(db, cell_rows)
    lb_path = out_dir / "leaderboard.json"
    lb_path.write_text(json.dumps(leaderboard, indent=1, default=str))

    summary = {
        "mesh": mesh_name, "cells": len(cell_rows), **counts,
        "shard": f"{shard[0]}/{shard[1]}" if shard else None,
        "strategy": strategy,
        "wall_s": round(time.time() - t0, 1),
        "evaluations": db.count(),
        "compiles": evaluator.compile_count,
        "pruned": evaluator.pruned_count,
        "cache": cache.stats(),
        "leaderboard": str(lb_path),
    }
    log(f"summary: {summary}")
    return summary


def main():
    # before any jax-touching import: jax locks the device count at first init
    os.environ["XLA_FLAGS"] = os.environ.get(
        "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

    from repro.configs import ARCH_NAMES, SHAPES

    ap = argparse.ArgumentParser(
        description="parallel, cached, resumable multi-workload DSE campaign")
    ap.add_argument("--archs", default="qwen3-0.6b,stablelm-3b",
                    help="comma-separated arch ids, or 'all'")
    ap.add_argument("--shapes", default="train_4k,decode_32k",
                    help="comma-separated shape cells, or 'all'")
    ap.add_argument("--mesh", default="small", choices=["small", "pod", "multipod"])
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--budget", type=int, default=3,
                    help="evaluations per loop iteration")
    ap.add_argument("--workers", type=int, default=2,
                    help="parallel dry-run compile processes")
    ap.add_argument("--out", default="artifacts/campaign")
    ap.add_argument("--llm", default="mock", choices=["mock", "ollama"])
    ap.add_argument("--force", action="store_true",
                    help="re-run cells even if their reports exist")
    # literal choices, not repro.search.STRATEGIES: importing the search
    # package pulls jax in, and --help must stay instant
    ap.add_argument("--strategy", default="ensemble",
                    choices=["greedy", "llm", "anneal", "evolve", "ensemble"],
                    help="search strategy per cell (fresh instance each cell)")
    ap.add_argument("--gate-factor", type=float, default=None,
                    help="enable the surrogate gate: prune candidates whose "
                         "predicted bound is > FACTOR x the incumbent "
                         "(must be > 1)")
    ap.add_argument("--shard", default=None, metavar="I/N",
                    help="run only cells i, i+n, i+2n, ... of the sorted "
                         "arch x shape grid (merge shards with "
                         "repro.launch.merge_db)")
    args = ap.parse_args()

    if args.gate_factor is not None and args.gate_factor <= 1.0:
        ap.error(f"--gate-factor must be > 1, got {args.gate_factor}")

    shard = None
    if args.shard:
        try:
            i, n = (int(x) for x in args.shard.split("/"))
        except ValueError:
            ap.error(f"--shard must look like i/n, got {args.shard!r}")
        if not (0 <= i < n):
            ap.error(f"--shard index must satisfy 0 <= i < n, got {args.shard}")
        shard = (i, n)

    archs = list(ARCH_NAMES) if args.archs == "all" else args.archs.split(",")
    shapes = ([s.name for s in SHAPES] if args.shapes == "all"
              else args.shapes.split(","))
    unknown = [a for a in archs if a not in ARCH_NAMES]
    unknown += [s for s in shapes if s not in {c.name for c in SHAPES}]
    if unknown:
        ap.error(f"unknown arch/shape: {unknown}")

    from repro.launch.mesh import make_mesh, make_production_mesh

    if args.mesh == "pod":
        mesh, mesh_name = make_production_mesh(), "pod16x16"
    elif args.mesh == "multipod":
        mesh, mesh_name = make_production_mesh(multi_pod=True), "multipod2x16x16"
    else:
        mesh, mesh_name = make_mesh((2, 4), ("data", "model")), "small2x4"

    llm_client = None
    if args.llm == "ollama":
        from repro.core.llm_client import OllamaClient

        llm_client = OllamaClient()

    run_campaign(archs, shapes, mesh, mesh_name, out_dir=args.out,
                 iterations=args.iterations, budget=args.budget,
                 workers=args.workers, llm_client=llm_client,
                 strategy=args.strategy, gate_factor=args.gate_factor,
                 shard=shard, resume=not args.force)


if __name__ == "__main__":
    main()
