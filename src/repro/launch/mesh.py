"""Production mesh factory.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real single CPU device.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types (Auto matches the old default)
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes):
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # older jax: make_mesh has no axis_types kwarg; Auto is implied
    def _axis_kwargs(n_axes):
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """The paper-scale mesh: 16x16 (data, model), or 2x16x16 with a leading
    ``pod`` axis. Requires >= mesh-size visible devices — pin
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` *before* the first
    jax import or jax raises at mesh construction."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / reduced dry-runs / elastic re-configuration)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kwargs(len(axes)))


def single_device_mesh():
    """A 1-device ``("data",)`` mesh — always constructible, no XLA_FLAGS
    needed (smoke tests and benches run on the real single CPU device)."""
    return make_mesh((1,), ("data",))
