"""Training launcher: reduced configs train for real on this host; full
configs build the production-mesh step (the artifact a pod would execute).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 50 --batch 8 --seq 64
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, reduced as reduce_cfg
from repro.sharding.plan import ShardingPlan, baseline_rules
from repro.train import step as step_mod
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    """CLI entry: train the (reduced) arch on this host's devices, or — for
    full configs — build and lower the production-mesh train step without
    executing it. Loss values depend on the synthetic-data seed but are
    deterministic per invocation."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config on this host's devices")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--grad-compress", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--ckpt", default="artifacts/ckpt_train")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    plan = ShardingPlan(rules={} if args.reduced else baseline_rules(),
                        remat=args.remat, microbatches=args.microbatches,
                        grad_compress=args.grad_compress, zero1=not args.reduced)
    print(f"arch={cfg.name} params={cfg.n_params()/1e6:.1f}M plan={plan.name}")

    state, _ = step_mod.init_train_state(cfg, jax.random.key(0), plan)
    step = jax.jit(step_mod.make_train_step(
        cfg, plan, None, AdamWConfig(warmup_steps=10, total_steps=args.steps)),
        donate_argnums=(0,))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    tr = Trainer(cfg, plan, step, state, data,
                 TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt,
                               ckpt_every=max(args.steps // 4, 5)))
    out = tr.run()
    h = out["history"]
    print(f"final: step {out['final_step']} loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
