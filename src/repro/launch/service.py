"""DSE-as-a-service: a tenant-aware control plane over :class:`CellQueue`.

``python -m repro.launch.service serve`` runs a long-lived, supervisor-side
daemon (jax-free — RPR004-scoped; jax exists only inside the campaign
worker subprocesses it spawns) that accepts exploration workloads over a
stdlib HTTP/JSON API and drives them to completion:

* **submission** — ``POST /submit`` with ``{tenant, arch, shape, mesh,
  space, strategy, objective, budget, priority, ...}`` seeds the cells
  into the tenant's own crash-safe ``CellQueue`` under the service root.
  A tenant's campaign profile (mesh/space/strategy/objective/budget/
  iterations/llm) is fixed by its first submission; conflicting later
  submissions are rejected with 409 so every worker replays one argv.
* **fair scheduling** — each scheduler tick snapshots the tenants
  (:func:`snapshot_tenants`) and asks the pure weighted round-robin
  policy in :mod:`repro.core.fairshare` which tenants earn a worker;
  priorities weight the share, deficit credits carry across ticks, and
  per-tenant cell budgets (``max_cells``) stop grants once spent.
* **autoscaling + healing** — workers are ``repro.launch.campaign
  --queue`` subprocesses supervised through the same
  :class:`~repro.launch.executors.ShardExecutor` protocol the
  orchestrator uses: spawned on backlog, retired when the tenant queue
  drains (the campaign exits 0), SIGKILL + respawned with resume on
  crash or heartbeat silence, with the dead owner's leases released.
* **coalescing** — every tenant queue's ``dryrun_cache``/
  ``measured_cache`` is a symlink to one service-wide content-addressed
  cache, so the same design submitted by any number of tenants compiles
  exactly once fleet-wide and replays everywhere else.
* **results** — ``GET /tenants/<t>/leaderboard`` merges the tenant's
  worker dirs on demand (:func:`repro.launch.merge_db.merge`) and
  streams the same byte-stable ``leaderboard.json`` the campaign CLI
  writes, scalar or Pareto depending on the tenant's objective.

Service root layout::

    ROOT/
      service.json                  control-plane state snapshot (atomic)
      endpoint.json                 bound host/port + daemon pid
      dryrun_cache/                 fleet-wide compile cache
      measured_cache/               fleet-wide tier-2 timing cache
      tenants/<t>/queue/            the tenant's CellQueue (caches symlink up)
      tenants/<t>/workers/w<k>/     one campaign --out dir per worker
      tenants/<t>/merged/           merge-on-read target for leaderboard GETs

The ``submit`` / ``status`` / ``leaderboard`` / ``shutdown`` subcommands
are thin stdlib-urllib clients for the same API.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import signal
import sys
import threading
import time
import urllib.error
import urllib.request
from dataclasses import dataclass, field
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.fairshare import (TenantSnapshot, over_budget,
                                  plan_worker_grants)
from repro.launch.campaign import (MESH_CHOICES, OBJECTIVE_CHOICES,
                                   STRATEGY_CHOICES, resolve_grid,
                                   validate_objective_args)
from repro.launch.executors import ShardExecutor, ShardProc, make_executor
from repro.launch.ioutil import write_json_atomic
from repro.launch.orchestrator import child_env
from repro.launch.scheduler import CellQueue, sanitize_owner

STATE_FILE = "service.json"
ENDPOINT_FILE = "endpoint.json"
SHARED_CACHES = ("dryrun_cache", "measured_cache")

#: campaign-argv profile fields fixed per tenant by its first submission
PROFILE_FIELDS = ("mesh", "space", "strategy", "objective", "budget",
                  "iterations", "llm")
PROFILE_DEFAULTS = {"mesh": "small", "space": "plans",
                    "strategy": "ensemble", "objective": "bound_s",
                    "budget": 3, "iterations": 2, "llm": "mock"}

_TENANT_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")


class SubmitError(Exception):
    """Invalid or conflicting submission; carries the HTTP status."""

    def __init__(self, code: int, message: str):
        super().__init__(message)
        self.code = code


def _resolve_cells(space: str, archs: str,
                   shapes: str) -> Tuple[List[Tuple[str, str]], str]:
    """Validated ``(cells, seed_mesh_tag)`` for a submission grid — the
    same expansion (and the same queue mesh tag) the campaign workers
    seed with, so the daemon's seeding is an idempotent superset of
    theirs. Raises ``ValueError`` for unknown ids."""
    if space == "kernels":
        from repro.launch.kernel_cell import (KERNEL_MESH_NAME,
                                              kernel_grid_cells,
                                              resolve_kernel_grid)
        kernels, kshapes = resolve_kernel_grid(archs, shapes)
        return kernel_grid_cells(kernels, kshapes), KERNEL_MESH_NAME
    arch_list, shape_list = resolve_grid(archs, shapes)
    return [(a, s) for a in arch_list for s in shape_list], None


def snapshot_tenants(facts: Sequence[Dict[str, Any]], *, hang_timeout: float,
                     now: float) -> List[TenantSnapshot]:
    """Pure assembly of the fairshare policy input from per-tenant facts
    (``name``/``priority``/``backlog``/``workers``/``cells_done``/
    ``budget_cells``/``credit``/``worker_beats``). A tenant is *stalled* —
    earning no new workers — when it has workers but every one of them has
    been heartbeat-silent past ``hang_timeout`` (the healer is already
    dealing with them). Sorted by name so the grant order never depends on
    dict iteration order."""
    snaps = []
    for f in facts:
        beats = list(f.get("worker_beats") or [])
        stalled = bool(beats) and all((now - b) > hang_timeout
                                      for b in beats)
        snaps.append(TenantSnapshot(
            name=f["name"], priority=int(f.get("priority", 1)),
            backlog=int(f.get("backlog", 0)),
            workers=int(f.get("workers", 0)),
            cells_done=int(f.get("cells_done", 0)),
            budget_cells=f.get("budget_cells"),
            credit=float(f.get("credit", 0.0)), stalled=stalled))
    return sorted(snaps, key=lambda s: s.name)


@dataclass
class Worker:
    """One campaign worker: its queue-owner identity plus the ShardProc
    the executor supervises."""

    tenant: str
    wid: int
    owner: str
    shard: ShardProc

    @property
    def state(self) -> str:
        """``running`` / ``done`` (queue drained) / ``failed`` (restart
        budget exhausted)."""
        if self.shard.failed:
            return "failed"
        return "done" if self.shard.done else "running"


@dataclass
class Tenant:
    """Daemon-side tenant state: queue, fixed campaign profile, worker
    fleet (past and present), and fairness accounting."""

    name: str
    root: Path
    queue: CellQueue
    profile: Dict[str, Any]
    priority: int = 1
    max_cells: Optional[int] = None
    credit: float = 0.0
    seed_cell: Optional[Tuple[str, str]] = None
    next_wid: int = 0
    workers: List[Worker] = field(default_factory=list)
    submissions: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def active(self) -> List[Worker]:
        """Workers still running (the only ones the healer polls)."""
        return [w for w in self.workers if w.state == "running"]

    def worker_dirs(self) -> List[Path]:
        """Every worker dir holding results (past workers included — their
        rows are the tenant's history and must survive retirement)."""
        return [w.shard.out_dir for w in self.workers
                if (w.shard.out_dir / "cost_db.jsonl").exists()]


class ServiceDaemon:
    """The control plane: HTTP front end + scheduler/heal/autoscale loop.

    All mutable state is guarded by one lock; HTTP handler threads only
    take it for short reads and submission seeding, the tick holds it
    while polling workers."""

    def __init__(self, root: Path | str, *, host: str = "127.0.0.1",
                 port: int = 8731, max_workers: int = 2,
                 max_workers_per_tenant: int = 2, poll_interval: float = 0.5,
                 hang_timeout: float = 300.0, max_restarts: int = 2,
                 executor: str = "local", queue_lease_s: float = 60.0,
                 verbose: bool = True):
        self.root = Path(root).resolve()
        self.host, self.port = host, port
        self.max_workers = max_workers
        self.max_workers_per_tenant = max_workers_per_tenant
        self.poll_interval = poll_interval
        self.hang_timeout = hang_timeout
        self.max_restarts = max_restarts
        self.queue_lease_s = queue_lease_s
        self.verbose = verbose
        self.executor: ShardExecutor = make_executor(executor)
        self.root.mkdir(parents=True, exist_ok=True)
        for name in SHARED_CACHES:
            (self.root / name).mkdir(exist_ok=True)
        self.tenants: Dict[str, Tenant] = {}
        self.submission_seq = 0
        self.worker_seq = 0  # fleet-wide spawn counter (REPRO_SHARD_INDEX)
        self.stop = threading.Event()
        self._lock = threading.RLock()
        self._httpd: Optional[ThreadingHTTPServer] = None

    def log(self, msg: str) -> None:
        """Print one supervisor log line (suppressed by ``--quiet``)."""
        if self.verbose:
            print(f"[service] {msg}", flush=True)

    # -- tenancy -----------------------------------------------------------
    def _tenant_dir(self, name: str) -> Path:
        return self.root / "tenants" / name

    def _open_tenant(self, name: str, profile: Dict[str, Any],
                     priority: int, max_cells: Optional[int]) -> Tenant:
        tdir = self._tenant_dir(name)
        qroot = tdir / "queue"
        q = CellQueue(qroot, lease_s=self.queue_lease_s)
        for cache in SHARED_CACHES:
            link = qroot / cache
            if not link.is_symlink() and not link.exists():
                # relative symlink: the service root stays relocatable
                os.symlink(os.path.join("..", "..", "..", cache), link)
        t = Tenant(name=name, root=tdir, queue=q, profile=dict(profile),
                   priority=priority, max_cells=max_cells)
        self.tenants[name] = t
        self.log(f"tenant {name}: opened (priority {priority}, "
                 f"profile {profile})")
        return t

    def submit(self, payload: Dict[str, Any]) -> Dict[str, Any]:
        """Validate + seed one submission; returns the submission record.
        Raises :class:`SubmitError` with the HTTP code on bad input."""
        if not isinstance(payload, dict):
            raise SubmitError(400, "payload must be a JSON object")
        tenant = payload.get("tenant")
        if not isinstance(tenant, str) or not _TENANT_RE.match(tenant):
            raise SubmitError(400, "tenant must match "
                                   f"{_TENANT_RE.pattern!r}")
        archs = str(payload.get("arch") or payload.get("archs") or "")
        shapes = str(payload.get("shape") or payload.get("shapes") or "")
        if not archs or not shapes:
            raise SubmitError(400, "arch and shape are required")
        profile = {k: payload.get(k, PROFILE_DEFAULTS[k])
                   for k in PROFILE_FIELDS}
        err = self._validate_profile(profile)
        if err:
            raise SubmitError(400, err)
        try:
            cells, seed_mesh = _resolve_cells(profile["space"], archs,
                                              shapes)
        except ValueError as e:
            raise SubmitError(400, str(e))
        priority = payload.get("priority", 1)
        if not isinstance(priority, int) or priority < 1:
            raise SubmitError(400, "priority must be an integer >= 1")
        max_cells = payload.get("max_cells")
        if max_cells is not None and (not isinstance(max_cells, int)
                                      or max_cells < 1):
            raise SubmitError(400, "max_cells must be an integer >= 1")
        with self._lock:
            t = self.tenants.get(tenant)
            if t is None:
                t = self._open_tenant(tenant, profile, priority, max_cells)
            elif t.profile != profile:
                drift = {k: (t.profile[k], profile[k]) for k in PROFILE_FIELDS
                         if t.profile[k] != profile[k]}
                raise SubmitError(
                    409, f"tenant {tenant} profile is fixed by its first "
                         f"submission; conflicting fields: {drift}")
            if t.seed_cell is None:
                t.seed_cell = cells[0]
            seeded = t.queue.seed(
                cells, mesh=seed_mesh if seed_mesh else profile["mesh"])
            self.submission_seq += 1
            record = {"id": self.submission_seq, "tenant": tenant,
                      "cells": [list(c) for c in sorted(set(cells))],
                      "seeded": seeded, "ts": round(time.time(), 3)}
            t.submissions.append(record)
            self._persist()
        self.log(f"submit #{record['id']} tenant={tenant} "
                 f"cells={len(record['cells'])} new={seeded}")
        return record

    @staticmethod
    def _validate_profile(profile: Dict[str, Any]) -> Optional[str]:
        if profile["mesh"] not in MESH_CHOICES:
            return f"unknown mesh {profile['mesh']!r}"
        if profile["space"] not in ("plans", "kernels"):
            return f"unknown space {profile['space']!r}"
        if profile["space"] == "kernels":
            from repro.launch.kernel_cell import KERNEL_STRATEGY_CHOICES
            if profile["strategy"] not in KERNEL_STRATEGY_CHOICES:
                return (f"space=kernels supports strategies "
                        f"{KERNEL_STRATEGY_CHOICES}")
        elif profile["strategy"] not in STRATEGY_CHOICES:
            return f"unknown strategy {profile['strategy']!r}"
        if profile["objective"] not in OBJECTIVE_CHOICES:
            return validate_objective_args(str(profile["objective"]))
        if profile["llm"] not in ("mock", "ollama"):
            return f"unknown llm {profile['llm']!r}"
        for k in ("budget", "iterations"):
            if not isinstance(profile[k], int) or profile[k] < 1:
                return f"{k} must be an integer >= 1"
        return None

    # -- workers -----------------------------------------------------------
    def _worker_cmd(self, t: Tenant, out_dir: Path, owner: str) -> List[str]:
        arch, shape = t.seed_cell
        p = t.profile
        cmd = [sys.executable, "-m", "repro.launch.campaign",
               "--archs", arch, "--shapes", shape,
               "--mesh", p["mesh"], "--iterations", str(p["iterations"]),
               "--budget", str(p["budget"]), "--workers", "1",
               "--strategy", p["strategy"], "--llm", p["llm"],
               "--out", str(out_dir)]
        if p["space"] != "plans":
            cmd += ["--space", p["space"]]
        if p["objective"] != "bound_s":
            cmd += ["--objective", p["objective"]]
        cmd += ["--queue", str(t.queue.root.resolve()),
                "--queue-owner", owner,
                "--queue-lease-s", str(self.queue_lease_s)]
        return cmd

    def _spawn_worker(self, name: str) -> None:
        t = self.tenants[name]
        wid = t.next_wid
        t.next_wid += 1
        owner = sanitize_owner(f"svc-{name}-w{wid}")
        out_dir = t.root / "workers" / f"w{wid}"
        env = child_env()
        # fleet position, for parity with the orchestrator (test preludes
        # that slow one worker key on it; REPRO_ ⇒ forwarded everywhere)
        env["REPRO_SHARD_INDEX"] = str(self.worker_seq)
        env["REPRO_SERVICE_TENANT"] = name
        self.worker_seq += 1
        shard = ShardProc(index=wid, out_dir=out_dir,
                          cmd=self._worker_cmd(t, out_dir, owner), env=env)
        self.executor.spawn(shard)
        t.workers.append(Worker(tenant=name, wid=wid, owner=owner,
                                shard=shard))
        self.log(f"tenant {name}: worker w{wid} pid {shard.proc.pid} "
                 f"-> {out_dir}")

    def _poll_workers(self, now: float) -> None:
        for t in self.tenants.values():
            for w in t.active:
                s = w.shard
                payload = self.executor.read_heartbeat(s)
                if payload and payload != s.last_payload:
                    s.last_payload = payload
                    s.last_beat = now
                rc = self.executor.poll(s)
                if rc == 0:
                    s.done = True
                    s.close_log()
                    self.executor.collect(s)
                    s.last_payload = (self.executor.read_heartbeat(s)
                                      or s.last_payload)
                    self.log(f"tenant {t.name}: worker w{w.wid} drained "
                             f"and retired")
                    continue
                crashed = rc is not None
                hung = rc is None and (now - s.last_beat) > self.hang_timeout
                if not (crashed or hung):
                    continue
                self.executor.signal(s, signal.SIGKILL)
                if hung and s.proc is not None:
                    s.proc.wait()
                s.close_log()
                released = t.queue.release_owner(w.owner)
                why = (f"no heartbeat for {self.hang_timeout:.0f}s" if hung
                       else f"exit code {rc}")
                if s.restarts >= self.max_restarts:
                    s.failed = True
                    self.log(f"tenant {t.name}: worker w{w.wid} {why}; "
                             f"restart budget exhausted "
                             f"(log: {s.log_path})")
                    continue
                s.restarts += 1
                self.log(f"tenant {t.name}: worker w{w.wid} {why}; "
                         f"released {len(released)} lease(s), restarting "
                         f"with resume (attempt {s.restarts + 1})")
                self.executor.spawn(s)

    def _tenant_facts(self, now: float) -> List[Dict[str, Any]]:
        facts = []
        for t in self.tenants.values():
            c = t.queue.counts()
            facts.append({
                "name": t.name, "priority": t.priority,
                "backlog": c["pending"] + c["leased"],
                "workers": len(t.active), "cells_done": c["done"],
                "budget_cells": t.max_cells, "credit": t.credit,
                "worker_beats": [w.shard.last_beat for w in t.active]})
        return facts

    def tick(self, now: Optional[float] = None) -> None:
        """One scheduler pass: poll/heal workers, reclaim dead leases,
        grant + spawn new workers per the fairshare policy, persist."""
        now = time.time() if now is None else now
        with self._lock:
            self._poll_workers(now)
            for t in self.tenants.values():
                for ticket in t.queue.reclaim_expired(now):
                    self.log(f"tenant {t.name}: lease on {ticket.cell} "
                             f"expired — reclaimed")
            snaps = snapshot_tenants(self._tenant_facts(now),
                                     hang_timeout=self.hang_timeout, now=now)
            free = self.max_workers - sum(len(t.active)
                                          for t in self.tenants.values())
            plan = plan_worker_grants(
                snaps, free,
                max_workers_per_tenant=self.max_workers_per_tenant)
            for name in plan.grants:
                self._spawn_worker(name)
            for s in snaps:
                self.tenants[s.name].credit = plan.credits[s.name]
            self._persist()

    # -- views -------------------------------------------------------------
    def healthz(self) -> Dict[str, Any]:
        """Liveness payload; ``jax_loaded`` proves the daemon stays
        supervisor-side (tests assert it is False)."""
        with self._lock:
            return {"ok": True, "jax_loaded": "jax" in sys.modules,
                    "tenants": len(self.tenants),
                    "workers_active": sum(len(t.active)
                                          for t in self.tenants.values()),
                    "pid": os.getpid()}

    def tenant_status(self, name: str) -> Optional[Dict[str, Any]]:
        """Full per-tenant view (queue counts, budget, workers,
        submissions) or ``None`` for an unknown tenant."""
        with self._lock:
            t = self.tenants.get(name)
            if t is None:
                return None
            counts = t.queue.counts()
            return {
                "tenant": name, "priority": t.priority,
                "profile": dict(t.profile), "queue": counts,
                "drained": t.queue.drained(),
                "max_cells": t.max_cells,
                "over_budget": over_budget(t.max_cells, counts["done"]),
                "credit": t.credit,
                "submissions": list(t.submissions),
                "workers": [{"wid": w.wid, "owner": w.owner,
                             "state": w.state,
                             "restarts": w.shard.restarts,
                             "cells_done": w.shard.last_payload.get(
                                 "cells_done"),
                             "compiles_total": w.shard.last_payload.get(
                                 "compiles_total"),
                             "out": str(w.shard.out_dir)}
                            for w in t.workers]}

    def tenants_index(self) -> Dict[str, Any]:
        """Summary of every tenant, sorted by name."""
        with self._lock:
            return {"tenants": {
                name: {"priority": t.priority,
                       "queue": t.queue.counts(),
                       "workers_active": len(t.active)}
                for name, t in sorted(self.tenants.items())}}

    def leaderboard_bytes(self, name: str) -> Optional[bytes]:
        """Merge-on-read: fold the tenant's worker dirs (and the shared
        caches) into ``tenants/<t>/merged`` and return the leaderboard
        bytes — the identical byte-stable artifact a standalone campaign
        writes. ``None`` when the tenant has no results yet."""
        from repro.launch.merge_db import merge
        with self._lock:
            t = self.tenants.get(name)
            if t is None:
                return None
            dirs = t.worker_dirs()
            objective = t.profile["objective"]
            caches = [t.queue.cache_dir, t.queue.measured_dir]
            merged = t.root / "merged"
        if not dirs:
            return None
        merge(dirs, merged, verbose=False, extra_cache_dirs=caches,
              objective=objective)
        return (merged / "leaderboard.json").read_bytes()

    # -- persistence -------------------------------------------------------
    def _persist(self) -> None:
        state = {"root": str(self.root), "max_workers": self.max_workers,
                 "submission_seq": self.submission_seq,
                 "tenants": {}}
        for name, t in sorted(self.tenants.items()):
            state["tenants"][name] = {
                "priority": t.priority, "profile": t.profile,
                "max_cells": t.max_cells, "credit": t.credit,
                "seed_cell": list(t.seed_cell) if t.seed_cell else None,
                "next_wid": t.next_wid,
                "queue": t.queue.counts(),
                "submissions": t.submissions,
                "workers": [{"wid": w.wid, "owner": w.owner,
                             "state": w.state,
                             "restarts": w.shard.restarts,
                             "out": str(w.shard.out_dir)}
                            for w in t.workers]}
        write_json_atomic(self.root / STATE_FILE, state)

    def _restore(self) -> None:
        """Re-open tenants recorded by a previous daemon run (queues and
        worker results are already on disk; workers themselves are not
        adopted — the backlog simply earns fresh ones)."""
        path = self.root / STATE_FILE
        if not path.exists():
            return
        try:
            state = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError):
            return
        self.submission_seq = int(state.get("submission_seq", 0))
        for name, rec in (state.get("tenants") or {}).items():
            try:
                t = self._open_tenant(name, rec["profile"],
                                      int(rec.get("priority", 1)),
                                      rec.get("max_cells"))
            except (KeyError, TypeError, ValueError):
                continue
            t.credit = float(rec.get("credit", 0.0))
            t.next_wid = int(rec.get("next_wid", 0))
            seed = rec.get("seed_cell")
            t.seed_cell = tuple(seed) if seed else None
            t.submissions = list(rec.get("submissions") or [])
            # past workers come back as retired shards so their result
            # dirs keep feeding the tenant's merged leaderboard
            for w in rec.get("workers") or []:
                shard = ShardProc(index=int(w["wid"]),
                                  out_dir=Path(w["out"]), cmd=[], env={})
                shard.done = True
                t.workers.append(Worker(tenant=name, wid=int(w["wid"]),
                                        owner=w["owner"], shard=shard))

    # -- lifecycle ---------------------------------------------------------
    def _shutdown_workers(self) -> None:
        with self._lock:
            for t in self.tenants.values():
                for w in t.active:
                    self.executor.signal(w.shard, signal.SIGKILL)
                    if w.shard.proc is not None:
                        w.shard.proc.wait()
                    w.shard.close_log()
                    w.shard.failed = True
                    t.queue.release_owner(w.owner)
            self._persist()

    def run(self) -> None:
        """Serve until ``POST /shutdown`` (or SIGTERM/SIGINT): HTTP in
        handler threads, the scheduler tick on this one."""
        self._restore()
        handler = _make_handler(self)
        self._httpd = ThreadingHTTPServer((self.host, self.port), handler)
        host, port = self._httpd.server_address[:2]
        write_json_atomic(self.root / ENDPOINT_FILE,
                          {"host": host, "port": port, "pid": os.getpid()})
        server_thread = threading.Thread(target=self._httpd.serve_forever,
                                         daemon=True)
        server_thread.start()
        self.log(f"listening on http://{host}:{port} (root {self.root})")
        try:
            while not self.stop.is_set():
                self.tick()
                self.stop.wait(self.poll_interval)
        finally:
            self._shutdown_workers()
            self._httpd.shutdown()
            server_thread.join(timeout=5)
            self.log("stopped")


def _make_handler(daemon: ServiceDaemon):
    """The HTTP request handler bound to one daemon instance."""

    class Handler(BaseHTTPRequestHandler):
        """Routes the service API onto the daemon's thread-safe views."""

        server_version = "repro-dse-service/1.0"

        def log_message(self, fmt, *args):
            """Quiet: the daemon's own log lines carry the signal."""

        def _send(self, code: int, body: bytes,
                  ctype: str = "application/json") -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _send_json(self, code: int, obj: Any) -> None:
            self._send(code, json.dumps(obj, indent=1,
                                        default=str).encode())

        def do_GET(self):
            """``/healthz`` | ``/tenants`` | ``/tenants/<t>`` |
            ``/tenants/<t>/leaderboard``."""
            path = self.path.rstrip("/") or "/"
            if path == "/healthz":
                return self._send_json(200, daemon.healthz())
            if path == "/tenants":
                return self._send_json(200, daemon.tenants_index())
            m = re.match(r"^/tenants/([^/]+)$", path)
            if m:
                status = daemon.tenant_status(m.group(1))
                if status is None:
                    return self._send_json(
                        404, {"error": f"unknown tenant {m.group(1)!r}"})
                return self._send_json(200, status)
            m = re.match(r"^/tenants/([^/]+)/leaderboard$", path)
            if m:
                try:
                    body = daemon.leaderboard_bytes(m.group(1))
                except (OSError, ValueError) as e:
                    return self._send_json(500, {"error": str(e)})
                if body is None:
                    return self._send_json(
                        404, {"error": f"no results yet for "
                                       f"{m.group(1)!r}"})
                return self._send(200, body)
            self._send_json(404, {"error": f"no route for {path!r}"})

        def do_POST(self):
            """``/submit`` (workload grid) | ``/shutdown`` (clean stop)."""
            path = self.path.rstrip("/")
            if path == "/shutdown":
                daemon.stop.set()
                return self._send_json(200, {"ok": True,
                                             "stopping": True})
            if path == "/submit":
                length = int(self.headers.get("Content-Length") or 0)
                raw = self.rfile.read(length) if length else b"{}"
                try:
                    payload = json.loads(raw.decode() or "{}")
                except json.JSONDecodeError as e:
                    return self._send_json(400,
                                           {"error": f"bad JSON: {e}"})
                try:
                    record = daemon.submit(payload)
                except SubmitError as e:
                    return self._send_json(e.code, {"error": str(e)})
                return self._send_json(200, record)
            self._send_json(404, {"error": f"no route for {path!r}"})

    return Handler


# -- client ----------------------------------------------------------------
def _request(url: str, *, method: str = "GET",
             payload: Optional[Dict] = None) -> Tuple[int, bytes]:
    data = None if payload is None else json.dumps(payload).encode()
    req = urllib.request.Request(url, data=data, method=method, headers={
        "Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=60) as resp:
            return resp.status, resp.read()
    except urllib.error.HTTPError as e:
        return e.code, e.read()


def _client_payload(args: argparse.Namespace) -> Dict[str, Any]:
    payload: Dict[str, Any] = {
        "tenant": args.tenant, "arch": args.archs, "shape": args.shapes,
        "mesh": args.mesh, "space": args.space, "strategy": args.strategy,
        "objective": args.objective, "budget": args.budget,
        "iterations": args.iterations, "llm": args.llm,
        "priority": args.priority}
    if args.max_cells is not None:
        payload["max_cells"] = args.max_cells
    return payload


def build_parser() -> argparse.ArgumentParser:
    """CLI: ``serve`` (daemon) + ``submit``/``status``/``leaderboard``/
    ``shutdown`` clients. Importable so ``scripts/check_quickstart.py``
    can parse documented commands without booting anything."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.service",
        description="tenant-aware DSE control plane over CellQueue")
    sub = ap.add_subparsers(dest="command", required=True)

    serve = sub.add_parser("serve", help="run the control-plane daemon")
    serve.add_argument("--root", required=True,
                       help="service root (state, queues, shared caches)")
    serve.add_argument("--host", default="127.0.0.1")
    serve.add_argument("--port", type=int, default=8731,
                       help="0 picks a free port (written to "
                            "endpoint.json)")
    serve.add_argument("--max-workers", type=int, default=2,
                       help="fleet-wide campaign worker pool size")
    serve.add_argument("--max-workers-per-tenant", type=int, default=2)
    serve.add_argument("--poll-interval", type=float, default=0.5)
    serve.add_argument("--hang-timeout", type=float, default=300.0,
                       help="seconds without a heartbeat change before a "
                            "worker is killed + respawned with resume")
    serve.add_argument("--max-restarts", type=int, default=2)
    serve.add_argument("--executor", default="local",
                       choices=["local", "loopback"],
                       help="ShardExecutor backend for workers")
    serve.add_argument("--queue-lease-s", type=float, default=60.0)
    serve.add_argument("--quiet", action="store_true")

    def add_url(p):
        p.add_argument("--url", default="http://127.0.0.1:8731",
                       help="daemon base URL")

    submit = sub.add_parser("submit", help="submit a workload grid")
    add_url(submit)
    submit.add_argument("--tenant", required=True)
    submit.add_argument("--archs", required=True,
                        help="comma-separated arch ids (or 'all')")
    submit.add_argument("--shapes", required=True,
                        help="comma-separated shape ids (or 'all')")
    submit.add_argument("--mesh", default=PROFILE_DEFAULTS["mesh"],
                        choices=list(MESH_CHOICES))
    submit.add_argument("--space", default=PROFILE_DEFAULTS["space"],
                        choices=["plans", "kernels"])
    submit.add_argument("--strategy", default=PROFILE_DEFAULTS["strategy"])
    submit.add_argument("--objective", default=PROFILE_DEFAULTS["objective"],
                        choices=list(OBJECTIVE_CHOICES))
    submit.add_argument("--budget", type=int,
                        default=PROFILE_DEFAULTS["budget"])
    submit.add_argument("--iterations", type=int,
                        default=PROFILE_DEFAULTS["iterations"])
    submit.add_argument("--llm", default=PROFILE_DEFAULTS["llm"],
                        choices=["mock", "ollama"])
    submit.add_argument("--priority", type=int, default=1)
    submit.add_argument("--max-cells", type=int, default=None,
                        help="per-tenant cell budget (scheduling stops "
                             "once this many cells completed)")

    status = sub.add_parser("status", help="tenant/fleet status")
    add_url(status)
    status.add_argument("--tenant", default=None)

    lb = sub.add_parser("leaderboard",
                        help="stream a tenant's merged leaderboard")
    add_url(lb)
    lb.add_argument("--tenant", required=True)
    lb.add_argument("--out", default="-",
                    help="output file ('-' = stdout)")

    shutdown = sub.add_parser("shutdown", help="stop the daemon cleanly")
    add_url(shutdown)
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Entry point: run the daemon or one client subcommand."""
    args = build_parser().parse_args(argv)
    if args.command == "serve":
        daemon = ServiceDaemon(
            args.root, host=args.host, port=args.port,
            max_workers=args.max_workers,
            max_workers_per_tenant=args.max_workers_per_tenant,
            poll_interval=args.poll_interval,
            hang_timeout=args.hang_timeout,
            max_restarts=args.max_restarts, executor=args.executor,
            queue_lease_s=args.queue_lease_s, verbose=not args.quiet)
        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, lambda *_: daemon.stop.set())
        daemon.run()
        return 0
    if args.command == "submit":
        code, body = _request(args.url + "/submit", method="POST",
                              payload=_client_payload(args))
        print(body.decode().rstrip())
        return 0 if code == 200 else 1
    if args.command == "status":
        path = ("/tenants" if args.tenant is None
                else f"/tenants/{args.tenant}")
        code, body = _request(args.url + path)
        print(body.decode().rstrip())
        return 0 if code == 200 else 1
    if args.command == "leaderboard":
        code, body = _request(
            args.url + f"/tenants/{args.tenant}/leaderboard")
        if code != 200:
            print(body.decode().rstrip(), file=sys.stderr)
            return 1
        if args.out == "-":
            sys.stdout.buffer.write(body)
        else:
            Path(args.out).parent.mkdir(parents=True, exist_ok=True)
            Path(args.out).write_bytes(body)
        return 0
    code, body = _request(args.url + "/shutdown", method="POST")
    print(body.decode().rstrip())
    return 0 if code == 200 else 1


if __name__ == "__main__":
    sys.exit(main())
