import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run driver.

For every (architecture x input-shape x mesh) cell: build the step function,
``jit(...).lower(**input_specs).compile()`` against the production mesh, and
record ``memory_analysis`` / ``cost_analysis`` / HLO collective bytes into a
JSON artifact (read by the roofline report, the SECDA-DSE evaluator, and
EXPERIMENTS.md).

The two XLA_FLAGS lines above MUST stay the first statements: jax locks the
device count at first init.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_NAMES, SHAPES, SHAPE_BY_NAME, get_config
from repro.core.device import TPU_V5E, roofline_terms
from repro.core.hlo_analysis import analyze_hlo
from repro.launch.ioutil import write_json_atomic
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import model as M
from repro.sharding.plan import ShardingPlan, baseline_plan
from repro.train import step as train_step_mod
from repro.serve import step as serve_step_mod

ARTIFACT_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# process-local count of actual lower+compile runs; cache hits in the
# evaluator never reach run_cell, so tests assert recompiles against this
N_COMPILES = 0


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a dict on current jax but a
    list of per-partition dicts on older releases."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS per step: 6·N·D train, 2·N·D prefill, 2·N·B decode."""
    n = cfg.n_active_params()
    if cell.kind == "train":
        return 6.0 * n * cell.seq_len * cell.global_batch
    if cell.kind == "prefill":
        return 2.0 * n * cell.seq_len * cell.global_batch
    return 2.0 * n * cell.global_batch


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, plan=None, *,
               cfg=None, cell=None, donate: bool = True):
    """Returns (jitted fn, kwargs of ShapeDtypeStructs) for one cell.

    ``cfg``/``cell`` override the registry lookup — pool workers receive the
    caller's (possibly reduced) config by value instead of re-resolving the
    name in a fresh process. ``donate=False`` disables input-buffer donation
    (train state / decode cache): a dry-run compile wants the production
    donation pattern, but the measured tier (``repro.launch.measure``) calls
    the compiled step repeatedly on the same buffers, which donation forbids.
    """
    cfg = cfg if cfg is not None else get_config(arch)
    cell = cell if cell is not None else SHAPE_BY_NAME[shape_name]
    ok, why = M.cell_supported(cfg, cell)
    if not ok:
        return None, why
    plan = plan or baseline_plan(cfg, cell, multi_pod="pod" in mesh.shape)
    specs = M.input_specs(cfg, cell)

    if cell.kind == "train":
        step = train_step_mod.make_train_step(cfg, plan, mesh)
        state, logical = train_step_mod.abstract_train_state(cfg, plan)
        sspec = train_step_mod.state_specs(mesh, plan, state, logical)
        s_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec)
        bspec = plan.batch_specs(mesh, specs["batch"])
        b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)
        fn = jax.jit(step, in_shardings=(s_shard, b_shard),
                     out_shardings=(s_shard, None),
                     donate_argnums=(0,) if donate else ())
        args = (state, specs["batch"])
        return (fn, args), None

    values, logical = M.abstract_params(cfg)
    pshard = plan.param_shardings(mesh, values, logical)
    bspec = plan.batch_specs(mesh, specs["batch"])
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)
    cspec = plan.cache_specs(mesh, specs["cache"])
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspec)

    if cell.kind == "prefill":
        step = serve_step_mod.make_prefill_step(cfg, plan, mesh)
    else:
        step = serve_step_mod.make_decode_step(cfg, plan, mesh)
    fn = jax.jit(step, in_shardings=(pshard, b_shard, c_shard),
                 out_shardings=(None, c_shard),
                 donate_argnums=(2,) if donate else ())
    args = (values, specs["batch"], specs["cache"])
    return (fn, args), None


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, plan=None,
             artifact_dir: Path = ARTIFACT_DIR, *, cfg=None, cell=None):
    """Dry-run one cell: lower+compile the step, extract memory/cost/HLO
    analyses and roofline terms (seconds), and write the JSON artifact.

    Never raises — unsupported cells return ``status="skipped"`` and any
    compile/lowering exception becomes a ``status="error"`` record with the
    truncated traceback (the evaluator turns both into negative data
    points). ``lower_s``/``compile_s``/``wall_s`` are wall-clock and the
    only non-deterministic fields; everything else is reproducible for a
    fixed (config, cell, plan, mesh, jax version)."""
    global N_COMPILES
    t0 = time.time()
    cfg = cfg if cfg is not None else get_config(arch)
    cell = cell if cell is not None else SHAPE_BY_NAME[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "n_devices": mesh.size, "plan": (plan or
            baseline_plan(cfg, cell, multi_pod="pod" in mesh.shape)).name}
    try:
        built, skip = build_cell(arch, shape_name, mesh, plan, cfg=cfg, cell=cell)
        if built is None:
            rec.update(status="skipped", reason=skip)
            artifact_dir.mkdir(parents=True, exist_ok=True)
            # atomic: the campaign resume path and merge tooling read these
            # records while pool workers are still writing siblings
            write_json_atomic(
                artifact_dir / f"{arch}__{shape_name}__{mesh_name}.json", rec)
            return rec
        fn, args = built
        N_COMPILES += 1
        with mesh:
            lowered = fn.lower(*args)
            t_low = time.time()
            compiled = lowered.compile()
            t_comp = time.time()
        mem = compiled.memory_analysis()
        cost = xla_cost_dict(compiled)
        hlo = analyze_hlo(compiled.as_text(), mesh.size)
        mf = model_flops(cfg, cell)
        terms = roofline_terms(
            flops=hlo["flops"], hbm_bytes=hlo["hbm_bytes"],
            wire_bytes=hlo["wire_bytes_total"],
        )
        # memory_analysis is already per-device on this backend (verified:
        # llama3-8b args = params/TP + ZeRO-sharded opt state = 1.76 GiB/dev)
        hbm_per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                       + mem.output_size_in_bytes - mem.alias_size_in_bytes)
        rec.update(
            status="ok",
            lower_s=round(t_low - t0, 2),
            compile_s=round(t_comp - t_low, 2),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
                "per_device_bytes": hbm_per_dev,
                "fits_hbm": bool(hbm_per_dev <= TPU_V5E.hbm_bytes),
            },
            xla_flops_once=cost.get("flops", 0.0),
            hlo=hlo,
            model_flops=mf,
            model_flops_per_dev=mf / mesh.size,
            useful_flops_ratio=(mf / mesh.size) / max(hlo["flops"], 1.0),
            roofline=terms.to_dict(),
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a negative datapoint
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    artifact_dir.mkdir(parents=True, exist_ok=True)
    write_json_atomic(artifact_dir / f"{arch}__{shape_name}__{mesh_name}.json",
                      rec)
    return rec


def main():
    """CLI entry: sweep the requested arch x shape x mesh grid, skipping
    cells whose artifacts already exist (``--force`` recomputes). Exits 1
    if any cell errored, 0 otherwise."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape cell name or 'all'")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both", "small"])
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--out", default=str(ARTIFACT_DIR))
    args = ap.parse_args()
    artifact_dir = Path(args.out)

    archs = list(ARCH_NAMES) if args.arch == "all" else [args.arch]
    shapes = [s.name for s in SHAPES] if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh in ("pod", "both"):
        meshes.append(("pod16x16", make_production_mesh()))
    if args.mesh in ("multipod", "both"):
        meshes.append(("multipod2x16x16", make_production_mesh(multi_pod=True)))
    if args.mesh == "small":
        meshes.append(("small2x4", make_mesh((2, 4), ("data", "model"))))

    failures = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape in shapes:
                out = artifact_dir / f"{arch}__{shape}__{mesh_name}.json"
                if out.exists() and not args.force:
                    rec = json.loads(out.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {shape} {mesh_name}: {rec['status']}")
                        continue
                rec = run_cell(arch, shape, mesh, mesh_name, artifact_dir=artifact_dir)
                if rec["status"] == "error":
                    failures += 1
                    print(f"[FAIL] {arch} {shape} {mesh_name}: {rec['error']}", flush=True)
                else:
                    extra = ""
                    if rec["status"] == "ok":
                        gb = rec["memory"]["per_device_bytes"] / 2**30
                        r = rec["roofline"]
                        extra = (f" flops/dev={rec['hlo']['flops']:.3e}"
                                 f" wire={rec['hlo']['wire_bytes_total']:.3e}B"
                                 f" mem/dev={gb:.2f}GiB dom={r['dominant']}"
                                 f" bound={r['bound_s']*1e3:.1f}ms compile={rec['compile_s']}s")
                    print(f"[{rec['status']}] {arch} {shape} {mesh_name}{extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
