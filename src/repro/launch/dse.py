import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
"""SECDA-DSE loop launcher.

Runs the full explore -> reason -> simulate -> record loop for one workload
cell on the production mesh. The XLA_FLAGS lines must stay first (jax locks
the device count at first init).

Example:
    PYTHONPATH=src python -m repro.launch.dse --arch llama3-8b --shape train_4k \
        --iterations 4 --budget 3 --workers 4

Candidate evaluations go through ``Evaluator.evaluate_batch`` (process pool +
content-addressed dry-run cache next to the cost DB); for arch x shape x mesh
grid sweeps use ``repro.launch.campaign``.
"""
import argparse
from pathlib import Path

from repro.configs import ARCH_NAMES, SHAPES
from repro.core.kernel_space import KERNEL_NAMES, KERNEL_SHAPES


def build_parser() -> argparse.ArgumentParser:
    """The single-cell DSE CLI surface, importable cheaply (the quickstart
    drift checker parses documented commands against it)."""
    ap = argparse.ArgumentParser(prog="python -m repro.launch.dse")
    ap.add_argument("--space", default="plans", choices=["plans", "kernels"],
                    help="design space: 'plans' tunes a sharding plan for "
                         "one arch x shape cell; 'kernels' tunes one Pallas "
                         "kernel's tile config (--arch is the kernel name, "
                         "--shape a KERNEL_SHAPES name; --mesh ignored)")
    ap.add_argument("--arch", required=True,
                    choices=list(ARCH_NAMES) + list(KERNEL_NAMES))
    ap.add_argument("--shape", required=True,
                    choices=[s.name for s in SHAPES]
                    + [s.name for s in KERNEL_SHAPES])
    ap.add_argument("--iterations", type=int, default=4)
    ap.add_argument("--budget", type=int, default=3, help="evaluations per iteration")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "small"])
    ap.add_argument("--db", default="artifacts/dse/cost_db.jsonl")
    ap.add_argument("--workers", type=int, default=1,
                    help="parallel dry-run compile processes (1 = in-process)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the content-addressed dry-run cache")
    ap.add_argument("--approve", action="store_true",
                    help="human-in-the-loop: confirm each accepted design")
    from repro.launch.campaign import (OBJECTIVE_CHOICES,  # light, no jax
                                       STRATEGY_CHOICES)

    ap.add_argument("--llm", default="mock", choices=["mock", "ollama"])
    ap.add_argument("--strategy", default="ensemble",
                    choices=list(STRATEGY_CHOICES),
                    help="search strategy (see repro.search)")
    ap.add_argument("--objective", default="bound_s",
                    choices=list(OBJECTIVE_CHOICES),
                    help="ranking mode: scalar bound_s (default) or "
                         "multi-objective pareto — the strategy scalarizes "
                         "along weight arms and tier-2 promotions walk the "
                         "dominance front instead of the scalar head")
    ap.add_argument("--gate-factor", type=float, default=None,
                    help="enable the surrogate gate: prune candidates whose "
                         "predicted bound is > FACTOR x the incumbent "
                         "(must be > 1)")
    ap.add_argument("--gate-min-factor", type=float, default=None,
                    help="anneal the gate's prune threshold from "
                         "--gate-factor down toward this as the surrogate's "
                         "validation RMSE improves (must be in "
                         "(1, gate-factor]; requires --gate-factor)")
    ap.add_argument("--measure-top-k", type=int, default=0, metavar="K",
                    help="promotion ladder tier 2: after the loop, execute "
                         "and time the cell's K best designs (0 = off); "
                         "measured rows land in the cost DB with "
                         "fidelity=measured")
    ap.add_argument("--measure-runs", type=int, default=3, metavar="N",
                    help="timed executions per measurement (min reported)")
    ap.add_argument("--report", default=None, help="write the loop report JSON here")
    return ap


def main():
    """CLI entry: run one SECDA-DSE loop cell end-to-end on the chosen mesh
    and optionally write the loop-report JSON. Exits 2 on bad arguments."""
    ap = build_parser()
    args = ap.parse_args()
    from repro.launch.campaign import (validate_gate_args,  # no jax
                                       validate_measure_args,
                                       validate_objective_args)

    gate_err = validate_gate_args(args.gate_factor, args.gate_min_factor)
    if gate_err:
        ap.error(gate_err)
    measure_err = validate_measure_args(args.measure_top_k, args.measure_runs,
                                        None)
    if measure_err:
        ap.error(measure_err)
    objective_err = validate_objective_args(args.objective)
    if objective_err:
        ap.error(objective_err)

    if args.space == "kernels":
        _run_kernel_cell(ap, args)
        return
    if args.arch not in ARCH_NAMES:
        ap.error(f"--arch {args.arch!r} is a kernel name; pass "
                 f"--space kernels to tune it")
    if args.shape not in {s.name for s in SHAPES}:
        ap.error(f"--shape {args.shape!r} is a kernel shape; pass "
                 f"--space kernels to tune it")

    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import Evaluator
    from repro.core.llm_client import MockLLM, OllamaClient
    from repro.core.llm_stack import LLMStack
    from repro.core.loop import DSELoop
    from repro.core.rag import CodeIndex
    from repro.launch.mesh import make_mesh, make_production_mesh
    from repro.search import PromotionLadder, SurrogateGate, make_strategy

    if args.mesh == "pod":
        mesh, mesh_name = make_production_mesh(), "pod16x16"
    elif args.mesh == "multipod":
        mesh, mesh_name = make_production_mesh(multi_pod=True), "multipod2x16x16"
    else:
        mesh, mesh_name = make_mesh((2, 4), ("data", "model")), "small2x4"

    db = CostDB(args.db)
    client = MockLLM() if args.llm == "mock" else OllamaClient()
    code_index = CodeIndex(roots=[Path(__file__).resolve().parents[1]]).build()
    stack = LLMStack(client=client, db=db, code_index=code_index)
    cost_model = CostModel.create(in_dim=featurize({}, {}).shape[0])

    approve = None
    if args.approve:
        def approve(dp):
            ans = input(f"accept design bound={dp.metrics.get('bound_s')}s? [Y/n] ")
            return ans.strip().lower() not in ("n", "no")

    cache = None if args.no_cache else DryRunCache.beside(db.path)
    measured_cache = (None if args.no_cache else
                      DryRunCache(Path(db.path).parent / "measured_cache"))
    evaluator = Evaluator(mesh, mesh_name, cache=cache,
                          max_workers=max(args.workers, 1),
                          measured_cache=measured_cache,
                          measure_runs=args.measure_runs)
    gate_cls = PromotionLadder if args.measure_top_k > 0 else SurrogateGate
    gate = (gate_cls(cost_model, factor=args.gate_factor,
                     min_factor=args.gate_min_factor)
            if args.gate_factor is not None else None)
    loop = DSELoop(evaluator=evaluator, db=db,
                   llm_stack=stack, cost_model=cost_model, approve_fn=approve,
                   strategy=make_strategy(args.strategy, llm_stack=stack,
                                          objective=args.objective),
                   gate=gate)
    report = loop.run(args.arch, args.shape, iterations=args.iterations,
                      eval_budget=args.budget)
    if cache is not None:
        print(f"dry-run cache: {cache.stats()}")
    if gate is not None:
        print(f"surrogate gate: active={gate.active} pruned={gate.pruned_total} "
              f"val_rmse={gate.last_rmse:.3f} (n={gate.last_val_n})")

    if args.measure_top_k > 0:
        from repro.core.design_space import PlanPoint
        from repro.core.promotion import (plan_front_promotions,
                                          plan_promotions)

        measured_keys = {d.point.get("__key__") for d in
                         db.measured_rows(args.arch, args.shape,
                                          mesh=mesh_name)}
        if args.objective == "pareto":
            front = db.front(args.arch, args.shape, k=args.measure_top_k,
                             mesh=mesh_name)
            promos = plan_front_promotions(front, measured_keys,
                                           top_k=args.measure_top_k)
        else:
            heads = db.winners(args.arch, args.shape, k=args.measure_top_k,
                               mesh=mesh_name)
            promos = plan_promotions(heads, measured_keys,
                                     top_k=args.measure_top_k)
        for head in promos:
            point = PlanPoint(dims={k: v for k, v in head.point.items()
                                    if k != "__key__"})
            dp = evaluator.measure(args.arch, args.shape, point,
                                   modeled_bound_s=head.metrics.get("bound_s"))
            db.append(dp)
            if dp.status == "ok":
                bound = head.metrics.get("bound_s")
                print(f"measured {point.key()}: "
                      f"{dp.metrics['measured_us']:.0f}us "
                      f"(modeled bound "
                      f"{bound * 1e6:.0f}us) [{dp.metrics.get('backend')}]"
                      if bound else
                      f"measured {point.key()}: "
                      f"{dp.metrics['measured_us']:.0f}us")
            else:
                print(f"measurement of {point.key()} -> {dp.status}: "
                      f"{dp.reason}")
        print(f"measured tier: {evaluator.measured_count} timed, "
              f"{evaluator.measured_replayed} replayed from cache")

    if args.report:
        out = {
            "arch": report.arch, "shape": report.shape,
            "baseline": report.baseline.__dict__ if report.baseline else None,
            "best": report.best.__dict__ if report.best else None,
            "iterations": report.iterations,
            "improvement": report.improvement(),
        }
        from repro.launch.ioutil import write_json_atomic

        Path(args.report).parent.mkdir(parents=True, exist_ok=True)
        # atomic: report consumers (dashboards, EXPERIMENTS harvesting) may
        # poll this path while a long loop is finishing
        write_json_atomic(Path(args.report), out)
        print(f"report -> {args.report}")


def _run_kernel_cell(ap, args):
    """``--space kernels``: run the DSE loop over one kernel cell —
    arch/shape are a kernel name + a ``KERNEL_SHAPES`` name; evaluation is
    interpret-mode + correctness gate + analytic bound, tier 2 times real
    executions. Mirrors the plan path's cache/gate/measure/report plumbing."""
    from repro.core.kernel_space import KERNEL_SHAPE_BY_NAME, kernel_arch
    from repro.launch.kernel_cell import (KERNEL_MESH_NAME,
                                          KERNEL_STRATEGY_CHOICES)

    if args.arch not in KERNEL_NAMES:
        ap.error(f"--space kernels needs a kernel name for --arch "
                 f"(one of {KERNEL_NAMES}), got {args.arch!r}")
    kshape = KERNEL_SHAPE_BY_NAME.get(args.shape)
    if kshape is None or kshape.kernel != args.arch:
        ours = tuple(s.name for s in KERNEL_SHAPES if s.kernel == args.arch)
        ap.error(f"--shape must name a {args.arch} kernel shape "
                 f"(one of {ours}), got {args.shape!r}")
    if args.strategy not in KERNEL_STRATEGY_CHOICES:
        ap.error(f"--space kernels supports --strategy "
                 f"{KERNEL_STRATEGY_CHOICES}; llm/transfer variants are "
                 f"plan-coupled (got {args.strategy!r})")

    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.design_space import PlanPoint
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import KernelEvaluator
    from repro.core.promotion import plan_front_promotions, plan_promotions
    from repro.launch.kernel_cell import _explore_kernel_cell
    from repro.search import PromotionLadder, SurrogateGate, make_strategy

    arch = kernel_arch(args.arch)
    db = CostDB(args.db)
    cache = None if args.no_cache else DryRunCache.beside(db.path)
    measured_cache = (None if args.no_cache else
                      DryRunCache(Path(db.path).parent / "measured_cache"))
    evaluator = KernelEvaluator(mesh=None, mesh_name=KERNEL_MESH_NAME,
                                cache=cache, measured_cache=measured_cache,
                                measure_runs=args.measure_runs)
    cost_model = CostModel.create(in_dim=featurize({}, {}).shape[0])
    gate_cls = PromotionLadder if args.measure_top_k > 0 else SurrogateGate
    gate = (gate_cls(cost_model, factor=args.gate_factor,
                     min_factor=args.gate_min_factor)
            if args.gate_factor is not None else None)
    report = _explore_kernel_cell(
        arch, args.shape, evaluator=evaluator, db=db, cost_model=cost_model,
        gate=gate, strategy=make_strategy(args.strategy,
                                          objective=args.objective),
        iterations=args.iterations, budget=args.budget, seed=0)
    if cache is not None:
        print(f"dry-run cache: {cache.stats()}")
    if gate is not None:
        print(f"surrogate gate: active={gate.active} "
              f"pruned={gate.pruned_total} "
              f"val_rmse={gate.last_rmse:.3f} (n={gate.last_val_n})")

    if args.measure_top_k > 0:
        measured_keys = {d.point.get("__key__") for d in
                         db.measured_rows(arch, args.shape,
                                          mesh=KERNEL_MESH_NAME)}
        if args.objective == "pareto":
            front = db.front(arch, args.shape, k=args.measure_top_k,
                             mesh=KERNEL_MESH_NAME)
            promos = plan_front_promotions(front, measured_keys,
                                           top_k=args.measure_top_k)
        else:
            heads = db.winners(arch, args.shape, k=args.measure_top_k,
                               mesh=KERNEL_MESH_NAME)
            promos = plan_promotions(heads, measured_keys,
                                     top_k=args.measure_top_k)
        for head in promos:
            point = PlanPoint(dims={k: v for k, v in head.point.items()
                                    if k != "__key__"})
            dp = evaluator.measure(arch, args.shape, point,
                                   modeled_bound_s=head.metrics.get("bound_s"))
            db.append(dp)
            if dp.status == "ok":
                print(f"measured {point.key()}: "
                      f"{dp.metrics['measured_us']:.0f}us "
                      f"[{dp.metrics.get('backend')}]")
            else:
                print(f"measurement of {point.key()} -> {dp.status}: "
                      f"{dp.reason}")
        print(f"measured tier: {evaluator.measured_count} timed, "
              f"{evaluator.measured_replayed} replayed from cache")

    if args.report:
        from repro.launch.ioutil import write_json_atomic

        Path(args.report).parent.mkdir(parents=True, exist_ok=True)
        write_json_atomic(Path(args.report), report)
        print(f"report -> {args.report}")


if __name__ == "__main__":
    main()
