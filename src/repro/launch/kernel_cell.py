"""Kernel-cell campaigns: autotune the Pallas kernels through the DSE engine.

``--space kernels`` on the campaign/orchestrator CLIs lands here. A *kernel
cell* is ``(kernel, shape)`` — a Pallas kernel (flash_attention, rmsnorm,
ssd_scan, vecmul) paired with a ``repro.core.kernel_space.KERNEL_SHAPES``
workload instance — encoded into the existing CostDB/queue/report columns
as ``arch="kernel:<name>"`` / ``shape=<shape name>``, so CellQueue leases,
``merge_db``, leaderboards, resume-from-reports, and progress heartbeats
all work unchanged.

The per-cell loop mirrors ``core.loop.DSELoop`` (seed the shipped-default
tile config -> strategy proposes -> dedupe/rank/truncate -> surrogate gate
-> evaluate -> observe -> periodic surrogate fit) over a
:class:`~repro.core.evaluator.KernelEvaluator`, whose fidelity ladder is:

  * tier 0 — surrogate gate (shared ``CostModel`` over the kernel tile dims,
    which featurize through the same ``featurize`` as plan dims);
  * tier 1 — interpret-mode execution + **correctness gate** against the
    ``kernels.ref`` oracle + analytic ``resource_model`` bound. A candidate
    whose output differs from the oracle beyond tolerance is recorded
    ``status="infeasible"`` with ``max_abs_err`` — it can never top a
    leaderboard, no matter how fast its bound claims it is;
  * tier 2 — ``--measure-top-k`` real timed executions
    (``launch.measure.measure_kernel_cell``), correctness re-checked on the
    executed output, exactly-once via the shared measured cache.

Strategies: the design-space-agnostic ones (greedy / anneal / evolve, and
``ensemble`` built without its LLM member). The plan-coupled ``llm`` /
``transfer`` variants are rejected with a clear error.

Outputs under --out mirror the plan campaign (cost_db.jsonl, reports/,
leaderboard.json, progress.json), plus ``BENCH_kernels.json``: per-cell
tuned-vs-default bound/timing and the correctness-gate audit (candidates
checked / rejected).

Import-safe without jax (RPR004 supervisor scope): everything jax-touching
is imported inside :func:`run_kernel_campaign`.
"""
import json
import os
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.kernel_space import (KERNEL_NAMES, KERNEL_SHAPES,
                                     KERNEL_SHAPE_BY_NAME, kernel_arch,
                                     parse_kernel_arch)
from repro.launch.campaign import (_injected_crash_hook, build_leaderboard,
                                   cell_report_path, read_progress,
                                   validate_gate_args, validate_measure_args,
                                   validate_objective_args, write_progress)
from repro.launch.ioutil import write_json_atomic
from repro.launch.scheduler import CellQueue, sanitize_owner

__all__ = [
    "KERNEL_MESH_NAME", "KERNEL_STRATEGY_CHOICES", "kernel_grid_cells",
    "resolve_kernel_grid", "run_kernel_campaign",
]

#: kernels are single-device — the mesh column every kernel row carries
KERNEL_MESH_NAME = "dev1"

#: design-space-agnostic strategies only (llm/transfer are plan-coupled)
KERNEL_STRATEGY_CHOICES = ("greedy", "anneal", "evolve", "ensemble")


def resolve_kernel_grid(kernels: str, shapes: str) -> Tuple[List[str], List[str]]:
    """Expand ``--archs`` / ``--shapes`` strings (comma-separated ids or the
    literal ``all``) into validated kernel / kernel-shape name lists —
    the kernel-space sibling of ``campaign.resolve_grid``. ``all`` shapes
    means every registry shape of the selected kernels. Raises
    ``ValueError`` naming every unknown id."""
    kernel_list = list(KERNEL_NAMES) if kernels == "all" else kernels.split(",")
    unknown = [k for k in kernel_list if k not in KERNEL_NAMES]
    if shapes == "all":
        shape_list = [s.name for s in KERNEL_SHAPES
                      if s.kernel in kernel_list]
    else:
        shape_list = shapes.split(",")
        unknown += [s for s in shape_list if s not in KERNEL_SHAPE_BY_NAME]
    if unknown:
        raise ValueError(f"unknown kernel/shape: {unknown}")
    return kernel_list, shape_list


def kernel_grid_cells(kernels: Sequence[str], shapes: Sequence[str],
                      shard: Optional[Tuple[int, int]] = None,
                      ) -> List[Tuple[str, str]]:
    """The kernel campaign's (arch, shape) work list: every named shape
    paired with its own kernel (never a cross product across kernels),
    arch-encoded as ``kernel:<name>``, in sorted order so every shard and
    the queue seeding agree on cell numbering; ``shard=(i, n)`` keeps cells
    ``i::n``. Disjoint and exhaustive across shards."""
    cells = sorted({(kernel_arch(KERNEL_SHAPE_BY_NAME[s].kernel), s)
                    for s in shapes
                    if KERNEL_SHAPE_BY_NAME[s].kernel in kernels})
    if shard is None:
        return cells
    i, n = shard
    if not (0 <= i < n):
        raise ValueError(f"shard index {i} outside 0..{n - 1}")
    return cells[i::n]


def _correctness_stats(db, cells: Sequence[Dict]) -> Dict[str, int]:
    """The correctness-gate audit over a campaign's cells: how many
    candidates were checked against the ref.py oracle and how many were
    rejected (``infeasible`` rows whose reason names the gate)."""
    checked = rejected = 0
    for c in cells:
        for d in db.query(c["arch"], c["shape"], mesh=c["mesh"]):
            if d.fidelity == "measured":
                continue
            if "max_abs_err" in d.metrics:
                checked += 1
            if (d.status == "infeasible"
                    and str(d.reason).startswith("correctness gate")):
                rejected += 1
    return {"checked": checked, "rejected": rejected}


def run_kernel_campaign(kernels: Sequence[str], shapes: Sequence[str], *,
                        out_dir: Path | str, iterations: int = 2,
                        budget: int = 3, strategy: str = "ensemble",
                        gate_factor: Optional[float] = None,
                        gate_min_factor: Optional[float] = None,
                        measure_top_k: int = 0, measure_runs: int = 3,
                        measure_budget: Optional[int] = None,
                        objective: str = "bound_s",
                        db=None, resume: bool = True,
                        shard: Optional[Tuple[int, int]] = None,
                        queue: Optional[Path | str] = None,
                        queue_owner: Optional[str] = None,
                        queue_lease_s: float = 300.0,
                        queue_poll_s: float = 0.5,
                        seed: int = 0, verbose: bool = True) -> Dict:
    """Run (or resume) a kernel campaign over the ``(kernel, shape)`` grid —
    a static ``shard=(i, n)`` slice or (``queue=DIR``) whatever cells this
    worker wins from the shared :class:`~repro.launch.scheduler.CellQueue`
    — and return the summary dict. Same supervision contract as
    ``campaign.run_campaign``: resumable from per-cell reports, heartbeats
    in ``progress.json`` (every beat renews the current lease), shared
    content-addressed caches in queue mode, one-shot crash hook at cell
    boundaries, atomic JSON artifacts throughout."""
    if queue is not None and shard is not None:
        raise ValueError("--queue and --shard are mutually exclusive: the "
                         "queue replaces the static grid cut")
    if queue is not None and queue_poll_s <= 0:
        raise ValueError(f"queue_poll_s must be > 0 (got {queue_poll_s}): "
                         "0 busy-spins the idle-wait loop")
    if strategy not in KERNEL_STRATEGY_CHOICES:
        raise ValueError(
            f"--space kernels supports strategies {KERNEL_STRATEGY_CHOICES} "
            f"(got {strategy!r}); llm/transfer variants are plan-coupled")
    gate_err = validate_gate_args(gate_factor, gate_min_factor)
    if gate_err:
        raise ValueError(gate_err)
    measure_err = validate_measure_args(measure_top_k, measure_runs,
                                        measure_budget)
    if measure_err:
        raise ValueError(measure_err)
    objective_err = validate_objective_args(objective)
    if objective_err:
        raise ValueError(objective_err)

    from repro.core.cost_db import CostDB, featurize
    from repro.core.cost_model import CostModel
    from repro.core.design_space import PlanPoint
    from repro.core.eval_cache import DryRunCache
    from repro.core.evaluator import KernelEvaluator
    from repro.core.promotion import plan_front_promotions, plan_promotions
    from repro.search import PromotionLadder, SurrogateGate, make_strategy

    mesh_name = KERNEL_MESH_NAME
    out_dir = Path(out_dir)
    (out_dir / "reports").mkdir(parents=True, exist_ok=True)
    db = db or CostDB(out_dir / "cost_db.jsonl")
    q = CellQueue(queue, lease_s=queue_lease_s) if queue is not None else None
    owner = (sanitize_owner(queue_owner or f"pid{os.getpid()}")
             if q is not None else None)
    cache = (DryRunCache(q.cache_dir) if q is not None
             else DryRunCache.beside(db.path))
    measured_cache = DryRunCache(q.measured_dir if q is not None
                                 else Path(db.path).parent / "measured_cache")
    evaluator = KernelEvaluator(mesh=None, mesh_name=mesh_name, cache=cache,
                                measured_cache=measured_cache,
                                measure_runs=measure_runs)
    cost_model = CostModel.create(in_dim=featurize({}, {}).shape[0])
    gate_cls = PromotionLadder if measure_top_k > 0 else SurrogateGate
    gate = (gate_cls(cost_model, factor=gate_factor,
                     min_factor=gate_min_factor)
            if gate_factor is not None else None)

    def log(msg):
        if verbose:
            print(f"[kernel-campaign {mesh_name}] {msg}", flush=True)

    t0 = time.time()
    cells = kernel_grid_cells(kernels, shapes, shard) if q is None else []
    if q is not None:
        seeded = q.seed(kernel_grid_cells(kernels, shapes), mesh=mesh_name)
        if seeded:
            log(f"queue {q.root}: seeded {seeded} cell ticket(s)")
    cell_rows: List[Dict] = []
    cell_best: List[Dict] = []
    counts = {"ran": 0, "resumed": 0, "unsupported": 0}
    qstats = {"stolen": 0}
    mstate = {"budget_left": measure_budget}
    current_ticket: List[Optional[object]] = [None]

    prior_hb = read_progress(out_dir)
    evals0 = db.count()
    compiles0 = evaluator.compile_count
    pruned0 = evaluator.pruned_count
    compiles_prior = int(prior_hb.get("compiles_total", 0) or 0)
    pruned_prior = int(prior_hb.get("pruned_total", 0) or 0)
    cells_total = q.total() if q is not None else len(cells)

    def progress(status: str, *, cell: Optional[str] = None,
                 iteration: Optional[int] = None,
                 iter_stats: Optional[Dict] = None) -> None:
        # same heartbeat payload contract as the plan campaign: the
        # orchestrator's hang detection and aggregation read it unchanged;
        # every beat doubles as a lease renewal
        if q is not None and current_ticket[0] is not None:
            try:
                q.renew(current_ticket[0])
            except OSError:
                pass
        top = sorted((r for r in cell_best if r["bound_s"] is not None),
                     key=lambda r: r["bound_s"])[:5]
        compiles = evaluator.compile_count - compiles0
        pruned = evaluator.pruned_count - pruned0
        evals = db.count()
        payload = {
            "pid": os.getpid(), "mesh": mesh_name, "space": "kernels",
            "shard": f"{shard[0]}/{shard[1]}" if shard else None,
            "status": status,
            "cells_total": cells_total, "cells_done": len(cell_rows),
            **counts,
            "cell_in_progress": cell, "iteration": iteration,
            "evaluations": evals - evals0,
            "compiles": compiles, "pruned": pruned,
            "measured": evaluator.measured_count,
            "measured_replayed": evaluator.measured_replayed,
            "evaluations_total": evals,
            "compiles_total": compiles_prior + compiles,
            "pruned_total": pruned_prior + pruned,
            "best": top, "ts": round(time.time(), 3)}
        if q is not None:
            payload["queue"] = {**q.counts(), "owner": owner,
                                "stolen": qstats["stolen"]}
        if iter_stats:
            payload.update({f"iter_{k}": iter_stats.get(k) for k in
                            ("evaluated", "compiled", "pruned", "cache_hits",
                             "phase")})
        write_progress(out_dir, payload)

    def promote_heads(arch: str, shape: str) -> None:
        """Tier-2 promotion for one finished kernel cell (same dedupe and
        shared-cache replay semantics as the plan campaign; the correctness
        gate runs again on the executed output)."""
        if measure_top_k <= 0:
            return
        measured_keys = {d.point.get("__key__")
                         for d in db.measured_rows(arch, shape,
                                                   mesh=mesh_name)}
        if objective == "pareto":
            front = db.front(arch, shape, k=measure_top_k, mesh=mesh_name)
            promos = plan_front_promotions(front, measured_keys,
                                           top_k=measure_top_k,
                                           budget_left=mstate["budget_left"])
        else:
            heads = db.winners(arch, shape, k=measure_top_k, mesh=mesh_name)
            promos = plan_promotions(heads, measured_keys,
                                     top_k=measure_top_k,
                                     budget_left=mstate["budget_left"])
        for head in promos:
            progress("measuring", cell=f"{arch}/{shape}")
            point = PlanPoint(dims={k: v for k, v in head.point.items()
                                    if k != "__key__"})
            dp = evaluator.measure(arch, shape, point,
                                   modeled_bound_s=head.metrics.get("bound_s"))
            db.append(dp)
            if mstate["budget_left"] is not None:
                mstate["budget_left"] -= 1
            if dp.status == "ok":
                log(f"{arch}/{shape}: measured {point.key()} = "
                    f"{dp.metrics['measured_us']:.0f}us "
                    f"[{dp.metrics.get('backend')}]")
            else:
                log(f"{arch}/{shape}: measurement of {point.key()} -> "
                    f"{dp.status}: {dp.reason}")

    def note_cell(arch: str, shape: str) -> None:
        best = db.best(arch, shape, mesh=mesh_name)
        cell_best.append({"cell": f"{arch}/{shape}",
                          "bound_s": best.metrics.get("bound_s")
                          if best else None})
        progress("running")
        _injected_crash_hook(len(cell_rows))

    def process_cell(arch: str, shape: str) -> str:
        """Run/resume one kernel cell (reports, counters, heartbeat);
        returns the cell status — shared by the static and queue drive
        loops, mirroring the plan campaign's ``process_cell``."""
        rpath = cell_report_path(out_dir, arch, shape, mesh_name)
        prior = None
        if resume and rpath.exists():
            try:
                prior = json.loads(rpath.read_text())
            except json.JSONDecodeError:
                log(f"{arch}/{shape}: unreadable report — re-running cell")
        if prior is not None:
            counts["resumed"] += 1
            cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                              "status": "resumed",
                              "improvement": prior.get("improvement")})
            log(f"{arch}/{shape}: resumed (report exists)")
            promote_heads(arch, shape)
            note_cell(arch, shape)
            return "resumed"

        t_cell = time.time()
        report = _explore_kernel_cell(
            arch, shape, evaluator=evaluator, db=db, cost_model=cost_model,
            gate=gate, strategy=make_strategy(strategy, seed=seed,
                                              objective=objective),
            iterations=iterations, budget=budget, seed=seed,
            heartbeat=lambda info: progress(
                "running", cell=f"{arch}/{shape}",
                iteration=info.get("iteration"), iter_stats=info),
            log=log)
        report["status"] = "complete"
        report["wall_s"] = round(time.time() - t_cell, 1)
        write_json_atomic(rpath, report)
        counts["ran"] += 1
        cell_rows.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                          "status": "complete",
                          "improvement": report["improvement"]})
        log(f"{arch}/{shape}: done in {report['wall_s']}s "
            f"(improvement {report['improvement']:.2%}, "
            f"cache {cache.stats()})")
        promote_heads(arch, shape)
        note_cell(arch, shape)
        return "complete"

    progress("starting")
    if q is None:
        for arch, shape in cells:
            process_cell(arch, shape)
    else:
        while True:
            ticket = q.acquire(owner)
            if ticket is None:
                if q.drained():
                    break
                progress("waiting")
                time.sleep(queue_poll_s)
                continue
            current_ticket[0] = ticket
            log(f"{ticket.cell}: leased (attempt {ticket.attempt})")
            status = process_cell(ticket.arch, ticket.shape)
            current_ticket[0] = None
            if not q.complete(ticket, status=status):
                qstats["stolen"] += 1
                log(f"{ticket.cell}: lease lost before completion "
                    f"(stolen/reclaimed) — results kept, merge dedupes")

    cell_rows.sort(key=lambda c: (c["arch"], c["shape"], c["mesh"]))
    leaderboard = build_leaderboard(db, cell_rows, objective=objective)
    lb_path = write_json_atomic(out_dir / "leaderboard.json", leaderboard)

    def _num(x):
        return None if x is None or x != x else x

    bench_cells = []
    for c in cell_rows:
        try:
            rep = json.loads(cell_report_path(out_dir, c["arch"], c["shape"],
                                              mesh_name).read_text())
        except (OSError, json.JSONDecodeError):
            rep = {}
        default = rep.get("baseline") or {}
        best = rep.get("best") or {}
        bench_cells.append({
            "cell": f"{c['arch']}/{c['shape']}",
            "kernel": parse_kernel_arch(c["arch"]),
            "status": c["status"],
            "default_point": default.get("point"),
            "default_bound_s": _num(default.get("bound_s")),
            "tuned_point": best.get("point"),
            "tuned_bound_s": _num(best.get("bound_s")),
            "improvement": _num(c.get("improvement")),
            "incumbent_by_iteration": [_num(it.get("best_bound"))
                                       for it in rep.get("iterations") or []],
        })
    bench = {
        "schema": "kernels-v1",
        "mesh": mesh_name,
        "strategy": strategy,
        "measure_top_k": measure_top_k,
        "correctness": _correctness_stats(db, cell_rows),
        "tiers": {
            "surrogate_pruned": evaluator.pruned_count - pruned0,
            "dryrun_compiles": evaluator.compile_count - compiles0,
            "dryrun_cache": cache.stats(),
            "measured": evaluator.measured_count,
            "measured_replayed": evaluator.measured_replayed,
        },
        "cells": bench_cells,
    }
    bench_path = write_json_atomic(out_dir / "BENCH_kernels.json", bench)

    evals = db.count()
    summary = {
        "mesh": mesh_name, "space": "kernels", "cells": len(cell_rows),
        **counts,
        "shard": f"{shard[0]}/{shard[1]}" if shard else None,
        "queue": str(q.root) if q is not None else None,
        "queue_owner": owner,
        "stolen": qstats["stolen"] if q is not None else None,
        "strategy": strategy,
        "objective": objective,
        "wall_s": round(time.time() - t0, 1),
        "evaluations": evals - evals0,
        "compiles": evaluator.compile_count - compiles0,
        "pruned": evaluator.pruned_count - pruned0,
        "measured": evaluator.measured_count,
        "measured_replayed": evaluator.measured_replayed,
        "measure_top_k": measure_top_k,
        "evaluations_total": evals,
        "compiles_total": compiles_prior + evaluator.compile_count - compiles0,
        "pruned_total": pruned_prior + evaluator.pruned_count - pruned0,
        "correctness": _correctness_stats(db, cell_rows),
        "cache": cache.stats(),
        "leaderboard": str(lb_path),
        "bench": str(bench_path),
    }
    progress("done")
    log(f"summary: {summary}")
    return summary


def _explore_kernel_cell(arch: str, shape: str, *, evaluator, db, cost_model,
                         gate, strategy, iterations: int, budget: int,
                         seed: int, heartbeat=None, log=print) -> Dict:
    """The per-cell search loop: DSELoop's seed/propose/gate/evaluate/
    observe skeleton over one kernel cell. Returns the report dict
    (``baseline`` / ``best`` / ``iterations`` / ``improvement``) that the
    campaign writes to ``reports/`` — same shape the plan campaign's
    ``_cell_report`` produces, so resume and ``BENCH_*`` trajectory readers
    are shared."""
    from repro.core.design_space import KernelTemplate, baseline_kernel_point
    from repro.core.kernel_space import kernel_workload
    from repro.search import SearchState, select_candidates

    kshape = KERNEL_SHAPE_BY_NAME[shape]
    template = KernelTemplate(kshape, evaluator.device)
    wl = kernel_workload(kshape)
    cache = evaluator.cache

    def beat(info):
        if heartbeat is not None:
            heartbeat(info)

    def dp_summary(dp):
        if dp is None or dp.status != "ok":
            return None
        return {"point": {k: v for k, v in sorted(dp.point.items())
                          if k != "__key__"},
                "bound_s": dp.metrics.get("bound_s"),
                "max_abs_err": dp.metrics.get("max_abs_err")}

    # iteration 0: the shipped-default tile config is the expert seed
    seed_point = baseline_kernel_point(kshape, template)
    compiles_b = evaluator.compile_count
    hits_b = cache.hits if cache is not None else 0
    base_dp = evaluator.evaluate_batch(arch, shape, [seed_point],
                                       source="expert", iteration=0)[0]
    db.append(base_dp)
    beat({"iteration": 0, "phase": "baseline", "evaluated": 1,
          "compiled": evaluator.compile_count - compiles_b, "pruned": 0,
          "cache_hits": (cache.hits - hits_b) if cache is not None else 0,
          "best_bound": base_dp.metrics.get("bound_s")})
    log(f"{arch}/{shape}: baseline {base_dp.status} "
        f"bound={base_dp.metrics.get('bound_s')} "
        f"err={base_dp.metrics.get('max_abs_err')}")

    iters: List[Dict] = []
    incumbent = base_dp if base_dp.status == "ok" else None
    for it in range(1, iterations + 1):
        state = SearchState(
            arch=arch, shape=shape, cfg=None, cell=kshape, template=template,
            db=db, iteration=it, budget=budget,
            incumbent=incumbent or base_dp, pool=[incumbent or base_dp],
            cost_model=cost_model, workload=wl, mesh=evaluator.mesh_name)
        cands = strategy.propose(state)
        ranked = select_candidates(state, cands)
        beat({"iteration": it, "phase": "proposed", "evaluated": 0,
              "compiled": 0, "pruned": 0, "cache_hits": 0,
              "best_bound": (incumbent.metrics.get("bound_s")
                             if incumbent else None)})
        if gate is not None:
            gate.calibrate(db, arch=arch, shape=shape,
                           mesh=evaluator.mesh_name)
        hits0 = cache.hits if cache is not None else 0
        compiles_i = evaluator.compile_count
        pruned_i = evaluator.pruned_count
        new_dps = evaluator.evaluate_batch(
            arch, shape, [c.point for c in ranked],
            source=[c.source for c in ranked], iteration=it, gate=gate,
            incumbent_bound=(incumbent.metrics.get("bound_s")
                             if incumbent is not None else None))
        # one pruned row per design, however often it is re-predicted
        prior_pruned = (db.keys(arch, shape)
                        - db.keys(arch, shape, include_pruned=False))
        db.append_many([dp for dp in new_dps
                        if not (dp.status == "pruned"
                                and dp.point.get("__key__") in prior_pruned)])
        strategy.observe(new_dps)
        ok_dps = [d for d in new_dps
                  if d.status == "ok" and d.metrics.get("bound_s")]
        cands_pool = ok_dps + ([incumbent] if incumbent is not None else [])
        incumbent = (min(cands_pool, key=lambda d: d.metrics["bound_s"])
                     if cands_pool else None)
        # periodic surrogate fit on the grown DB (pretrain no-ops < 4 rows)
        if cost_model is not None and it % 2 == 0:
            cost_model.pretrain(db)
        entry = {
            "iteration": it,
            "evaluated": len(new_dps),
            "compiled": evaluator.compile_count - compiles_i,
            "pruned": evaluator.pruned_count - pruned_i,
            "cache_hits": (cache.hits - hits0) if cache is not None else 0,
            "best_bound": (incumbent.metrics.get("bound_s")
                           if incumbent else None),
        }
        iters.append(entry)
        beat({**entry, "phase": "iteration"})

    best = incumbent or db.best(arch, shape, mesh=evaluator.mesh_name)
    b0 = base_dp.metrics.get("bound_s") if base_dp.status == "ok" else None
    b1 = best.metrics.get("bound_s") if best is not None else None
    return {
        "arch": arch, "shape": shape,
        "baseline": dp_summary(base_dp),
        "best": dp_summary(best),
        "iterations": iters,
        # same contract as LoopReport.improvement(): best/baseline bound
        # ratio, 1.0 when either side is missing
        "improvement": (b1 / b0) if (b0 and b1) else 1.0,
    }
