"""Self-orchestrating sharded DSE campaigns: one command, n supervised shards.

Replaces the manual quickstart workflow (run n ``campaign --shard i/n``
processes by hand, then ``merge_db``) with a supervisor that owns the whole
lifecycle:

* **spawn** — dispatches the n shard campaigns (``python -m
  repro.launch.campaign --shard i/n``) through a pluggable
  :class:`~repro.launch.executors.ShardExecutor`: local subprocesses by
  default (``--executor local``), remote hosts over ssh (``--executor ssh
  --hosts h0,h1,...``), or the ssh code path with a local transport
  (``--executor loopback``, CI/tests); each shard gets its own log file and
  output dir ``OUT/shards/shard{i}``;
* **monitor** — polls every shard's atomically-replaced ``progress.json``
  heartbeat and streams an aggregated live leaderboard to stdout. The
  campaign refreshes the heartbeat after **every proposal round, evaluation
  batch, and loop iteration**, not just at cell boundaries, so hang
  detection stays sharp even when one cell takes hours;
* **heal** — a shard that exits nonzero, or whose heartbeat goes stale for
  ``--hang-timeout`` seconds, is killed (whole process group, local or
  remote) and relaunched with the same command. Campaign resume semantics
  make the restart cheap and safe: completed cells are skipped via their
  report files, and the shard's content-addressed dry-run cache replays any
  compiles the crashed attempt already paid for — no cell is evaluated
  twice. A shard that crashes more than ``--max-restarts`` times fails the
  run (every other shard is terminated, nothing is merged);
* **merge** — on success, each shard dir is collected to this machine
  (a no-op for local shards, an rsync for ssh ones) and folded into
  ``--out`` via ``repro.launch.merge_db`` (dedup by design identity,
  earliest record wins), so the single invocation ends with the same
  byte-stable ``leaderboard.json`` the manual shard+merge flow produces —
  whichever executor ran the shards;
* **schedule** (``--queue``) — instead of cutting the grid statically
  (``--shard i/n``), seed a crash-safe file-backed cell queue
  (``repro.launch.scheduler``) under ``OUT/queue/`` and let every shard
  pull its next cell under a heartbeat-renewed lease. The orchestrator is
  the scheduler: it releases a crashed shard's leases immediately on
  restart (no waiting out the deadline), and it **steals** — when a leased
  cell's age exceeds ``--steal-factor`` x the fleet's median completed-cell
  duration (and at least ``--steal-min-s``) while another shard sits idle,
  the lease is expired back to pending so the idle shard picks it up;
  the slow shard's in-flight work is surrendered gracefully and every
  compile it already paid for replays from the queue-shared dry-run cache.
  The merged leaderboard stays byte-identical to the static shard+merge
  flow on the same grid — steals and kills included.

Quickstart (the whole campaign, supervised, one command):

    PYTHONPATH=src python -m repro.launch.orchestrator \\
        --archs all --shapes all --shards 2 --out artifacts/run

    # dynamic cell queue + work stealing instead of a static grid cut
    PYTHONPATH=src python -m repro.launch.orchestrator \\
        --archs all --shapes all --shards 2 --queue --out artifacts/run

Fault injection (tests/CI): ``--inject-kill I:K`` arms a one-shot crash in
shard I after K completed cells — the shard dies abruptly at a cell boundary
(exit code 86, via the campaign's ``REPRO_CAMPAIGN_CRASH_TOKEN`` hook) and
the supervisor must restart it. Because the crash lands between cells, the
healed run's merged leaderboard is byte-identical to an uninterrupted one;
tier-1 asserts exactly that (``tests/test_orchestrator.py``). The token is
a local file, so injection works with the ``local`` and ``loopback``
executors (a real ssh shard never sees it).

Pure supervision — this module never imports jax, so ``--help`` and the
monitoring loop stay instant no matter what the shards are compiling.
"""
from __future__ import annotations

import argparse
import signal
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.campaign import (MESH_CHOICES, OBJECTIVE_CHOICES,
                                   STRATEGY_CHOICES, resolve_grid,
                                   shard_cells, validate_gate_args,
                                   validate_measure_args,
                                   validate_objective_args)
from repro.launch.executors import (EXECUTOR_CHOICES, ShardExecutor,
                                    ShardProc, make_executor)
from repro.launch.ioutil import write_json_atomic
from repro.launch.scheduler import CellQueue

CRASH_TOKEN_FILE = ".crash_token"
QUEUE_DIR = "queue"


def child_env() -> Dict[str, str]:
    """The shard subprocess environment: the supervisor's env with this
    checkout's ``src`` prepended to PYTHONPATH, so ``python -m
    repro.launch.campaign`` resolves the same code the supervisor runs
    (ssh-dispatched shards get a remote-checkout PYTHONPATH instead, see
    ``SSHExecutor._forward_env``)."""
    import os

    env = dict(os.environ)
    src = str(Path(__file__).resolve().parents[2])
    prior = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + prior if prior else "")
    return env


def shard_dirs_for(out_dir: Path, shards: int) -> List[Path]:
    """The canonical per-shard output dirs: ``OUT/shards/shard{i}`` —
    deliberately *inside* ``--out`` but distinct from it, satisfying
    ``merge_db``'s out-must-not-alias-a-shard rule."""
    return [Path(out_dir) / "shards" / f"shard{i}" for i in range(shards)]


def build_shard_cmd(i: int, shards: int, shard_dir: Path, *, archs: str,
                    shapes: str, mesh: str, iterations: int, budget: int,
                    workers: int, strategy: str,
                    gate_factor: Optional[float],
                    gate_min_factor: Optional[float] = None, llm: str,
                    measure_top_k: int = 0, measure_runs: int = 3,
                    measure_budget: Optional[int] = None,
                    queue_dir: Optional[Path] = None,
                    queue_lease_s: float = 300.0,
                    space: str = "plans",
                    objective: str = "bound_s") -> List[str]:
    """The exact ``repro.launch.campaign`` argv for shard ``i`` of
    ``shards`` — one place, so supervisor restarts always replay the
    original command (campaign resume makes that idempotent). With
    ``queue_dir`` the shard pulls cells from the queue as owner
    ``shard{i}`` instead of taking the static ``--shard i/n`` slice.
    Remote executors rewrite only the interpreter and the ``--out`` value
    (the queue path must be a shared filesystem when shards run
    remotely)."""
    cmd = [sys.executable, "-m", "repro.launch.campaign",
           "--archs", archs, "--shapes", shapes, "--mesh", mesh,
           "--iterations", str(iterations), "--budget", str(budget),
           "--workers", str(workers), "--strategy", strategy,
           "--llm", llm, "--out", str(shard_dir)]
    if space != "plans":
        # appended only for non-default spaces: plan-campaign argv stays
        # byte-identical to what pre---space supervisors replayed
        cmd += ["--space", space]
    if objective != "bound_s":
        # same append-only-non-default contract as --space
        cmd += ["--objective", objective]
    if queue_dir is not None:
        # absolute: the queue is the shards' rendezvous, and remote
        # executors assume one shared-filesystem path on every host
        cmd += ["--queue", str(Path(queue_dir).resolve()),
                "--queue-owner", f"shard{i}",
                "--queue-lease-s", str(queue_lease_s)]
    else:
        cmd += ["--shard", f"{i}/{shards}"]
    if gate_factor is not None:
        cmd += ["--gate-factor", str(gate_factor)]
    if gate_min_factor is not None:
        cmd += ["--gate-min-factor", str(gate_min_factor)]
    if measure_top_k > 0:
        cmd += ["--measure-top-k", str(measure_top_k),
                "--measure-runs", str(measure_runs)]
        if measure_budget is not None:
            cmd += ["--measure-budget", str(measure_budget)]
    return cmd


def parse_inject_kill(spec: Optional[str]) -> Optional[Tuple[int, int]]:
    """Parse an ``--inject-kill I:K`` spec into ``(shard_index,
    after_cells)``; ``None`` passes through. Raises ``ValueError`` on
    malformed specs or non-positive K."""
    if not spec:
        return None
    try:
        i, k = (int(x) for x in spec.split(":"))
    except ValueError:
        raise ValueError(f"--inject-kill must look like I:K, got {spec!r}")
    if i < 0 or k < 1:
        raise ValueError(f"--inject-kill needs I >= 0 and K >= 1, got {spec}")
    return (i, k)


def aggregate_best(shard_states: Sequence[ShardProc], k: int = 5) -> List[Dict]:
    """Fold the shards' heartbeat leaderboards into one: the ``k`` fastest
    cells (bound_s seconds, ascending) across every shard's last
    ``progress.json``. Purely cosmetic/streaming — the authoritative
    leaderboard is rebuilt from the merged DB at the end."""
    rows = [r for s in shard_states
            for r in s.last_payload.get("best", [])
            if r.get("bound_s") is not None]
    rows.sort(key=lambda r: (r["bound_s"], r.get("cell", "")))
    return rows[:k]


def _status_line(shard_states: Sequence[ShardProc]) -> str:
    """One-line aggregated view of every shard + the global incumbent.
    ``evals`` counts are *run-local* (this attempt's work, see the campaign
    heartbeat contract), so a restarted shard never appears to redo the
    work its resume skipped."""
    parts = []
    for s in shard_states:
        p = s.last_payload
        done, total = p.get("cells_done", 0), p.get("cells_total", "?")
        tag = ("failed" if s.failed else "done" if s.done else
               p.get("status", "starting"))
        cell = p.get("cell_in_progress")
        at = (f" @{cell}#{p.get('iteration')}" if cell else "")
        extra = f", {p.get('evaluations', 0)} evals" if p else ""
        restarts = f", restarts {s.restarts}" if s.restarts else ""
        parts.append(f"shard{s.index} {done}/{total} {tag}{at}{extra}{restarts}")
    best = aggregate_best(shard_states, k=1)
    if best:
        parts.append(f"best {best[0]['bound_s']:.4g}s ({best[0]['cell']})")
    return " | ".join(parts)


def plan_steals(q: CellQueue, shard_states: Sequence[ShardProc], *,
                steal_factor: float, steal_min_s: float, max_steals: int,
                now: float) -> List:
    """The work-stealing rule: which leased cells should be expired back to
    pending *right now*. A cell is steal-eligible when

    * its lease age exceeds ``max(steal_min_s, steal_factor x median)``,
      where the median is over the fleet's completed-cell durations
      (``status == "complete"`` done tickets — resumed/unsupported cells
      finish in milliseconds and would poison the scale), and
    * it has been stolen fewer than ``max_steals`` times (a cell that is
      slow *everywhere* must not ping-pong forever), and
    * at least one *other* live shard is idle (heartbeat ``status ==
      "waiting"``) — stealing without a taker just burns the owner's work.

    At most one steal per idle shard per pass. Returns the tickets to
    steal (the caller performs the steal — and supplies ``now``, which is
    *required*: a pure decision function never consults the wall clock, so
    a recorded campaign replays byte-stably and the invariant linter's
    RPR003 rule holds; unit-testable without a fleet)."""
    durations = [d for t in q.tickets("done")
                 if t.status == "complete" and (d := t.duration())]
    if not durations:
        return []  # no completed cell yet: no scale to judge "slow" against
    durations.sort()
    med = durations[len(durations) // 2]
    threshold = max(steal_min_s, steal_factor * med)
    idle = {f"shard{s.index}" for s in shard_states
            if not s.done and not s.failed
            and s.last_payload.get("status") == "waiting"}
    if not idle:
        return []
    out = []
    for t in q.tickets("leased"):
        if t.owner in idle or t.steals >= max_steals:
            continue
        age = now - (t.leased_at if t.leased_at is not None else now)
        if age > threshold:
            out.append(t)
        if len(out) >= len(idle):
            break
    return out


def run_orchestrator(*, archs: str, shapes: str, shards: int,
                     out_dir: Path | str, mesh: str = "small",
                     iterations: int = 2, budget: int = 3, workers: int = 2,
                     strategy: str = "ensemble",
                     gate_factor: Optional[float] = None,
                     gate_min_factor: Optional[float] = None,
                     measure_top_k: int = 0, measure_runs: int = 3,
                     measure_budget: Optional[int] = None,
                     llm: str = "mock",
                     poll_interval: float = 1.0, hang_timeout: float = 300.0,
                     max_restarts: int = 2,
                     inject_kill: Optional[Tuple[int, int]] = None,
                     queue: bool = False, steal_factor: float = 4.0,
                     steal_min_s: float = 20.0, max_steals: int = 2,
                     queue_lease_s: float = 300.0,
                     executor: str = "local",
                     hosts: Optional[Sequence[str]] = None,
                     remote_root: Optional[str] = None,
                     remote_repo: Optional[str] = None,
                     remote_python: str = "python3",
                     space: str = "plans",
                     objective: str = "bound_s",
                     verbose: bool = True) -> Dict:
    """Run the full supervised campaign; returns the summary dict (also
    written to ``OUT/summary.json``).

    Dispatches ``shards`` campaign processes over the sorted arch x shape
    grid through the chosen :class:`~repro.launch.executors.ShardExecutor`,
    supervises them (crash/hang restart with resume, up to ``max_restarts``
    per shard), collects every shard dir local, and merges into ``out_dir``
    on success. ``hang_timeout`` is wall seconds without a heartbeat
    *change* — the campaign heartbeats after every proposal round,
    evaluation batch, and loop iteration, so the timeout must exceed the
    slowest single iteration *step* (one proposal round, one evaluation
    batch, or one fine-tune tail; budget a few extra seconds for the jax
    import before a fresh shard's first beat), never a whole cell. Raises
    ``RuntimeError`` when a shard exhausts its restart budget (remaining
    shards are terminated and nothing is merged — the shard dirs stay
    resumable) and ``ValueError`` on inconsistent arguments (unknown grid
    ids, ssh without hosts, ``--inject-kill`` with a remote executor).
    Determinism: with the mock LLM and a transfer-free strategy the merged
    leaderboard is byte-identical to the manual shard+merge flow — kills or
    not (injected crashes land at cell boundaries; resume skips completed
    cells), whichever executor ran the shards, and static cut or dynamic
    ``queue=True`` cell queue (steals included: a stolen cell's results
    dedupe at merge).

    Queue mode (``queue=True``) seeds ``OUT/queue/`` from the grid before
    any shard spawns, releases a crashed/hung shard's leases immediately on
    restart, and runs the steal rule (:func:`plan_steals`) every poll."""
    if space == "kernels":  # fail fast, and seed the queue from the same grid
        from repro.launch.kernel_cell import (KERNEL_STRATEGY_CHOICES,
                                              resolve_kernel_grid)

        if strategy not in KERNEL_STRATEGY_CHOICES:
            raise ValueError(
                f"--space kernels supports strategies "
                f"{KERNEL_STRATEGY_CHOICES} (got {strategy!r})")
        grid_archs, grid_shapes = resolve_kernel_grid(archs, shapes)
    else:
        grid_archs, grid_shapes = resolve_grid(archs, shapes)
    objective_err = validate_objective_args(objective)
    if objective_err:
        raise ValueError(objective_err)
    if shards < 1:
        raise ValueError(f"need shards >= 1, got {shards}")
    if inject_kill is not None and not (0 <= inject_kill[0] < shards):
        raise ValueError(f"--inject-kill shard {inject_kill[0]} outside "
                         f"0..{shards - 1}")
    if inject_kill is not None and executor == "ssh":
        raise ValueError("--inject-kill arms a local token file; it is "
                         "supported with --executor local or loopback only")
    if queue and executor == "ssh" and remote_root is not None:
        raise ValueError("--queue needs every shard to see the queue dir at "
                         "the same path (shared filesystem); --remote-root "
                         "relocates shard dirs, so the two cannot combine — "
                         "drop --remote-root or use --executor "
                         "local|loopback")
    ex: ShardExecutor = make_executor(
        executor, hosts=hosts, remote_root=remote_root,
        remote_repo=remote_repo, remote_python=remote_python)
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    def log(msg: str) -> None:
        if verbose:
            print(f"[orchestrator] {msg}", flush=True)

    q: Optional[CellQueue] = None
    if queue:
        q = CellQueue(out_dir / QUEUE_DIR, lease_s=queue_lease_s)
        if space == "kernels":
            from repro.launch.kernel_cell import (KERNEL_MESH_NAME,
                                                  kernel_grid_cells)

            # same cells + mesh tag the shard campaigns seed with, so the
            # supervisor's seeding stays an idempotent no-op for them
            cells, seed_mesh = (kernel_grid_cells(grid_archs, grid_shapes),
                                KERNEL_MESH_NAME)
        else:
            cells, seed_mesh = shard_cells(grid_archs, grid_shapes), mesh
        seeded = q.seed(cells, mesh=seed_mesh)
        c = q.counts()
        log(f"queue {q.root}: seeded {seeded} ticket(s) "
            f"({c['done']} already done, {c['pending']} pending)")

    states: List[ShardProc] = []
    for i, sd in enumerate(shard_dirs_for(out_dir, shards)):
        env = child_env()
        # the shard's fleet position, for test preludes that slow exactly
        # one shard (REPRO_ prefix ⇒ forwarded by the remote executors too)
        env["REPRO_SHARD_INDEX"] = str(i)
        if inject_kill is not None and inject_kill[0] == i:
            sd.mkdir(parents=True, exist_ok=True)
            token = sd / CRASH_TOKEN_FILE
            token.write_text("armed")
            env["REPRO_CAMPAIGN_CRASH_TOKEN"] = str(token)
            env["REPRO_CAMPAIGN_CRASH_AFTER_CELLS"] = str(inject_kill[1])
            log(f"shard{i}: armed one-shot crash after "
                f"{inject_kill[1]} cell(s)")
        cmd = build_shard_cmd(i, shards, sd, archs=archs, shapes=shapes,
                              mesh=mesh, iterations=iterations, budget=budget,
                              workers=workers, strategy=strategy,
                              gate_factor=gate_factor,
                              gate_min_factor=gate_min_factor, llm=llm,
                              measure_top_k=measure_top_k,
                              measure_runs=measure_runs,
                              measure_budget=measure_budget,
                              queue_dir=q.root if q is not None else None,
                              queue_lease_s=queue_lease_s, space=space,
                              objective=objective)
        states.append(ShardProc(index=i, out_dir=sd, cmd=cmd, env=env))

    t0 = time.time()
    total_restarts = 0
    steals = 0
    lease_reclaims = 0
    last_line = ""
    try:
        for s in states:
            ex.spawn(s)
            log(f"shard{s.index}: pid {s.proc.pid} [{ex.name}] -> {s.out_dir}")

        while not all(s.done or s.failed for s in states):
            time.sleep(poll_interval)
            for s in states:
                if s.done or s.failed:
                    continue
                payload = ex.read_heartbeat(s)
                # per-shard clock, stamped AFTER the (possibly slow, e.g.
                # ssh) heartbeat fetch: a stalled transport on one shard
                # must never age another shard's hang clock
                now = time.time()
                if payload and payload != s.last_payload:
                    s.last_payload = payload
                    s.last_beat = now
                rc = ex.poll(s)
                crashed = rc is not None and rc != 0
                hung = rc is None and (now - s.last_beat) > hang_timeout
                if rc == 0:
                    s.done = True
                    s.close_log()
                    # one final read: the shard's last heartbeat ("done",
                    # full counts) may have landed after this poll's read
                    s.last_payload = ex.read_heartbeat(s) or s.last_payload
                    log(f"shard{s.index}: completed "
                        f"({s.last_payload.get('cells_done', '?')} cells)")
                elif crashed or hung:
                    # unconditional: a crashed leader can leave pool workers
                    # mid-compile just like a hung one; no-op once reaped
                    ex.signal(s, signal.SIGKILL)
                    if hung:
                        s.proc.wait()
                    s.close_log()
                    why = (f"no heartbeat for {hang_timeout:.0f}s" if hung
                           else f"exit code {rc}")
                    if s.restarts >= max_restarts:
                        # fail fast: terminating the healthy shards (finally
                        # block) beats burning hours on a run that can no
                        # longer merge
                        s.failed = True
                        log(f"shard{s.index}: {why}; restart budget "
                            f"({max_restarts}) exhausted — giving up "
                            f"(log: {s.log_path})")
                        raise RuntimeError(
                            f"shard {s.index} failed after {max_restarts} "
                            f"restart(s) ({why}); shard dirs under "
                            f"{out_dir / 'shards'} remain resumable "
                            f"(re-run the same command)")
                    s.restarts += 1
                    total_restarts += 1
                    if q is not None:
                        # the owner is known-dead: reclaim its leases now
                        # instead of waiting out their deadlines
                        released = q.release_owner(f"shard{s.index}")
                        lease_reclaims += len(released)
                        for t in released:
                            log(f"shard{s.index}: released lease on "
                                f"{t.cell} (attempt {t.attempt})")
                    log(f"shard{s.index}: {why}; restarting with resume "
                        f"(attempt {s.restarts + 1})")
                    ex.spawn(s)
            if q is not None:
                # scheduler pass: deadline reclaims (belt and braces — the
                # shards' acquirers reclaim too) and the steal rule
                for t in q.reclaim_expired():
                    lease_reclaims += 1
                    log(f"queue: lease on {t.cell} expired — reclaimed "
                        f"(attempt {t.attempt})")
                for t in plan_steals(q, states, steal_factor=steal_factor,
                                     steal_min_s=steal_min_s,
                                     max_steals=max_steals, now=time.time()):
                    if q.steal(t) is not None:
                        steals += 1
                        log(f"queue: stole {t.cell} from {t.owner} "
                            f"(lease age beat the fleet median; "
                            f"steal #{t.steals + 1} for this cell)")
            line = _status_line(states)
            if line != last_line:
                last_line = line
                log(line)
    finally:
        for s in states:
            if s.proc is not None and s.proc.poll() is None:
                ex.signal(s, signal.SIGTERM)
                try:
                    s.proc.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    ex.signal(s, signal.SIGKILL)
                    s.proc.wait()
            s.close_log()

    for s in states:
        ex.collect(s)

    from repro.launch.merge_db import merge

    merged = merge([s.out_dir for s in states], out_dir, verbose=verbose,
                   extra_cache_dirs=([q.cache_dir, q.measured_dir]
                                     if q is not None else None),
                   objective=objective)
    queue_cells = q.counts() if q is not None else None
    summary = {
        "out": str(out_dir),
        "shards": shards,
        "objective": objective,
        "executor": ex.name,
        "hosts": list(hosts) if hosts else None,
        # queue mode counts DONE tickets, not the sum of shard-local
        # cells_done: a stolen cell is worked by two shards but is one cell
        "cells": (queue_cells["done"] if queue_cells is not None else
                  sum(s.last_payload.get("cells_done", 0) for s in states)),
        "restarts": total_restarts,
        "restarts_per_shard": {f"shard{s.index}": s.restarts for s in states},
        "queue": str(q.root) if q is not None else None,
        "queue_cells": queue_cells,
        "steals": steals,
        "lease_reclaims": lease_reclaims,
        "max_lease_attempts": (max((t.attempt for t in q.tickets("done")),
                                   default=0) if q is not None else None),
        "evaluations": merged["datapoints"],
        "duplicates_dropped": merged["duplicates_dropped"],
        "best": aggregate_best(states),
        "wall_s": round(time.time() - t0, 1),
        "leaderboard": merged["leaderboard"],
    }
    write_json_atomic(out_dir / "summary.json", summary)
    log(f"summary: {summary}")
    return summary


def build_parser() -> argparse.ArgumentParser:
    """The orchestrator CLI surface, importable without touching jax (the
    quickstart drift checker parses documented commands against it)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.orchestrator",
        description="spawn, supervise, heal, and merge a sharded DSE "
                    "campaign in one command")
    ap.add_argument("--space", default="plans",
                    choices=["plans", "kernels"],
                    help="design space the shards explore (forwarded to "
                         "every shard): 'kernels' tunes Pallas kernel tile "
                         "configs — --archs become kernel names, --shapes "
                         "KERNEL_SHAPES names, --mesh is ignored")
    ap.add_argument("--archs", default="qwen3-0.6b,stablelm-3b",
                    help="comma-separated arch ids, or 'all' "
                         "(--space kernels: kernel names)")
    ap.add_argument("--shapes", default="train_4k,decode_32k",
                    help="comma-separated shape cells, or 'all' "
                         "(--space kernels: kernel shape names)")
    ap.add_argument("--shards", type=int, default=2,
                    help="number of campaign processes to dispatch")
    ap.add_argument("--out", default="artifacts/run",
                    help="merged campaign dir (shards live in OUT/shards/)")
    ap.add_argument("--mesh", default="small", choices=list(MESH_CHOICES))
    ap.add_argument("--iterations", type=int, default=2)
    ap.add_argument("--budget", type=int, default=3,
                    help="evaluations per loop iteration")
    ap.add_argument("--workers", type=int, default=2,
                    help="parallel dry-run compile processes per shard")
    ap.add_argument("--strategy", default="ensemble",
                    choices=list(STRATEGY_CHOICES))
    ap.add_argument("--gate-factor", type=float, default=None,
                    help="surrogate gate factor, forwarded to every shard "
                         "(must be > 1)")
    ap.add_argument("--gate-min-factor", type=float, default=None,
                    help="anneal target for the gate factor, forwarded to "
                         "every shard (must be in (1, gate-factor]; "
                         "requires --gate-factor)")
    ap.add_argument("--measure-top-k", type=int, default=0, metavar="K",
                    help="promotion ladder tier 2, forwarded to every "
                         "shard: execute and time each cell's K best "
                         "designs (0 = off); measured rows merge "
                         "byte-stably and dedupe exactly-once via the "
                         "shared measured cache")
    ap.add_argument("--measure-runs", type=int, default=3, metavar="N",
                    help="timed executions per measurement, forwarded to "
                         "every shard (min reported)")
    ap.add_argument("--measure-budget", type=int, default=None, metavar="M",
                    help="per-shard cap on tier-2 measurements (requires "
                         "--measure-top-k)")
    ap.add_argument("--llm", default="mock", choices=["mock", "ollama"])
    ap.add_argument("--objective", default="bound_s",
                    choices=list(OBJECTIVE_CHOICES),
                    help="ranking mode, forwarded to every shard and to the "
                         "final merge: scalar bound_s heads (default, "
                         "byte-identical to pre-pareto leaderboards) or "
                         "dominance-ranked pareto fronts over the full "
                         "objective vector")
    ap.add_argument("--queue", action="store_true",
                    help="dynamic scheduling: seed a crash-safe cell queue "
                         "under OUT/queue/ and let shards pull leases from "
                         "it instead of taking static --shard i/n slices; "
                         "enables lease release on restart and work "
                         "stealing")
    ap.add_argument("--steal-factor", type=float, default=4.0,
                    help="steal a leased cell once its age exceeds this "
                         "multiple of the fleet's median completed-cell "
                         "duration (queue mode; also needs --steal-min-s "
                         "and an idle shard)")
    ap.add_argument("--steal-min-s", type=float, default=20.0,
                    help="never steal a lease younger than this many "
                         "seconds (queue mode)")
    ap.add_argument("--max-steals", type=int, default=2,
                    help="per-cell steal budget: a cell slow everywhere "
                         "must not ping-pong between shards forever "
                         "(queue mode)")
    ap.add_argument("--queue-lease-s", type=float, default=300.0,
                    help="lease length forwarded to every shard; renewed "
                         "each heartbeat, so it must exceed the slowest "
                         "single iteration step (queue mode)")
    ap.add_argument("--executor", default="local",
                    choices=list(EXECUTOR_CHOICES),
                    help="where shards run: local subprocesses, remote "
                         "hosts over ssh, or the ssh path with a local "
                         "transport (loopback; tests/CI)")
    ap.add_argument("--hosts", default=None,
                    help="comma-separated ssh hosts for --executor ssh "
                         "(round-robin by shard index)")
    ap.add_argument("--remote-root", default=None,
                    help="shard output root on the remote host (default: "
                         "the same absolute path as the local shard dir)")
    ap.add_argument("--remote-repo", default=None,
                    help="repo checkout path on the remote host (default: "
                         "this checkout's path)")
    ap.add_argument("--remote-python", default="python3",
                    help="python interpreter on the remote host")
    ap.add_argument("--poll-interval", type=float, default=1.0,
                    help="seconds between supervisor polls")
    ap.add_argument("--hang-timeout", type=float, default=300.0,
                    help="seconds without a heartbeat change before a shard "
                         "is declared hung and restarted; the campaign "
                         "heartbeats every proposal round / evaluation "
                         "batch / iteration, so this must exceed the "
                         "slowest single step (never a whole cell)")
    ap.add_argument("--max-restarts", type=int, default=2,
                    help="crash/hang restarts allowed per shard before the "
                         "run fails")
    ap.add_argument("--inject-kill", default=None, metavar="I:K",
                    help="fault injection (tests/CI): crash shard I once "
                         "after K completed cells and let the supervisor "
                         "heal it (local/loopback executors only)")
    return ap


def main():
    """CLI entry: validate arguments and hand off to
    :func:`run_orchestrator`. Exits 2 on bad arguments, 1 when a shard
    exhausts its restart budget."""
    ap = build_parser()
    args = ap.parse_args()
    gate_err = validate_gate_args(args.gate_factor, args.gate_min_factor)
    if gate_err:
        ap.error(gate_err)
    measure_err = validate_measure_args(args.measure_top_k, args.measure_runs,
                                        args.measure_budget)
    if measure_err:
        ap.error(measure_err)
    objective_err = validate_objective_args(args.objective)
    if objective_err:
        ap.error(objective_err)
    if args.shards < 1:
        ap.error(f"--shards must be >= 1, got {args.shards}")
    if args.executor == "ssh" and not args.hosts:
        ap.error("--executor ssh requires --hosts h0,h1,...")
    if args.queue and args.queue_lease_s <= 0:
        ap.error(f"--queue-lease-s must be > 0, got {args.queue_lease_s}")
    try:
        inject = parse_inject_kill(args.inject_kill)
    except ValueError as e:
        ap.error(str(e))
    if args.space == "kernels":
        from repro.launch.kernel_cell import (KERNEL_STRATEGY_CHOICES,
                                              resolve_kernel_grid)

        # the plan-grid defaults are meaningless kernel ids (same remap as
        # the campaign CLI): untouched --archs/--shapes mean the whole grid
        if args.archs == ap.get_default("archs"):
            args.archs = "all"
        if args.shapes == ap.get_default("shapes"):
            args.shapes = "all"
        if args.strategy not in KERNEL_STRATEGY_CHOICES:
            ap.error(f"--space kernels supports --strategy "
                     f"{KERNEL_STRATEGY_CHOICES}; llm/transfer variants "
                     f"are plan-coupled (got {args.strategy!r})")
        try:
            resolve_kernel_grid(args.archs, args.shapes)
        except ValueError as e:
            ap.error(str(e))
    else:
        try:
            resolve_grid(args.archs, args.shapes)
        except ValueError as e:
            ap.error(str(e))
    hosts = args.hosts.split(",") if args.hosts else None
    try:
        run_orchestrator(archs=args.archs, shapes=args.shapes,
                         shards=args.shards, out_dir=args.out,
                         mesh=args.mesh, iterations=args.iterations,
                         budget=args.budget, workers=args.workers,
                         strategy=args.strategy, gate_factor=args.gate_factor,
                         gate_min_factor=args.gate_min_factor,
                         measure_top_k=args.measure_top_k,
                         measure_runs=args.measure_runs,
                         measure_budget=args.measure_budget,
                         llm=args.llm, poll_interval=args.poll_interval,
                         hang_timeout=args.hang_timeout,
                         max_restarts=args.max_restarts, inject_kill=inject,
                         queue=args.queue, steal_factor=args.steal_factor,
                         steal_min_s=args.steal_min_s,
                         max_steals=args.max_steals,
                         queue_lease_s=args.queue_lease_s,
                         executor=args.executor, hosts=hosts,
                         remote_root=args.remote_root,
                         remote_repo=args.remote_repo,
                         remote_python=args.remote_python,
                         space=args.space, objective=args.objective)
    except (RuntimeError, ValueError) as e:
        print(f"[orchestrator] FAILED: {e}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
