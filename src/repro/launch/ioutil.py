"""Atomic file I/O for supervisor-polled campaign artifacts.

Every JSON file a supervisor, merge, or resumed campaign may read while a
writer is mid-flight (heartbeats, leaderboards, per-cell reports, dry-run
artifacts, checkpoint manifests) must be written through
:func:`write_json_atomic`: serialize to a sibling temp file, then commit
with a single ``os.replace`` so no reader — and no restart after SIGKILL —
ever observes a torn file. The invariant linter (``repro.analysis``,
rule RPR001) enforces this contract mechanically: a non-atomic JSON write
landing anywhere in ``repro.launch`` fails CI.

This module exists *below* ``repro.launch.campaign`` so that pure file
consumers (``merge_db``, ``train.checkpoint``, the orchestrator) can share
the helper without importing the campaign engine. Pure stdlib — no jax
import, safe in supervisor and bench processes.
"""
from __future__ import annotations

import json
from pathlib import Path


def write_json_atomic(path: Path | str, payload) -> Path:
    """Serialize ``payload`` to ``path`` via temp-file + ``os.replace`` so a
    reader (or a restarted campaign) never sees a torn file, even if this
    process is SIGKILLed mid-write. Serialization is byte-stable for a
    given payload (``indent=1``, ``default=str``) — sharded-vs-merged
    leaderboard comparisons rely on it. Returns ``path``."""
    path = Path(path)
    tmp = path.with_suffix(".json.tmp")
    tmp.write_text(json.dumps(payload, indent=1, default=str))
    tmp.replace(path)
    return path
