"""repro subpackage."""
