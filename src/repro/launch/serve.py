"""Serving launcher: batched prefill+decode for a (reduced) architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --requests 8
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, reduced as reduce_cfg
from repro.models import model as M
from repro.serve import step as serve_step
from repro.serve.batcher import Batcher
from repro.sharding.plan import ShardingPlan


def main():
    """CLI entry: run the continuous batcher over synthetic requests for a
    reduced text architecture. Exits via SystemExit for vlm/audio archs
    (their frontends are dry-run stubs, not servable)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduce_cfg(get_config(args.arch))
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("serve launcher targets text-in architectures; "
                         "vlm/audio frontends are stub inputs (see dryrun)")
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    plan = ShardingPlan(rules={})
    batcher = Batcher(
        cfg, params,
        jax.jit(serve_step.make_prefill_step(cfg, plan, None)),
        jax.jit(serve_step.make_decode_step(cfg, plan, None)),
        init_cache=lambda b, ml: M.init_cache(cfg, b, ml),
        max_batch=args.max_batch, max_len=256)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        batcher.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 32))),
                       max_new=args.max_new)
    batcher.run()
    s = batcher.stats
    print(f"{s['requests']} requests, {s['tokens']} tokens, "
          f"{s['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
