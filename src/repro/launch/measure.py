"""Tier-2 measured execution: run the compiled computation and time it.

Every other number in this engine is *modeled* — the dry-run tier compiles
a cell and reads an analytical roofline bound off the HLO. This module is
the promotion ladder's raw-speed anchor: it builds the same jitted step as
``launch/dryrun.build_cell`` (donation disabled, so the step can be called
repeatedly on the same buffers), concretizes the abstract inputs as zeros,
runs one warm call (compile + first dispatch), then times ``runs`` calls
and reports the **minimum** wall-clock — the compile-and-replay idiom; a
GC or dispatch hiccup inflates a mean but never the min.

On a machine with no accelerator the forced-host-platform CPU backend
executes the computation in interpret-ish mode: the absolute numbers are
not production latencies, but they are *real executions* of the real HLO,
which is exactly what calibrating prediction-vs-measured error needs
(``CostModel.measured_calibration``). The record carries ``backend`` so
readers can tell the two apart.

Contract mirrors ``dryrun.run_cell``: ``measure_cell`` never raises —
unsupported cells return ``status="skipped"`` and any build/run exception
becomes a ``status="error"`` record. ``ok``/``skipped`` records are safe
to cache content-addressed (``measured_cache/`` beside ``dryrun_cache/``):
a measurement is taken exactly once per design and every re-leased, stolen,
or resumed worker replays the recorded timing instead of re-running.

This module is import-safe without jax (RPR004 supervisor scope): jax and
the dry-run builder are imported lazily inside the functions that need
them, so the campaign/orchestrator CLIs can import the measured-tier
plumbing without paying a jax startup.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback
from pathlib import Path
from typing import Any, Dict

# process-local count of actual timed executions (cache replays never reach
# measure_cell) — the exactly-once-per-promoted-head tests assert on this,
# mirroring dryrun.N_COMPILES
N_MEASUREMENTS = 0

# same counter for the kernel-cell measured tier (measure_kernel_cell)
N_KERNEL_MEASUREMENTS = 0

DEFAULT_RUNS = 3


def measure_cell(arch: str, shape_name: str, mesh, mesh_name: str,
                 plan=None, *, runs: int = DEFAULT_RUNS,
                 cfg=None, cell=None) -> Dict[str, Any]:
    """Execute one cell's compiled step and time it (see module docstring).

    Returns a record with ``status`` ``ok`` (``measured_s`` = min over
    ``runs`` timed calls, ``times_s`` the full list, ``warm_s`` the
    compile+first-dispatch call, ``backend`` the jax backend that ran it),
    ``skipped`` (unsupported cell), or ``error``. ``measured_at`` is the
    wall timestamp of the measurement — DataPoints built from a cached
    record reuse it, so a replayed measurement serializes byte-identically
    to the original.
    """
    global N_MEASUREMENTS
    if runs < 1:
        raise ValueError(f"runs must be >= 1, got {runs}")
    t0 = time.time()
    # measured_at set up-front so cached *skipped* records are replay-stable
    # too, not just the ok path
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "fidelity": "measured",
                           "n": runs, "measured_at": round(t0, 3)}
    try:
        import jax
        import jax.numpy as jnp

        from repro.launch import dryrun

        built, skip = dryrun.build_cell(arch, shape_name, mesh, plan,
                                        cfg=cfg, cell=cell, donate=False)
        if built is None:
            rec.update(status="skipped", reason=skip)
            return rec
        fn, args = built
        # concretize the abstract input specs: zeros are fine — wall time
        # of a dense step is data-independent, and allocating real batches
        # here would drag the data pipeline into a timing harness
        concrete = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), args)
        N_MEASUREMENTS += 1
        with mesh:
            t_warm = time.perf_counter()
            jax.block_until_ready(fn(*concrete))  # compile + first dispatch
            warm_s = time.perf_counter() - t_warm
            times = []
            for _ in range(runs):
                t = time.perf_counter()
                jax.block_until_ready(fn(*concrete))
                times.append(time.perf_counter() - t)
        rec.update(status="ok",
                   measured_s=min(times),
                   times_s=times,
                   warm_s=warm_s,
                   backend=jax.default_backend())
    except Exception as e:  # noqa: BLE001 — a failed measurement is a negative datapoint
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def measure_kernel_cell(kshape, dims: Dict[str, Any], *,
                        mesh_name: str = "dev1", runs: int = DEFAULT_RUNS,
                        interpret=True, seed: int = 0) -> Dict[str, Any]:
    """Measured tier for a kernel cell: execute the Pallas kernel with the
    candidate tile dims and time it (same warm-then-min-of-``runs`` idiom
    as :func:`measure_cell`), then re-run the correctness gate on the warm
    output against the ``kernels.ref`` oracle.

    ``kshape`` is a ``repro.core.kernel_space.KernelShape``. Never raises:
    returns ``status`` ``ok`` (correct within tolerance), ``incorrect``
    (ran fine but the output is wrong — ``max_abs_err`` > ``tol``; the
    caller turns this into an ``infeasible`` row, never a winner), or
    ``error``. Both ``ok`` and ``incorrect`` are deterministic verdicts
    and safe to cache content-addressed; ``measured_at`` makes replayed
    rows serialize byte-identically, exactly like plan cells.
    """
    global N_KERNEL_MEASUREMENTS
    if runs < 1:
        raise ValueError(f"runs must be >= 1, got {runs}")
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": f"kernel:{kshape.kernel}",
                           "shape": kshape.name, "mesh": mesh_name,
                           "fidelity": "measured", "n": runs,
                           "measured_at": round(t0, 3)}
    try:
        import jax

        from repro.kernels import conformance

        inputs = conformance.make_inputs(kshape, seed=seed)
        N_KERNEL_MEASUREMENTS += 1
        t_warm = time.perf_counter()
        out = jax.block_until_ready(conformance.run_candidate(
            kshape, dims, inputs, interpret=interpret))
        warm_s = time.perf_counter() - t_warm
        want = conformance.run_reference(kshape, dims, inputs)
        err = conformance.max_abs_error(out, want)
        tol = conformance.tolerance(kshape.kernel, kshape.dtype)
        times = []
        for _ in range(runs):
            t = time.perf_counter()
            jax.block_until_ready(conformance.run_candidate(
                kshape, dims, inputs, interpret=interpret))
            times.append(time.perf_counter() - t)
        rec.update(status="ok" if err <= tol else "incorrect",
                   measured_s=min(times),
                   times_s=times,
                   warm_s=warm_s,
                   backend=jax.default_backend(),
                   max_abs_err=err,
                   tol=tol)
    except Exception as e:  # noqa: BLE001 — a failed measurement is a negative datapoint
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    rec["wall_s"] = round(time.time() - t0, 2)
    return rec


def build_parser() -> argparse.ArgumentParser:
    """The measured-execution CLI surface, importable without touching jax
    (the quickstart drift checker parses documented commands against it)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.measure",
        description="measure one cell: execute the compiled step and time "
                    "it (tier 2 of the promotion ladder)")
    ap.add_argument("--arch", required=True, help="arch id")
    ap.add_argument("--shape", required=True, help="shape cell name")
    ap.add_argument("--mesh", default="tiny",
                    choices=["tiny", "small", "pod", "multipod"])
    ap.add_argument("--runs", type=int, default=DEFAULT_RUNS,
                    help="timed executions after the warm call; the "
                         "reported measured_s is their minimum")
    ap.add_argument("--out", default=None,
                    help="write the measurement record JSON here")
    return ap


def main() -> None:
    """CLI entry: measure one (arch, shape) cell's baseline plan on the
    chosen mesh and print the record. Exits 1 on a failed measurement."""
    # before any jax-touching import: jax locks the device count at first init
    os.environ["XLA_FLAGS"] = os.environ.get(
        "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    ap = build_parser()
    args = ap.parse_args()
    if args.runs < 1:
        ap.error(f"--runs must be >= 1, got {args.runs}")

    # test/CI hook shared with the campaign CLI: shrink configs before
    # anything jax-touching runs, so the standalone harness is drivable on
    # a laptop/CI box where real configs don't fit interpret-mode memory
    prelude = os.environ.get("REPRO_CAMPAIGN_PRELUDE")
    if prelude:
        src = Path(prelude).read_text()
        exec(compile(src, prelude, "exec"), {"__name__": "__repro_prelude__"})

    from repro.configs import ARCH_NAMES, SHAPE_BY_NAME
    from repro.launch.campaign import make_campaign_mesh

    if args.arch not in ARCH_NAMES:
        ap.error(f"unknown arch {args.arch!r}")
    if args.shape not in SHAPE_BY_NAME:
        ap.error(f"unknown shape {args.shape!r}")
    mesh, mesh_name = make_campaign_mesh(args.mesh)
    rec = measure_cell(args.arch, args.shape, mesh, mesh_name,
                       runs=args.runs)
    print(json.dumps({k: v for k, v in rec.items() if k != "trace"},
                     indent=1, default=str))
    if args.out:
        from repro.launch.ioutil import write_json_atomic

        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        write_json_atomic(Path(args.out), rec)
    if rec["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    main()
