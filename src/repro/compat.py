"""Version-compat shims over the installed jax.

The repo targets current jax APIs (``jax.shard_map``, ``AxisType`` meshes)
but must run on older releases where ``shard_map`` still lives under
``jax.experimental`` and the replication check is spelled ``check_rep``.
Mesh-construction compat lives in ``repro.launch.mesh``.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
