"""RPR006 — no silently-swallowed broad exceptions in the launch layer.

The orchestrator's heal/heartbeat/steal paths deliberately tolerate
specific races (``ProcessLookupError`` when a healed shard already
exited, ``FileNotFoundError`` when a rename lost) — those narrow,
commented catches are the protocol working as designed. What this rule
bans is the degenerate form: ``except Exception: pass`` (or bare
``except`` / ``BaseException`` with an empty body), which converts a
real fault — a corrupted ticket, a dead executor — into silence the
supervisor can never heal from. Catch narrowly, or at minimum log.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.rules import Finding, Rule

_BROAD = {"Exception", "BaseException"}


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:  # bare except:
        return True
    names = [t] if not isinstance(t, ast.Tuple) else list(t.elts)
    for n in names:
        if isinstance(n, ast.Name) and n.id in _BROAD:
            return True
        if isinstance(n, ast.Attribute) and n.attr in _BROAD:
            return True
    return False


def _is_silent(handler: ast.ExceptHandler) -> bool:
    """Body is nothing but ``pass`` / ``...`` — no logging, no re-raise,
    no fallback value."""
    for stmt in handler.body:
        if isinstance(stmt, ast.Pass):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant) \
                and stmt.value.value is Ellipsis:
            continue
        return False
    return True


class SwallowedException(Rule):
    """RPR006 — broad except with an empty body in ``repro.launch``."""

    id = "RPR006"
    title = "silently swallowed broad exception"
    contract = ("launch-layer code never pairs a broad catch (bare / "
                "Exception / BaseException) with an empty body; catch "
                "the specific race or surface the fault")

    def applies(self, f) -> bool:
        return f.rel.startswith("src/repro/launch/")

    def check(self, f, project) -> Iterator[Finding]:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ExceptHandler) \
                    and _is_broad(node) and _is_silent(node):
                what = ("bare except" if node.type is None
                        else "broad except")
                yield self.finding(
                    f, node,
                    f"{what} with empty body swallows faults the "
                    "supervisor needs to see; catch the specific "
                    "exception or handle/log it")


__all__ = ["SwallowedException"]
