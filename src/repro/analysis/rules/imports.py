"""RPR004 — jax must stay out of supervisor and benchmark processes.

The orchestrator, scheduler, executors, merge CLI, and benchmark
harnesses run on login nodes and in bare CI containers where importing
jax is either unavailable or costs seconds of startup per shard
heartbeat. The codebase keeps them jax-free by importing jax lazily
inside the functions that need it (``run_campaign`` does this).

A naive "no ``import jax`` at top level" check misses the common way
this regresses: a jax-free module imports a *repro* module that imports
jax at top level. This rule therefore computes a transitive taint over
the project's import graph — a module is *tainted* when any of its
top-level imports reaches jax — and flags any top-level import in the
jax-free scope that lands on a tainted module, reporting the full chain
(``orchestrator -> repro.train.checkpoint -> jax``) so the fix site is
obvious.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.rules import Finding, Rule

#: modules that must import cleanly without jax present
_JAX_FREE_FILES = {
    "src/repro/launch/scheduler.py",
    "src/repro/launch/orchestrator.py",
    "src/repro/launch/executors.py",
    "src/repro/launch/campaign.py",
    "src/repro/launch/merge_db.py",
    "src/repro/launch/ioutil.py",
    # tier-2 measurement CLI: jax is imported lazily inside measure_cell,
    # so the supervisor (and the quickstart drift checker) can import the
    # module for its parser without paying a jax startup
    "src/repro/launch/measure.py",
    # kernel campaigns: the whole queue/heartbeat/leaderboard drive loop
    # is supervision; jax enters only through the KernelEvaluator and the
    # conformance harness, both imported lazily inside run_kernel_campaign
    "src/repro/launch/kernel_cell.py",
    # Pareto dominance/crowding/hypervolume: stdlib-only so the merge CLI
    # and the leaderboard rebuild can rank fronts on login nodes
    "src/repro/core/pareto.py",
    # DSE-as-a-service control plane: the daemon runs on supervisor nodes
    # and must serve HTTP + schedule workers without a jax runtime; jax
    # lives only in the campaign worker subprocesses it spawns
    "src/repro/launch/service.py",
    "src/repro/core/fairshare.py",
}
_JAX_FREE_PREFIXES = ("benchmarks/", "src/repro/analysis/")

_JAX_ROOTS = ("jax", "jaxlib", "flax", "optax")


def _rel_to_module(rel: str) -> Optional[str]:
    """``src/repro/launch/dse.py`` -> ``repro.launch.dse`` (None for
    files outside the ``src/`` package tree)."""
    if not rel.startswith("src/") or not rel.endswith(".py"):
        return None
    mod = rel[len("src/"):-len(".py")]
    if mod.endswith("/__init__"):
        mod = mod[: -len("/__init__")]
    return mod.replace("/", ".")


def _is_jax(mod: str) -> bool:
    root = mod.split(".", 1)[0]
    return root in _JAX_ROOTS


def _top_level_imports(tree: ast.AST, self_mod: Optional[str],
                       ) -> List[Tuple[str, int]]:
    """(module, lineno) for every top-level import, with relative
    imports resolved against the importing module's package."""
    out: List[Tuple[str, int]] = []
    for node in ast.iter_child_nodes(tree):
        # guard one level of nesting: `if TYPE_CHECKING:` imports are
        # not executed at runtime and must not taint
        if isinstance(node, ast.If):
            continue
        if isinstance(node, ast.Import):
            out.extend((a.name, node.lineno) for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level:
                if self_mod is None:
                    continue
                parts = self_mod.split(".")
                # level=1 from a module means its own package
                base = parts[: len(parts) - node.level]
                mod = ".".join(base + ([mod] if mod else []))
            if mod:
                out.append((mod, node.lineno))
                # `from pkg import sub` may bind a submodule: emit the
                # dotted candidate too (taint lookup walks prefixes, so
                # a name that is really a function resolves to pkg)
                out.extend((f"{mod}.{a.name}", node.lineno)
                           for a in node.names if a.name != "*")
    return out


class JaxImportInJaxFreeScope(Rule):
    """RPR004 — no top-level jax (direct or transitive through repro
    modules) in supervisor/benchmark code; see module docstring."""

    id = "RPR004"
    title = "top-level jax import in jax-free scope"
    contract = ("supervisor + benchmark modules import jax lazily inside "
                "functions; top-level imports must not reach jax, even "
                "transitively through other repro modules")

    def applies(self, f) -> bool:
        return (f.rel in _JAX_FREE_FILES
                or f.rel.startswith(_JAX_FREE_PREFIXES))

    def _taint(self, project) -> Dict[str, List[str]]:
        """Map tainted module name -> witness chain ending in the jax
        root, e.g. ``['repro.train.checkpoint', 'jax']``. Fixpoint over
        the project's top-level import graph."""
        cache = getattr(project, "_rpr004_taint", None)
        if cache is not None:
            return cache
        imports: Dict[str, List[str]] = {}
        for sf in project.files:
            mod = _rel_to_module(sf.rel)
            if mod is None:
                continue
            imports[mod] = [m for m, _ in
                            _top_level_imports(sf.tree, mod)]
        taint: Dict[str, List[str]] = {}
        changed = True
        while changed:
            changed = False
            for mod, deps in imports.items():
                if mod in taint:
                    continue
                for dep in deps:
                    if _is_jax(dep):
                        taint[mod] = [dep.split(".", 1)[0]]
                        changed = True
                        break
                    # an import of repro.a.b executes repro.a.b AND the
                    # repro.a / repro packages; any tainted prefix taints
                    chain = self._tainted_prefix(dep, taint, imports)
                    if chain is not None:
                        taint[mod] = chain
                        changed = True
                        break
        project._rpr004_taint = taint
        return taint

    @staticmethod
    def _tainted_prefix(dep: str, taint: Dict[str, List[str]],
                        imports: Dict[str, List[str]],
                        ) -> Optional[List[str]]:
        parts = dep.split(".")
        for i in range(len(parts), 0, -1):
            prefix = ".".join(parts[:i])
            if prefix in taint:
                return [prefix] + taint[prefix]
            if prefix in imports:
                # known-clean so far this fixpoint round; keep walking
                continue
        return None

    def check(self, f, project) -> Iterator[Finding]:
        taint = self._taint(project)
        self_mod = _rel_to_module(f.rel)
        known: Set[str] = {m for sf in project.files
                           for m in [_rel_to_module(sf.rel)] if m}
        flagged_lines: Set[int] = set()
        for node in ast.iter_child_nodes(f.tree):
            if isinstance(node, ast.If):
                continue
            if not isinstance(node, (ast.Import, ast.ImportFrom)):
                continue
            for mod, line in _top_level_imports_of(node, self_mod):
                if line in flagged_lines:
                    continue
                if _is_jax(mod):
                    flagged_lines.add(line)
                    yield Finding(
                        rule=self.id, rel=f.rel, line=line,
                        message=f"top-level import of {mod} in jax-free "
                                "scope; import it lazily inside the "
                                "function that needs it",
                        snippet=f.lines[line - 1].strip())
                    continue
                chain = self._tainted_prefix(mod, taint, {m: []
                                                          for m in known})
                if chain is not None:
                    flagged_lines.add(line)
                    full = " -> ".join(dict.fromkeys([mod] + chain))
                    yield Finding(
                        rule=self.id, rel=f.rel, line=line,
                        message=f"top-level import chain reaches jax: "
                                f"{full}; break the chain with a lazy "
                                "import",
                        snippet=f.lines[line - 1].strip())


def _top_level_imports_of(node: ast.AST, self_mod: Optional[str],
                          ) -> List[Tuple[str, int]]:
    """Single-statement version of :func:`_top_level_imports`."""
    shim = ast.Module(body=[node], type_ignores=[])
    return _top_level_imports(shim, self_mod)


__all__ = ["JaxImportInJaxFreeScope"]
