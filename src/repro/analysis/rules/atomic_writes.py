"""Atomic-write rules: RPR001 (supervisor-polled JSON must go through
``write_json_atomic``) and RPR005 (``CellQueue`` may never open a ticket
path with O_CREAT after the claim rename).

Both rules share the same exemption: the write-to-tmp-then-rename idiom.
A path expression that visibly mentions ``tmp`` (name, attribute, or
string constant anywhere in its subtree) is the *first half* of an atomic
write and is legal; the rename that publishes it is what readers see.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Optional

from repro.analysis.rules import (Finding, Rule, dotted_name,
                                  enclosing_defs, subtree_mentions_tmp)

#: functions whose body IS the atomic-write implementation; their
#: internal .write_text is the sanctioned tmp write
_IMPL_FUNCS = {"write_json_atomic"}

#: classes that form the filesystem-primitive layer: their methods wrap
#: raw os calls by design and are the enforcement boundary, not a
#: violation site (LocalFS in scheduler.py, MemFS in the race explorer)
_FS_PRIMITIVE_CLASSES = {"LocalFS", "MemFS"}


def _contains_json_dumps(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call) and dotted_name(n.func) in (
                "json.dumps", "json.dump"):
            return True
    return False


def _contains_json_literal(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str) \
                and n.value.endswith(".json"):
            return True
    return False


def _open_mode(call: ast.Call, mode_pos: int) -> Optional[str]:
    """The literal mode string of an open()-style call, if present."""
    if len(call.args) > mode_pos:
        arg = call.args[mode_pos]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            return arg.value
    for kw in call.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            return kw.value.value
    return None


def _is_writing_mode(mode: Optional[str]) -> bool:
    return mode is not None and any(c in mode for c in "wax+")


class NonAtomicJsonWrite(Rule):
    """RPR001 — JSON artifacts in the campaign tree are polled by
    concurrent readers (the orchestrator tails ``progress.json``; resumed
    campaigns re-read reports and leaderboards), so every JSON write in
    ``repro.launch`` and the checkpoint manifest must be
    tmp-write + atomic-rename (``repro.launch.ioutil.write_json_atomic``),
    never an in-place ``write_text``/``json.dump``/``open('w')``."""

    id = "RPR001"
    title = "non-atomic JSON artifact write"
    contract = ("JSON artifacts under repro.launch (and the checkpoint "
                "manifest) must be written via write_json_atomic, never "
                "in-place")

    def applies(self, f) -> bool:
        return (f.rel.startswith("src/repro/launch/")
                or f.rel == "src/repro/train/checkpoint.py")

    def check(self, f, project) -> Iterator[Finding]:
        scopes = enclosing_defs(f.tree)

        def exempt(node: ast.AST) -> bool:
            return any(s in _IMPL_FUNCS for s in scopes.get(node, ()))

        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            # json.dump(obj, fh) — the file handle came from a plain
            # open(); a reader can see the torn prefix
            if name == "json.dump":
                if not exempt(node):
                    yield self.finding(
                        f, node,
                        "json.dump() writes in place; build the payload "
                        "and call write_json_atomic() instead")
                continue
            if not isinstance(node.func, ast.Attribute):
                # builtin open(path, "w") with a *.json literal path
                if isinstance(node.func, ast.Name) \
                        and node.func.id == "open" and node.args \
                        and _is_writing_mode(_open_mode(node, 1)) \
                        and _contains_json_literal(node.args[0]) \
                        and not subtree_mentions_tmp(node.args[0]) \
                        and not exempt(node):
                    yield self.finding(
                        f, node,
                        "open(...'w') on a .json path writes in place; "
                        "use write_json_atomic()")
                continue
            recv = node.func.value
            if node.func.attr == "write_text" \
                    and any(_contains_json_dumps(a) for a in node.args) \
                    and not subtree_mentions_tmp(recv) \
                    and not exempt(node):
                yield self.finding(
                    f, node,
                    ".write_text(json.dumps(...)) is not atomic; a "
                    "concurrent reader can see a torn file — use "
                    "write_json_atomic()")
            elif node.func.attr == "open" \
                    and _is_writing_mode(_open_mode(node, 0)) \
                    and _contains_json_literal(recv) \
                    and not subtree_mentions_tmp(recv) \
                    and not exempt(node):
                yield self.finding(
                    f, node,
                    ".open('w') on a .json path writes in place; use "
                    "write_json_atomic()")


class CreatingWriteInQueue(Rule):
    """RPR005 — after ``CellQueue``'s claim rename, the loser of a race
    holds a path that no longer exists; any O_CREAT-capable write on its
    side would *resurrect* the ticket as a duplicate. Inside
    ``scheduler.py``, post-claim content writes must therefore be
    never-creating (``rewrite_nocreate``: O_WRONLY without O_CREAT);
    creating writes (``.write_text``, ``open('w')``, ``os.open`` with
    O_CREAT) are only legal on tmp-named paths that are subsequently
    renamed into place."""

    id = "RPR005"
    title = "O_CREAT-capable write in CellQueue"
    contract = ("scheduler.py may only create files at tmp paths; ticket "
                "content rewrites must be O_WRONLY-without-O_CREAT")

    def applies(self, f) -> bool:
        return f.rel == "src/repro/launch/scheduler.py"

    def check(self, f, project) -> Iterator[Finding]:
        scopes = enclosing_defs(f.tree)

        def in_primitive_layer(node: ast.AST) -> bool:
            return any(s in _FS_PRIMITIVE_CLASSES
                       for s in scopes.get(node, ()))

        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name == "os.open":
                if any("O_CREAT" == getattr(n, "attr", None)
                       for arg in node.args for n in ast.walk(arg)) \
                        and not in_primitive_layer(node):
                    yield self.finding(
                        f, node,
                        "os.open with O_CREAT can resurrect a ticket the "
                        "claim rename already moved; use the fs seam's "
                        "rewrite_nocreate")
                continue
            if isinstance(node.func, ast.Name) and node.func.id == "open" \
                    and node.args and _is_writing_mode(_open_mode(node, 1)) \
                    and not subtree_mentions_tmp(node.args[0]) \
                    and not in_primitive_layer(node):
                yield self.finding(
                    f, node,
                    "creating open() in CellQueue outside a tmp path; "
                    "write to tmp + rename, or rewrite_nocreate")
                continue
            if not isinstance(node.func, ast.Attribute):
                continue
            if node.func.attr == "write_text":
                # two call shapes: path.write_text(text) and the fs-seam
                # form fs.write_text(path, text) where the path is arg 0
                recv_name = dotted_name(node.func.value)
                path_expr: ast.AST = node.func.value
                if recv_name.split(".")[-1].endswith("fs") and node.args:
                    path_expr = node.args[0]
                if not subtree_mentions_tmp(path_expr) \
                        and not in_primitive_layer(node):
                    yield self.finding(
                        f, node,
                        "write_text in CellQueue creates files; only tmp "
                        "paths (later renamed) may be created")
            elif node.func.attr == "open" \
                    and _is_writing_mode(_open_mode(node, 0)) \
                    and not subtree_mentions_tmp(node.func.value) \
                    and not in_primitive_layer(node):
                yield self.finding(
                    f, node,
                    ".open('w') in CellQueue creates files; only tmp "
                    "paths (later renamed) may be created")


__all__: List[str] = ["NonAtomicJsonWrite", "CreatingWriteInQueue"]
