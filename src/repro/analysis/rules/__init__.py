"""Rule registry and shared plumbing for the repro invariant linter.

A *rule* is a small object with an ``id`` (``RPR001``…), a one-line
``contract`` (what invariant it enforces and where), and a
``check(file, project)`` generator yielding :class:`Finding` objects.
Rules see the whole :class:`~repro.analysis.lint.Project` so cross-file
checks (RPR004's transitive jax-taint) are first-class, not bolted on.

Findings carry a content-addressed ``fingerprint`` — a hash of
``rule id + relative path + normalized source line (+ occurrence index)``
— deliberately excluding the line *number*, so baseline entries survive
unrelated edits that shift code up or down. The linter's ratcheting
baseline (``repro.analysis.baseline``) keys on these fingerprints.

The registry below is the single source of truth for which rules run;
``docs/architecture.md`` mirrors it as a human-readable table.
"""
from __future__ import annotations

import ast
import hashlib
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable, Iterator, List

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.analysis.lint import Project, SourceFile


@dataclass
class Finding:
    """One rule violation at one source location."""
    rule: str            # "RPR001"
    rel: str             # posix path relative to the lint root
    line: int            # 1-based line number (display only, not identity)
    message: str         # human-readable description of the violation
    snippet: str = ""    # the offending source line, stripped
    occurrence: int = 0  # disambiguates identical lines in one file

    @property
    def fingerprint(self) -> str:
        """Stable identity for the baseline ratchet. Hashes the rule, the
        file, and the *text* of the offending line — never its number —
        so entries survive line drift; ``occurrence`` separates repeats
        of an identical line within one file."""
        key = f"{self.rule}:{self.rel}:{self.snippet}:{self.occurrence}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def render(self) -> str:
        """One-line ``path:line: RULE message`` report format."""
        loc = f"{self.rel}:{self.line}"
        return f"{loc}: {self.rule} {self.message}"


class Rule:
    """Base class: subclasses set ``id``/``title``/``contract`` and
    implement ``check``. ``applies`` pre-filters files so rule bodies
    only ever see their own scope."""

    id: str = "RPR000"
    title: str = ""
    contract: str = ""

    def applies(self, f: "SourceFile") -> bool:
        raise NotImplementedError

    def check(self, f: "SourceFile", project: "Project") -> Iterator[Finding]:
        raise NotImplementedError

    # -- shared helpers -------------------------------------------------

    def finding(self, f: "SourceFile", node: ast.AST, message: str,
                ) -> Finding:
        """Build a Finding anchored at ``node``, filling in the snippet
        from the file's source lines."""
        line = getattr(node, "lineno", 1)
        snippet = ""
        if 1 <= line <= len(f.lines):
            snippet = f.lines[line - 1].strip()
        return Finding(rule=self.id, rel=f.rel, line=line,
                       message=message, snippet=snippet)


def number_occurrences(findings: Iterable[Finding]) -> List[Finding]:
    """Assign ``occurrence`` indices so two findings on byte-identical
    lines in the same file fingerprint differently (source order)."""
    seen: dict = {}
    out = []
    for fd in findings:
        key = (fd.rule, fd.rel, fd.snippet)
        fd.occurrence = seen.get(key, 0)
        seen[key] = fd.occurrence + 1
        out.append(fd)
    return out


# -- AST helpers shared across rule modules -----------------------------

def dotted_name(node: ast.AST) -> str:
    """Render ``a.b.c`` attribute/name chains; '' for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def subtree_mentions_tmp(node: ast.AST) -> bool:
    """True when a path expression is visibly a temp file: any name,
    attribute, or string constant in the subtree containing ``tmp``.
    This is the linter's exemption for the write-tmp-then-rename idiom
    (``write_json_atomic``, CellQueue's seam writes)."""
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and "tmp" in n.id.lower():
            return True
        if isinstance(n, ast.Attribute) and "tmp" in n.attr.lower():
            return True
        if isinstance(n, ast.Constant) and isinstance(n.value, str) \
                and "tmp" in n.value.lower():
            return True
    return False


def enclosing_defs(tree: ast.AST) -> dict:
    """Map every node to the stack of enclosing function/class names,
    e.g. ``['LocalFS', 'write_text']``. Used for registry-scoped rules
    (RPR003 purity) and class-level exemptions (RPR005's fs primitive
    layer)."""
    scopes: dict = {}

    def visit(node: ast.AST, stack: tuple):
        scopes[node] = stack
        child_stack = stack
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            child_stack = stack + (node.name,)
        for child in ast.iter_child_nodes(node):
            visit(child, child_stack)

    visit(tree, ())
    return scopes


def _registry() -> List[Rule]:
    # imported lazily so `python -m repro.analysis.rules...` cannot cycle
    from repro.analysis.rules.atomic_writes import (NonAtomicJsonWrite,
                                                    CreatingWriteInQueue)
    from repro.analysis.rules.determinism import (UnseededRandom,
                                                  WallClockInPureFn)
    from repro.analysis.rules.imports import JaxImportInJaxFreeScope
    from repro.analysis.rules.exceptions import SwallowedException
    return [NonAtomicJsonWrite(), UnseededRandom(), WallClockInPureFn(),
            JaxImportInJaxFreeScope(), CreatingWriteInQueue(),
            SwallowedException()]


RULES: List[Rule] = _registry()
