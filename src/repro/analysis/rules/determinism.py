"""Determinism rules: RPR002 (no unseeded module-level RNG in the search
and serving stacks) and RPR003 (no wall-clock reads inside functions the
codebase declares pure).

The engine's replayability contract is that a campaign is a function of
``(config, seed)``: two runs with the same seed must produce
byte-identical leaderboards (tier-1 asserts this). Both rules defend
that property at the source level.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Set

from repro.analysis.rules import (Finding, Rule, dotted_name,
                                  enclosing_defs)

#: RPR002 scope — every module whose randomness must flow from the
#: campaign seed. search/ holds the strategies, serve/ the batcher, and
#: the core loop/explorer drive proposal sampling.
_RNG_SCOPE_PREFIXES = ("src/repro/search/", "src/repro/serve/")
_RNG_SCOPE_FILES = {"src/repro/core/loop.py", "src/repro/core/explorer.py"}

#: random-module functions that are fine: constructing an *instance* RNG
#: (which the caller seeds) is the sanctioned pattern
_RANDOM_OK = {"Random", "SystemRandom"}

#: RPR003 registry — functions the codebase declares pure decision
#: logic: every input (including time) arrives as a parameter, so tests
#: and the race explorer can replay them deterministically. Keys are
#: lint-root-relative paths; values are function names (methods listed
#: by bare name).
PURE_FUNCTIONS: Dict[str, Set[str]] = {
    "src/repro/launch/orchestrator.py": {
        "plan_steals", "aggregate_best", "shard_dirs_for",
    },
    "src/repro/launch/campaign.py": {
        "build_leaderboard", "shard_cells", "resolve_grid",
    },
    "src/repro/launch/merge_db.py": {
        "merge_cost_dbs", "_report_rank",
    },
    "src/repro/launch/scheduler.py": {
        "sanitize_owner", "_expire_lease",
    },
    # the kernel campaign's grid cut: every shard and the queue seeding
    # must agree on cell numbering from the arguments alone
    "src/repro/launch/kernel_cell.py": {
        "resolve_kernel_grid", "kernel_grid_cells",
    },
    # the promotion ladder's tier-2 policy: which heads get measured and
    # which duplicate measured row is canonical must replay identically
    # on every shard (exactly-once measurement rides on it)
    "src/repro/core/promotion.py": {
        "plan_promotions", "plan_front_promotions", "select_measured_row",
    },
    # Pareto machinery: dominance ranking, crowding, and the total front
    # order must be pure functions of the row set — merged leaderboards
    # are byte-compared across shard permutations
    "src/repro/core/pareto.py": {
        "dominates", "front_ranks", "crowding_distances", "front_order",
        "hypervolume",
    },
    # objective extraction + front assembly: one shared code path ranks
    # kernel rows and plan rows, replayed identically by every shard and
    # by the merge's leaderboard rebuild
    "src/repro/core/cost_db.py": {
        "derive_objectives", "objectives_of", "objective_value",
        "pareto_rows",
    },
    # the service control plane's fairness/budget policy: every worker
    # grant must be a pure function of the tenant snapshot so scheduling
    # decisions replay in unit tests without a daemon
    "src/repro/core/fairshare.py": {
        "budget_left", "over_budget", "plan_worker_grants",
    },
    # the daemon's per-tick tenant snapshot assembly feeds the fairshare
    # policy; time arrives via now= from the scheduler loop
    "src/repro/launch/service.py": {
        "snapshot_tenants",
    },
}

_WALL_CLOCK_CALLS = {
    "time.time", "time.monotonic", "time.perf_counter", "time.time_ns",
    "time.monotonic_ns", "datetime.now", "datetime.utcnow",
    "datetime.today", "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}


class UnseededRandom(Rule):
    """RPR002 — module-level RNG (``random.random()``,
    ``np.random.uniform()``, no-arg ``np.random.default_rng()``) draws
    from interpreter-global state that campaign seeds don't control.
    Search strategies must use ``random.Random(seed)`` instances; numpy
    consumers must use ``np.random.default_rng(seed)``."""

    id = "RPR002"
    title = "unseeded module-level RNG"
    contract = ("search/serve/core-loop code must draw randomness from "
                "seeded instances (random.Random(seed) / "
                "np.random.default_rng(seed)), never module-level state")

    def applies(self, f) -> bool:
        return (f.rel.startswith(_RNG_SCOPE_PREFIXES)
                or f.rel in _RNG_SCOPE_FILES)

    def check(self, f, project) -> Iterator["Finding"]:
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    bad = [a.name for a in node.names
                           if a.name not in _RANDOM_OK]
                    if bad:
                        yield self.finding(
                            f, node,
                            f"from random import {', '.join(bad)} pulls "
                            "module-level RNG functions; import Random "
                            "and seed an instance")
                elif node.module == "numpy.random":
                    bad = [a.name for a in node.names
                           if a.name != "default_rng"]
                    if bad:
                        yield self.finding(
                            f, node,
                            f"from numpy.random import {', '.join(bad)} "
                            "pulls global-state RNG; use "
                            "default_rng(seed)")
                continue
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name.startswith("random."):
                attr = name.split(".", 1)[1]
                if "." not in attr and attr not in _RANDOM_OK:
                    yield self.finding(
                        f, node,
                        f"{name}() uses the module-level RNG; use a "
                        "random.Random(seed) instance threaded from the "
                        "campaign seed")
            elif name.startswith(("np.random.", "numpy.random.")):
                attr = name.rsplit(".", 1)[1]
                if attr == "default_rng":
                    if not node.args and not node.keywords:
                        yield self.finding(
                            f, node,
                            "np.random.default_rng() without a seed is "
                            "entropy-seeded; pass the campaign seed")
                else:
                    yield self.finding(
                        f, node,
                        f"{name}() draws from numpy's global RNG; use "
                        "np.random.default_rng(seed)")


class WallClockInPureFn(Rule):
    """RPR003 — the functions in :data:`PURE_FUNCTIONS` are declared
    pure: the orchestrator replays ``plan_steals`` decisions in tests,
    the merge is property-tested for order-invariance, and leaderboard
    building must be a function of its inputs. A ``time.time()`` (or any
    wall-clock read) inside them silently re-introduces nondeterminism;
    the clock must arrive as a ``now=`` parameter instead."""

    id = "RPR003"
    title = "wall-clock read in declared-pure function"
    contract = ("functions in the purity registry take time as a "
                "parameter (now=...); they never read the clock "
                "themselves")

    def applies(self, f) -> bool:
        return f.rel in PURE_FUNCTIONS

    def check(self, f, project) -> Iterator["Finding"]:
        registry = PURE_FUNCTIONS[f.rel]
        scopes = enclosing_defs(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name not in _WALL_CLOCK_CALLS:
                continue
            stack = scopes.get(node, ())
            hit = next((s for s in stack if s in registry), None)
            if hit is not None:
                yield self.finding(
                    f, node,
                    f"{name}() inside declared-pure {hit}(); take the "
                    "timestamp as a now= parameter so callers/tests "
                    "control the clock")


__all__ = ["UnseededRandom", "WallClockInPureFn", "PURE_FUNCTIONS"]
