"""Ratcheting baseline for the invariant linter.

The baseline file (``analysis_baseline.json`` at the repo root) is a
list of accepted-debt entries keyed by finding fingerprint (rule + file
+ normalized source line — no line numbers, so entries survive code
motion). The ratchet semantics:

* a finding whose fingerprint is **not** in the baseline is *new* →
  the lint run fails;
* a baseline entry whose fingerprint no longer fires is *stale* → the
  linter rewrites the baseline without it (the ratchet only tightens;
  committing the shrunken file is the payoff for fixing debt);
* ``--write-baseline`` accepts all current findings (bootstrap — used
  once when introducing a rule over a codebase with existing debt).

The shipped baseline is **empty**: every rule runs clean on the tree,
and the file exists purely so new debt has somewhere to *not* be.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List, Tuple

from repro.analysis.rules import Finding


def load_baseline(path: Path) -> Dict[str, dict]:
    """fingerprint -> entry. A missing file is an empty baseline (the
    strictest possible ratchet), so fresh checkouts and fixture repos
    need no bootstrap step."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    """Serialize findings as accepted debt, sorted for stable diffs.
    Entries keep the human-readable context (rule/file/snippet) so a
    reviewer can audit the debt without re-running the linter."""
    entries = sorted(
        ({"fingerprint": fd.fingerprint, "rule": fd.rule, "path": fd.rel,
          "snippet": fd.snippet, "occurrence": fd.occurrence}
         for fd in findings),
        key=lambda e: (e["rule"], e["path"], e["snippet"], e["occurrence"]))
    payload = {"comment": "accepted debt for repro.analysis.lint; "
                          "the ratchet only ever shrinks this list",
               "findings": entries}
    path.write_text(json.dumps(payload, indent=1) + "\n")


def apply_baseline(findings: List[Finding], baseline: Dict[str, dict],
                   ) -> Tuple[List[Finding], List[Finding], List[dict]]:
    """Split findings against the baseline.

    Returns ``(new, known, stale)``: findings not covered by the
    baseline (failures), findings covered (tolerated debt), and baseline
    entries that no longer fire (to be ratcheted away).
    """
    current = {fd.fingerprint for fd in findings}
    new = [fd for fd in findings if fd.fingerprint not in baseline]
    known = [fd for fd in findings if fd.fingerprint in baseline]
    stale = [e for fp, e in baseline.items() if fp not in current]
    return new, known, stale
