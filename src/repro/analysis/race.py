"""Queue-protocol race explorer — a bounded model checker for CellQueue.

``CellQueue``'s crash-safety argument is that every state transition is a
single atomic rename, so no interleaving of concurrent owners can fork a
ticket into two states, lose it, or complete it twice. This module checks
that argument *mechanically* against the real implementation:

* :class:`MemFS` implements the :class:`~repro.launch.scheduler.LocalFS`
  seam in memory, with every primitive (rename / link / unlink / glob /
  read / rewrite…) instrumented as one **atomic step** that yields to a
  scheduler before executing;
* :class:`TurnScheduler` runs each queue operation in its own thread and
  grants exactly one atomic step at a time, so an interleaving *is* a
  sequence of (operation, step) choices;
* :func:`explore` enumerates interleavings exhaustively (DFS over the
  schedule tree by prefix replay — the standard stateless-model-checking
  construction) up to a bounded branching depth and schedule budget;
* after **every** atomic step the one-state-per-ticket invariant is
  checked against the in-memory tree; at the end of every schedule,
  ticket conservation plus the scenario's own exactly-once assertions.

On a violation the failing schedule is shrunk (shortest failing prefix,
then greedy context-switch reduction) and printed step by step — the
counterexample reads as "alice renamed pending/X, then bob's write
resurrected it". The shipped scenarios (two contending acquirers,
acquire vs reclaim, complete vs steal, renew vs steal, release vs
complete, two-cell contention) pass exhaustively; the deliberately
broken :class:`BrokenCellQueue` (check-then-act acquire) exists to prove
the explorer still has teeth — ``--broken`` demands a counterexample.

Determinism: operations receive explicit ``now=`` timestamps and MemFS
stamps mtimes from a logical clock, so a schedule replays identically —
which both the DFS (prefix replay) and the minimizer rely on.

Usage::

    PYTHONPATH=src python -m repro.analysis.race            # all scenarios
    PYTHONPATH=src python -m repro.analysis.race --broken   # self-test

Stdlib-only; a full sweep is a few thousand sub-millisecond replays and
finishes in seconds — cheap enough for every CI run.
"""
from __future__ import annotations

import argparse
import fnmatch
import posixpath
import sys
import threading
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.launch.scheduler import (LEASE_INFIX, CellQueue, LocalFS,
                                    Ticket, sanitize_owner)

QUEUE_ROOT = "Q"
#: the single "current time" every scenario op runs at; MemFS logical
#: clocks start here so modelled mtimes and op timestamps share a domain
NOW = 100.0


class SchedulerAbort(BaseException):
    """Raised inside an op thread when the run is being torn down (a
    violation was already found); BaseException so ops' own ``except
    Exception`` handling can never swallow it."""


# ---------------------------------------------------------------------------
# MemFS: the LocalFS seam, in memory, one gated atomic step per primitive
# ---------------------------------------------------------------------------

class MemFS(LocalFS):
    """In-memory :class:`LocalFS` with a scheduler gate before every
    primitive. Semantics mirror the POSIX behavior the queue relies on:
    ``rename`` is atomic and fails with ``FileNotFoundError`` for a lost
    race, ``link`` is exclusive-create, ``rmdir`` refuses non-empty
    directories, mtimes come from a logical clock (monotonic per
    mutation) so lease-expiry fallbacks are schedule-deterministic."""

    #: logical-clock increment per mutation — small against any lease_s
    #: so modelled mtimes stay in the same time domain as the explicit
    #: ``now=`` values the scenario ops pass (a fresh rewrite must look
    #: *fresh* to the mtime-fallback deadline, exactly as on a real fs)
    TICK = 1e-3

    def __init__(self, clock: float = 0.0):
        self.files: Dict[str, str] = {}
        self.dirs: set = set()
        self.mtimes: Dict[str, float] = {}
        self.clock = float(clock)
        self.scheduler: Optional["TurnScheduler"] = None

    # -- plumbing -----------------------------------------------------------

    @staticmethod
    def _key(path) -> str:
        return posixpath.normpath(str(path))

    def _gate(self, label: str) -> None:
        sched = self.scheduler
        if sched is not None:
            sched.maybe_gate(label)

    def _tick(self) -> float:
        self.clock += self.TICK
        return self.clock

    # -- primitives (each: one gate, then one atomic mutation/observation) --

    def mkdirs(self, path) -> None:
        self._gate(f"mkdirs {path}")
        parts = self._key(path).split("/")
        for i in range(1, len(parts) + 1):
            self.dirs.add("/".join(parts[:i]))

    def mkdir_exclusive(self, path) -> None:
        self._gate(f"mkdir_exclusive {path}")
        k = self._key(path)
        if k in self.dirs or k in self.files:
            raise FileExistsError(k)
        self.dirs.add(k)
        self.mtimes[k] = self._tick()

    def rmdir(self, path) -> None:
        self._gate(f"rmdir {path}")
        k = self._key(path)
        if k not in self.dirs:
            raise FileNotFoundError(k)
        if any(p != k and (p.startswith(k + "/"))
               for p in list(self.files) + list(self.dirs)):
            raise OSError(39, "directory not empty", k)  # ENOTEMPTY
        self.dirs.discard(k)

    def glob(self, dir_path, pattern: str) -> List[Path]:
        self._gate(f"glob {dir_path}/{pattern}")
        d = self._key(dir_path)
        names = set()
        for k in list(self.files) + list(self.dirs):
            if posixpath.dirname(k) == d:
                names.add(posixpath.basename(k))
        return sorted(Path(d) / n for n in names
                      if fnmatch.fnmatchcase(n, pattern))

    def exists(self, path) -> bool:
        self._gate(f"exists {path}")
        k = self._key(path)
        return k in self.files or k in self.dirs

    def rename(self, src, dst) -> None:
        self._gate(f"rename {src} -> {dst}")
        s, d = self._key(src), self._key(dst)
        if s not in self.files:
            raise FileNotFoundError(s)
        self.files[d] = self.files.pop(s)
        self.mtimes[d] = self.mtimes.pop(s)  # rename preserves mtime

    def link(self, src, dst) -> None:
        self._gate(f"link {src} -> {dst}")
        s, d = self._key(src), self._key(dst)
        if s not in self.files:
            raise FileNotFoundError(s)
        if d in self.files:
            raise FileExistsError(d)
        self.files[d] = self.files[s]
        self.mtimes[d] = self.mtimes[s]

    def unlink(self, path, missing_ok: bool = False) -> None:
        self._gate(f"unlink {path}")
        k = self._key(path)
        if k not in self.files:
            if missing_ok:
                return
            raise FileNotFoundError(k)
        del self.files[k]
        self.mtimes.pop(k, None)

    def read_text(self, path) -> str:
        self._gate(f"read {path}")
        k = self._key(path)
        if k not in self.files:
            raise FileNotFoundError(k)
        return self.files[k]

    def write_text(self, path, text: str) -> None:
        self._gate(f"write {path}")
        k = self._key(path)
        self.files[k] = text
        self.mtimes[k] = self._tick()

    def replace(self, src, dst) -> None:
        self._gate(f"replace {src} -> {dst}")
        s, d = self._key(src), self._key(dst)
        if s not in self.files:
            raise FileNotFoundError(s)
        self.files[d] = self.files.pop(s)
        self.mtimes[d] = self.mtimes.pop(s)

    def rewrite_nocreate(self, path, text: str) -> bool:
        self._gate(f"rewrite {path}")
        k = self._key(path)
        if k not in self.files:
            return False
        self.files[k] = text
        self.mtimes[k] = self._tick()
        return True

    def mtime(self, path) -> float:
        self._gate(f"mtime {path}")
        k = self._key(path)
        if k not in self.mtimes:
            raise FileNotFoundError(k)
        return self.mtimes[k]


# ---------------------------------------------------------------------------
# Per-step invariant: one state per ticket
# ---------------------------------------------------------------------------

def ticket_locations(fs: MemFS, root: str = QUEUE_ROOT) -> Dict[str, List[str]]:
    """Map ticket base name -> every queue location currently holding it
    (``pending/X.json``, ``leased/X.json.lease-O``, ``done/X.json``);
    tmp debris and foreign files are ignored, as the queue itself does."""
    locs: Dict[str, List[str]] = {}
    for k in fs.files:
        rel = posixpath.relpath(k, root)
        if rel.startswith(".."):
            continue
        parts = rel.split("/")
        if len(parts) != 2 or parts[0] not in ("pending", "leased", "done"):
            continue
        name = parts[1]
        if ".tmp" in name:
            continue
        base = name.rsplit(LEASE_INFIX, 1)[0] if parts[0] == "leased" \
            else name
        if not base.endswith(".json"):
            continue
        locs.setdefault(base, []).append(rel)
    return locs


def one_state_per_ticket(fs: MemFS, root: str = QUEUE_ROOT) -> Optional[str]:
    """The protocol's core safety property, checkable after every atomic
    step: no ticket may ever exist in two queue locations at once."""
    for base, places in sorted(ticket_locations(fs, root).items()):
        if len(places) > 1:
            return (f"one-state-per-ticket violated: {base} exists at "
                    f"{places}")
    return None


# ---------------------------------------------------------------------------
# TurnScheduler: one atomic step at a time, under one schedule
# ---------------------------------------------------------------------------

class TurnScheduler:
    """Runs each operation in a thread and grants one MemFS primitive at
    a time. Between steps every live thread is parked at its gate, so the
    ``enabled`` set at each decision point is exactly the unfinished ops
    — deterministic, which prefix-replay DFS requires."""

    WAIT_S = 10.0  # a stuck run is a bug in the run itself

    def __init__(self, op_names: Sequence[str]):
        self.cv = threading.Condition()
        self.names = list(op_names)
        self.by_ident: Dict[int, str] = {}
        self.waiting: Dict[str, str] = {}   # name -> label of next step
        self.finished: set = set()
        self.granted: Optional[str] = None
        self.abort = False

    # -- worker side --------------------------------------------------------

    def maybe_gate(self, label: str) -> None:
        """Called by MemFS before each primitive. Unregistered threads
        (setup / final checks on the main thread) pass straight through."""
        name = self.by_ident.get(threading.get_ident())
        if name is None:
            return
        with self.cv:
            self.waiting[name] = label
            self.cv.notify_all()
            while self.granted != name:
                if self.abort:
                    self.waiting.pop(name, None)
                    self.cv.notify_all()
                    raise SchedulerAbort()
                if not self.cv.wait(self.WAIT_S):
                    raise RuntimeError(f"op {name} starved at gate")
            self.granted = None
            self.waiting.pop(name, None)
            self.cv.notify_all()
        # returning = executing the one granted primitive

    def _worker(self, name: str, fn: Callable, results: Dict,
                errors: Dict) -> None:
        with self.cv:
            # self-registration: the ident only exists once the thread
            # runs, and the first fs primitive must already be gated
            self.by_ident[threading.get_ident()] = name
        try:
            results[name] = fn()
        except SchedulerAbort:
            pass
        except BaseException as e:  # an escaping exception IS a finding
            errors[name] = e
        finally:
            with self.cv:
                self.finished.add(name)
                self.waiting.pop(name, None)
                self.cv.notify_all()

    # -- driver side --------------------------------------------------------

    def _wait_quiescent(self) -> None:
        with self.cv:
            while self.granted is not None or (
                    len(self.waiting) + len(self.finished) < len(self.names)):
                if not self.cv.wait(self.WAIT_S):
                    raise RuntimeError(
                        f"scheduler stalled: waiting={list(self.waiting)} "
                        f"finished={sorted(self.finished)}")

    def _grant(self, name: str) -> None:
        with self.cv:
            self.granted = name
            self.cv.notify_all()

    def _teardown(self, threads: List[threading.Thread]) -> None:
        with self.cv:
            self.abort = True
            self.cv.notify_all()
        for t in threads:
            t.join(self.WAIT_S)

    def run(self, ops: Sequence[Tuple[str, Callable]],
            choices: Sequence[str],
            step_check: Callable[[], Optional[str]]) -> "RunResult":
        """Execute one schedule: follow ``choices`` while they name
        enabled ops (infeasible entries are skipped — the minimizer
        exploits this tolerance), then default to the first enabled op.
        ``step_check`` runs after every atomic step; the first violation
        aborts the run."""
        results: Dict[str, object] = {}
        errors: Dict[str, BaseException] = {}
        threads = [threading.Thread(target=self._worker,
                                    args=(name, fn, results, errors),
                                    daemon=True)
                   for name, fn in ops]
        for t in threads:
            t.start()
        queue = deque(choices)
        trace: List[Tuple[str, str, Tuple[str, ...]]] = []
        violation: Optional[str] = None
        while True:
            self._wait_quiescent()
            violation = step_check()
            if violation:
                break
            enabled = sorted(self.waiting)
            if not enabled:
                break  # every op ran to completion
            chosen = None
            while queue and chosen is None:
                c = queue.popleft()
                if c in enabled:
                    chosen = c
            if chosen is None:
                chosen = enabled[0]
            trace.append((chosen, self.waiting[chosen], tuple(enabled)))
            self._grant(chosen)
        self._teardown(threads)
        if violation is None and errors:
            name, e = sorted(errors.items())[0]
            violation = f"op {name} raised {type(e).__name__}: {e}"
        return RunResult(trace=trace, results=results, violation=violation)


@dataclass
class RunResult:
    """One executed schedule: the decision trace (chosen op, the atomic
    step it took, the enabled set), per-op return values, and the first
    invariant violation (None for a clean run)."""
    trace: List[Tuple[str, str, Tuple[str, ...]]]
    results: Dict[str, object]
    violation: Optional[str]

    @property
    def choices(self) -> List[str]:
        return [c for c, _, _ in self.trace]

    def render_schedule(self) -> str:
        lines = []
        for i, (chosen, label, enabled) in enumerate(self.trace, 1):
            mark = "" if len(enabled) == 1 else \
                f"   (enabled: {', '.join(enabled)})"
            lines.append(f"  step {i:>2}: {chosen:<10} {label}{mark}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Scenarios: real CellQueue operations under contention
# ---------------------------------------------------------------------------

def _copy(t: Ticket) -> Ticket:
    """Each op gets its own Ticket object — concurrent owners never
    share in-process state, only the filesystem."""
    return Ticket.from_json(t.to_json())


def _queues(fs: MemFS, n: int, lease_s: float = 100.0,
            queue_cls: type = CellQueue) -> List[CellQueue]:
    return [queue_cls(QUEUE_ROOT, lease_s=lease_s, fs=fs) for _ in range(n)]


@dataclass
class Built:
    """A scenario instance ready to run: the shared MemFS, the named
    concurrent operations, and the end-state assertion."""
    fs: MemFS
    ops: List[Tuple[str, Callable]]
    final_check: Callable[[Dict[str, object]], Optional[str]]
    initial_tickets: set = field(default_factory=set)


def _finish_build(fs: MemFS, ops, final_check) -> Built:
    return Built(fs=fs, ops=ops, final_check=final_check,
                 initial_tickets=set(ticket_locations(fs)))


def _scn_two_acquirers(queue_cls: type = CellQueue) -> Built:
    """Two owners race ``acquire`` for a single pending ticket: exactly
    one may win the claim rename; the loser gets None."""
    fs = MemFS(clock=NOW)
    q0, q1, q2 = _queues(fs, 3, queue_cls=queue_cls)
    q0.seed([("mxu", "s0")])

    def final(results):
        winners = [r for r in results.values() if r is not None]
        if len(winners) != 1:
            return f"expected exactly one acquire winner, got {len(winners)}"
        c = q0.counts()
        if c != {"pending": 0, "leased": 1, "done": 0}:
            return f"unexpected end state {c}"
        return None

    return _finish_build(fs, [
        ("alice", lambda: q1.acquire("alice", now=NOW)),
        ("bob", lambda: q2.acquire("bob", now=NOW)),
    ], final)


def _scn_acquire_vs_reclaim(queue_cls: type = CellQueue) -> Built:
    """An acquirer races an explicit ``reclaim_expired`` over one expired
    lease: the ticket must end in exactly one of pending/leased, never
    duplicated or lost."""
    fs = MemFS(clock=NOW)
    q0, q1, q2 = _queues(fs, 3, lease_s=50.0, queue_cls=queue_cls)
    q0.seed([("mxu", "s0")])
    assert q0.acquire("old_owner", now=0.0) is not None  # expires at 50

    def final(results):
        c = q0.counts()
        if c["done"] != 0 or c["pending"] + c["leased"] != 1:
            return f"unexpected end state {c}"
        if results.get("new_owner") is not None and c["leased"] != 1:
            return "acquire returned a ticket but nothing is leased"
        return None

    return _finish_build(fs, [
        ("new_owner", lambda: q1.acquire("new_owner", now=NOW)),
        ("reclaimer", lambda: q2.reclaim_expired(now=NOW)),
    ], final)


def _scn_complete_vs_steal(queue_cls: type = CellQueue) -> Built:
    """The owner's ``complete`` races a supervisor ``steal`` of the same
    live lease: the rename CAS lets exactly one side win — completion
    credit is granted exactly once or the ticket is back up for grabs."""
    fs = MemFS(clock=NOW)
    q0, q1, q2 = _queues(fs, 3, queue_cls=queue_cls)
    q0.seed([("mxu", "s0")])
    t = q0.acquire("alice", now=10.0)
    assert t is not None

    def final(results):
        completed = results.get("alice") is True
        stolen = results.get("stealer") is not None
        if completed == stolen:
            return (f"exactly-once violated: complete={completed} "
                    f"steal={stolen}")
        c = q0.counts()
        want = ({"pending": 0, "leased": 0, "done": 1} if completed
                else {"pending": 1, "leased": 0, "done": 0})
        if c != want:
            return f"end state {c} does not match winner (want {want})"
        return None

    return _finish_build(fs, [
        ("alice", lambda: q1.complete(_copy(t), now=NOW)),
        ("stealer", lambda: q2.steal(_copy(t), now=NOW)),
    ], final)


def _scn_renew_vs_steal(queue_cls: type = CellQueue) -> Built:
    """A heartbeat ``renew`` races a ``steal``: the steal's rename always
    wins eventually, and the renew — a never-creating rewrite — must not
    resurrect the lease it lost."""
    fs = MemFS(clock=NOW)
    q0, q1, q2 = _queues(fs, 3, queue_cls=queue_cls)
    q0.seed([("mxu", "s0")])
    t = q0.acquire("alice", now=10.0)
    assert t is not None

    def final(results):
        if results.get("stealer") is None:
            return "steal of a live lease unexpectedly failed"
        c = q0.counts()
        if c != {"pending": 1, "leased": 0, "done": 0}:
            return f"stolen ticket not solely pending: {c}"
        return None

    return _finish_build(fs, [
        ("alice", lambda: q1.renew(_copy(t), now=NOW)),
        ("stealer", lambda: q2.steal(_copy(t), now=NOW)),
    ], final)


def _scn_release_vs_complete(queue_cls: type = CellQueue) -> Built:
    """The supervisor's crash-path ``release_owner`` races the (not
    actually dead) owner's ``complete``: exactly one transition wins."""
    fs = MemFS(clock=NOW)
    q0, q1, q2 = _queues(fs, 3, queue_cls=queue_cls)
    q0.seed([("mxu", "s0")])
    t = q0.acquire("alice", now=10.0)
    assert t is not None

    def final(results):
        completed = results.get("alice") is True
        released = len(results.get("supervisor") or []) == 1
        if completed == released:
            return (f"exactly-once violated: complete={completed} "
                    f"release={released}")
        c = q0.counts()
        want = ({"pending": 0, "leased": 0, "done": 1} if completed
                else {"pending": 1, "leased": 0, "done": 0})
        if c != want:
            return f"end state {c} does not match winner (want {want})"
        return None

    return _finish_build(fs, [
        ("alice", lambda: q1.complete(_copy(t), now=NOW)),
        ("supervisor", lambda: q2.release_owner("alice", now=NOW)),
    ], final)


def _scn_two_cells(queue_cls: type = CellQueue) -> Built:
    """Two owners drain a two-ticket queue: both must come away with a
    (distinct) cell regardless of interleaving — losing a rename race
    means trying the next ticket, not giving up."""
    fs = MemFS(clock=NOW)
    q0, q1, q2 = _queues(fs, 3, queue_cls=queue_cls)
    q0.seed([("mxu", "s0"), ("vec", "s1")])

    def final(results):
        a, b = results.get("alice"), results.get("bob")
        if a is None or b is None:
            return f"an owner came away empty: alice={a} bob={b}"
        if (a.arch, a.shape) == (b.arch, b.shape):
            return f"both owners leased the same cell {a.cell}"
        c = q0.counts()
        if c != {"pending": 0, "leased": 2, "done": 0}:
            return f"unexpected end state {c}"
        return None

    return _finish_build(fs, [
        ("alice", lambda: q1.acquire("alice", now=NOW)),
        ("bob", lambda: q2.acquire("bob", now=NOW)),
    ], final)


SCENARIOS: Dict[str, Callable[..., Built]] = {
    "two_acquirers": _scn_two_acquirers,
    "acquire_vs_reclaim": _scn_acquire_vs_reclaim,
    "complete_vs_steal": _scn_complete_vs_steal,
    "renew_vs_steal": _scn_renew_vs_steal,
    "release_vs_complete": _scn_release_vs_complete,
    "two_cells": _scn_two_cells,
}


# ---------------------------------------------------------------------------
# The deliberately broken variant (explorer self-test)
# ---------------------------------------------------------------------------

class BrokenCellQueue(CellQueue):
    """``CellQueue`` with the textbook bug the real protocol exists to
    prevent: ``acquire`` is check-then-act — read the pending ticket,
    *create* the lease file, then unlink pending — three steps where the
    real code has one atomic rename. Two claimants interleaved between
    the read and the unlink both manufacture leases, putting one ticket
    in two states. Exists so tests and ``--broken`` can prove the
    explorer actually catches protocol violations."""

    def acquire(self, owner: str, now: Optional[float] = None,
                ) -> Optional[Ticket]:
        owner = sanitize_owner(owner)
        now = 0.0 if now is None else now
        for f in self._fs.glob(self._state_dir("pending"), "*.json"):
            if not self._fs.exists(f):
                continue
            try:
                text = self._fs.read_text(f)
            except OSError:
                continue
            target = self._lease_path(f.name, owner)
            # BUG: creates the lease while pending/ still holds the file
            self._fs.write_text(target, text)
            self._fs.unlink(f, missing_ok=True)
            try:
                t = Ticket.from_json(text)
            except Exception:
                t = Ticket(*self._cell_of(f.name))
            t.attempt += 1
            t.owner, t.leased_at = owner, now
            t.deadline = now + self.lease_s
            self._rewrite_existing(target, t)
            return t
        return None


# ---------------------------------------------------------------------------
# Exploration: DFS over the schedule tree by prefix replay
# ---------------------------------------------------------------------------

def run_once(build: Callable[[], Built],
             choices: Sequence[str]) -> RunResult:
    """Build a fresh scenario instance and execute one schedule. The
    per-step check is the one-state-per-ticket invariant; the final
    checks add ticket conservation and the scenario's own assertions."""
    b = build()
    sched = TurnScheduler([name for name, _ in b.ops])
    b.fs.scheduler = sched  # setup above ran ungated
    res = sched.run(b.ops, choices, lambda: one_state_per_ticket(b.fs))
    b.fs.scheduler = None
    if res.violation is None:
        now_tickets = set(ticket_locations(b.fs))
        if now_tickets != b.initial_tickets:
            res.violation = (
                "ticket conservation violated: started with "
                f"{sorted(b.initial_tickets)}, ended with "
                f"{sorted(now_tickets)}")
    if res.violation is None:
        res.violation = b.final_check(res.results)
    return res


@dataclass
class ExploreResult:
    """Outcome of exhaustively exploring one scenario."""
    scenario: str
    schedules: int
    max_decisions: int
    counterexample: Optional[RunResult] = None

    @property
    def ok(self) -> bool:
        return self.counterexample is None


def explore(build: Callable[[], Built], *, max_depth: int = 24,
            max_schedules: int = 5000, scenario: str = "") -> ExploreResult:
    """Enumerate interleavings depth-first: run a schedule prefix (default
    continuation: first enabled op), then branch on every alternative
    choice at every decision point past the prefix, up to ``max_depth``
    decisions deep. With a branching horizon past the longest trace this
    is *exhaustive*; the budget caps runaway scenarios."""
    stack: List[Tuple[str, ...]] = [()]
    seen = 0
    longest = 0
    while stack and seen < max_schedules:
        prefix = stack.pop()
        res = run_once(build, list(prefix))
        seen += 1
        longest = max(longest, len(res.trace))
        if res.violation is not None:
            return ExploreResult(scenario, seen, longest, res)
        for i in range(len(prefix), min(len(res.trace), max_depth)):
            chosen, _, enabled = res.trace[i]
            for alt in enabled:
                if alt != chosen:
                    stack.append(tuple(res.choices[:i]) + (alt,))
    return ExploreResult(scenario, seen, longest)


def _switches(choices: Sequence[str]) -> int:
    return sum(1 for a, b in zip(choices, choices[1:]) if a != b)


def minimize(build: Callable[[], Built],
             choices: Sequence[str]) -> RunResult:
    """Shrink a failing schedule: (1) shortest failing prefix — the
    default continuation past the prefix is deterministic, so a linear
    scan finds the earliest decision that seals the violation; (2) greedy
    context-switch reduction — try extending each op's run over the next
    decision and keep every variant that still fails with fewer
    switches. Best-effort, bounded; returns the final failing run."""
    best = list(choices)
    for k in range(len(best) + 1):
        r = run_once(build, best[:k])
        if r.violation is not None:
            best = best[:k]
            break
    budget = 200
    improved = True
    while improved and budget > 0:
        improved = False
        for i in range(1, len(best)):
            if best[i] == best[i - 1]:
                continue
            cand = best[:i] + [best[i - 1]] + best[i + 1:]
            if _switches(cand) >= _switches(best):
                continue
            budget -= 1
            if run_once(build, cand).violation is not None:
                best = cand
                improved = True
                break
    final = run_once(build, best)
    assert final.violation is not None, "minimizer lost the violation"
    return final


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    """The race-explorer CLI surface (parsed by
    scripts/check_quickstart.py to keep documented commands honest)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.race",
        description="bounded model checker for the CellQueue protocol: "
                    "exhaustively interleaves concurrent queue ops over "
                    "an in-memory fs and checks the one-state-per-ticket"
                    ", conservation, and exactly-once invariants")
    ap.add_argument("--scenario", action="append", default=None,
                    choices=sorted(SCENARIOS),
                    help="scenario(s) to explore (default: all)")
    ap.add_argument("--max-depth", type=int, default=24,
                    help="branching horizon in scheduling decisions "
                         "(default: 24 — past every shipped trace, i.e. "
                         "exhaustive)")
    ap.add_argument("--max-schedules", type=int, default=5000,
                    help="schedule budget per scenario (default: 5000)")
    ap.add_argument("--broken", action="store_true",
                    help="self-test: run the deliberately broken "
                         "check-then-act queue and DEMAND a "
                         "counterexample (exit 1 if none found)")
    ap.add_argument("--list", action="store_true",
                    help="list scenarios and exit")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list:
        for name, fn in sorted(SCENARIOS.items()):
            doc = (fn.__doc__ or "").strip().split("\n")[0]
            print(f"{name:<22} {doc}")
        return 0
    names = args.scenario or sorted(SCENARIOS)
    queue_cls = BrokenCellQueue if args.broken else CellQueue
    if args.broken:
        names = [n for n in names if "acquirers" in n or "cells" in n]
    failures = 0
    found_counterexample = False
    for name in names:
        factory = SCENARIOS[name]
        build = lambda f=factory: f(queue_cls=queue_cls)
        res = explore(build, max_depth=args.max_depth,
                      max_schedules=args.max_schedules, scenario=name)
        if res.ok:
            print(f"race: {name}: OK — {res.schedules} schedules "
                  f"explored exhaustively (longest trace "
                  f"{res.max_decisions} decisions)")
            continue
        found_counterexample = True
        failures += 1
        mini = minimize(build, res.counterexample.choices)
        print(f"race: {name}: VIOLATION after {res.schedules} schedules")
        print(f"  {mini.violation}")
        print("  minimal counterexample schedule "
              f"({_switches(mini.choices)} context switches):")
        print(mini.render_schedule())
    if args.broken:
        if found_counterexample:
            print("race: --broken self-test passed: the explorer caught "
                  "the check-then-act bug")
            return 0
        print("race: --broken self-test FAILED: no counterexample found "
              "for a queue that is known-broken")
        return 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
