"""repro.analysis — the engine's contracts, machine-checked.

The campaign engine's correctness rests on invariants that used to exist
only as prose and spot tests: atomic-rename ticket lifecycles in
``CellQueue``, never-creating in-place lease writes, ``write_json_atomic``
for every supervisor-polled JSON file, seeded-RNG determinism in
``repro.search``, and jax-free supervisor/bench processes. This package
turns those contracts into CI-enforced checks, two ways:

* **Invariant linter** (``repro.analysis.lint`` + ``repro.analysis.rules``)
  — an AST pass with project-specific rules RPR001–RPR006 (see
  ``rules.RULES`` or ``docs/architecture.md`` for the table), run as
  ``python -m repro.analysis.lint --baseline analysis_baseline.json``.
  The baseline is a *ratchet*: pre-existing debt is tolerated, new
  violations fail, and debt that disappears auto-tightens the baseline.

* **Queue-protocol race explorer** (``repro.analysis.race``) — a bounded
  model checker for ``CellQueue``: it runs the real ``acquire`` / ``renew``
  / ``complete`` / ``steal`` / ``reclaim_expired`` / ``release_owner``
  implementations against an instrumented in-memory filesystem
  (rename/link/unlink as atomic steps), exhaustively enumerates
  interleavings up to a bounded schedule depth, and asserts the
  one-state-per-ticket, ticket-conservation, and exactly-once-complete
  invariants — printing a minimized counterexample schedule on failure.
  Run as ``python -m repro.analysis.race``.

Pure stdlib — no jax, no third-party imports — so both tools run in bare
CI jobs and pre-commit hooks at interactive speed. (No eager re-exports
here: ``python -m repro.analysis.lint`` must not pre-import the module
runpy is about to execute.)
"""
