"""Invariant linter driver + CLI.

Parses every Python file under the lint root's ``src/repro`` and
``benchmarks`` trees (plus any extra paths given on the command line),
runs the RPR rule registry over the whole project at once (rules may be
cross-file — RPR004's jax-taint walks the import graph), and reports
findings against the ratcheting baseline.

Usage (from the repo root)::

    PYTHONPATH=src python -m repro.analysis.lint \\
        --baseline analysis_baseline.json

Exit codes: 0 clean (or debt fully covered by the baseline), 1 new
findings, 2 unparseable source. Stdlib-only; safe for bare CI jobs.
"""
from __future__ import annotations

import argparse
import ast
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from repro.analysis.baseline import (apply_baseline, load_baseline,
                                     write_baseline)
from repro.analysis.rules import RULES, Finding, number_occurrences

#: trees scanned by default, relative to --root
DEFAULT_SCAN = ("src/repro", "benchmarks")


class SourceFile:
    """One parsed source file: absolute path, root-relative posix path
    (what rules scope on), raw text, AST, and split lines."""

    def __init__(self, path: Path, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.tree = ast.parse(text, filename=str(path))
        self.lines = text.splitlines()


class Project:
    """The whole lint unit. Rules receive it alongside each file so
    cross-file analyses (RPR004 taint) can cache on it."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)


def discover(root: Path, scan: Sequence[str] = DEFAULT_SCAN,
             ) -> List[Path]:
    """Python files under the scan trees, sorted for run determinism."""
    out: List[Path] = []
    for sub in scan:
        base = root / sub
        if base.is_dir():
            out.extend(sorted(base.rglob("*.py")))
        elif base.is_file() and base.suffix == ".py":
            out.append(base)
    return out


def load_project(root: Path, paths: Sequence[Path]) -> Project:
    files = []
    for p in paths:
        rel = p.relative_to(root).as_posix()
        files.append(SourceFile(p, rel, p.read_text()))
    return Project(files)


def run_rules(project: Project, rules=None) -> List[Finding]:
    """All findings over the project, occurrence-numbered, in
    (path, line) order."""
    rules = RULES if rules is None else rules
    findings: List[Finding] = []
    for f in project.files:
        for rule in rules:
            if rule.applies(f):
                findings.extend(rule.check(f, project))
    findings.sort(key=lambda fd: (fd.rel, fd.line, fd.rule))
    return number_occurrences(findings)


def lint_paths(root: Path | str, scan: Sequence[str] = DEFAULT_SCAN,
               rules=None) -> List[Finding]:
    """Library entry point: lint the given root, return findings."""
    root = Path(root)
    return run_rules(load_project(root, discover(root, scan)), rules)


def build_parser() -> argparse.ArgumentParser:
    """The lint CLI surface (parsed by scripts/check_quickstart.py to
    keep documented commands honest)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="invariant linter for the repro engine: enforces "
                    "the atomic-write, determinism, jax-free, and "
                    "exception-handling contracts (rules RPR001-RPR006)")
    ap.add_argument("--root", default=".",
                    help="repo root to lint (default: cwd)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="ratcheting baseline JSON; new findings fail, "
                         "fixed debt auto-tightens the file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into --baseline "
                         "(bootstrap only)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable findings on stdout")
    ap.add_argument("--rules", default=None, metavar="IDS",
                    help="comma-separated rule ids to run "
                         "(default: all)")
    ap.add_argument("paths", nargs="*",
                    help="scan roots relative to --root "
                         f"(default: {' '.join(DEFAULT_SCAN)})")
    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    root = Path(args.root).resolve()
    scan = tuple(args.paths) if args.paths else DEFAULT_SCAN
    rules = RULES
    if args.rules:
        wanted = {r.strip().upper() for r in args.rules.split(",")}
        unknown = wanted - {r.id for r in RULES}
        if unknown:
            print(f"lint: unknown rule ids: {', '.join(sorted(unknown))}")
            return 2
        rules = [r for r in RULES if r.id in wanted]

    try:
        findings = lint_paths(root, scan, rules)
    except SyntaxError as e:
        print(f"lint: cannot parse {e.filename}:{e.lineno}: {e.msg}")
        return 2

    if args.write_baseline:
        if not args.baseline:
            print("lint: --write-baseline requires --baseline FILE")
            return 2
        write_baseline(root / args.baseline, findings)
        print(f"lint: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    if args.baseline:
        bl_path = root / args.baseline
        baseline = load_baseline(bl_path)
        new, known, stale = apply_baseline(findings, baseline)
        if stale:
            # the ratchet tightens: debt that stopped firing is removed
            # from the baseline so it can never silently come back
            write_baseline(bl_path, known)
            print(f"lint: ratchet tightened — {len(stale)} baseline "
                  f"entr{'y' if len(stale) == 1 else 'ies'} no longer "
                  f"fire(s); rewrote {args.baseline}")
        display = new
    else:
        new, known = findings, []
        display = findings

    if args.as_json:
        print(json.dumps([{
            "rule": fd.rule, "path": fd.rel, "line": fd.line,
            "message": fd.message, "snippet": fd.snippet,
            "fingerprint": fd.fingerprint,
            "baselined": fd in known} for fd in findings], indent=1))
    else:
        for fd in display:
            print(fd.render())

    n_files = len(discover(root, scan))
    if new:
        print(f"lint: {len(new)} new finding(s) across {n_files} files "
              f"({len(known)} baselined)")
        return 1
    print(f"lint: clean — {n_files} files, 0 new findings"
          + (f" ({len(known)} baselined)" if known else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
