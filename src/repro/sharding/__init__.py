"""repro subpackage."""
