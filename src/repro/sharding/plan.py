"""Execution-plan → sharding resolution.

A :class:`ShardingPlan` is the TPU analogue of a SECDA accelerator
configuration: it maps *logical* axes (embed/heads/ffn/experts/…) to mesh
axes, and carries the memory-policy knobs (remat, microbatches, ZeRO). The
DSE Explorer mutates plans; this module resolves them into per-tensor
``PartitionSpec`` s with device-aware divisibility fallbacks (non-divisible
dims are replicated and recorded — the paper's "device-aware parameter
ranges" constraint).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical dims of each activation "kind" passed to constrain(x, kind)
ACT_KINDS: Dict[str, Tuple[Optional[str], ...]] = {
    # residual carry: "seq" may be mesh-sharded (Megatron-style SP); compute
    # tensors shard heads/ffn/vocab instead and keep seq local ("seq_attn").
    "hidden": ("batch", "seq", "embed"),
    "heads": ("batch", "seq_attn", "heads", "head_dim"),
    "kv": ("batch", "seq_attn", "kv_heads", "head_dim"),
    "ffn": ("batch", "seq_attn", "ffn"),
    "logits": ("batch", "seq_attn", "vocab"),
    "experts_in": ("moe_groups", "experts", "capacity", "embed"),
    "expert_hidden": ("moe_groups", "experts", "capacity", "expert_ffn"),
    "ssm_inner": ("batch", "seq_attn", "ssm_inner"),
}

# logical dims of cache tensors, keyed by cache leaf path suffix
CACHE_KINDS: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
    "ck": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
    "cv": ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
    "len": ("batch",),
    "conv": ("layers", "batch", "conv", "ssm_inner"),
    "ssm": ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"),
}


@dataclass(frozen=True)
class ShardingPlan:
    """One point in the execution-plan design space."""

    name: str = "baseline"
    # logical axis -> mesh axis (str), tuple of mesh axes, or None (replicate)
    rules: Mapping[str, Any] = field(default_factory=dict)
    remat: str = "full"  # none | dots | full
    microbatches: int = 1
    zero1: bool = True  # shard optimizer state over the data axis
    master_weights: bool = False  # keep f32 master params in the opt state
    grad_compress: str = "none"  # none | int8 | topk
    decode_attn: str = "gspmd"  # gspmd | sp_shardmap (seq-sharded flash decode)
    loss_chunk: int = 0  # CE loss sequence chunking (0 = full logits)
    attn_impl: str = "chunked"  # chunked | tri (causal-skip triangular scan)
    opt_int8: bool = False  # blockwise int8 Adam moments (8-bit Adam)
    # logical axes allowed to shard unevenly (GSPMD pads, e.g. 56 heads / 16)
    force_uneven: Tuple[str, ...] = ()
    # Pallas kernel tiling (the paper's "compute unit dimensions")
    kernel_blocks: Mapping[str, int] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        r = self.rules.get(logical)
        if r is None:
            return ()
        return (r,) if isinstance(r, str) else tuple(r)

    def resolve(self, mesh: Mesh, shape: Sequence[int],
                logical_dims: Sequence[Optional[str]]) -> P:
        """PartitionSpec for one tensor, replicating non-divisible dims."""
        assert len(shape) == len(logical_dims), (shape, logical_dims)
        used: set = set()
        parts = []
        for dim, logical in zip(shape, logical_dims):
            axes = self.mesh_axes(logical)
            axes = tuple(a for a in axes if a in mesh.shape and a not in used)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            ok = dim % size == 0 or logical in self.force_uneven
            if axes and ok and dim > 0:
                used.update(axes)
                parts.append(axes[0] if len(axes) == 1 else axes)
            else:
                parts.append(None)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    # ------------------------------------------------------------------
    def param_specs(self, mesh: Mesh, values, logical_specs):
        """PartitionSpecs for a param tree given its logical-axes tree."""
        return jax.tree.map(
            lambda v, ax: self.resolve(mesh, v.shape, ax), values, logical_specs
        )

    def param_shardings(self, mesh: Mesh, values, logical_specs):
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.param_specs(mesh, values, logical_specs)
        )

    # ------------------------------------------------------------------
    def make_constrain(self, mesh: Optional[Mesh]):
        """The constrain(x, kind) hook passed into models. Besides sharding
        constraints it carries plan attributes the model layers dispatch on
        (``attn_impl``)."""
        if mesh is None:
            fn = lambda x, kind: x  # noqa: E731
        else:
            def fn(x, kind):
                dims = ACT_KINDS.get(kind)
                if dims is None or x.ndim != len(dims):
                    return x
                spec = self.resolve(mesh, x.shape, dims)
                return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

        return PlanCtx(fn, attn_impl=self.attn_impl)

    # ------------------------------------------------------------------
    def batch_specs(self, mesh: Mesh, batch_tree):
        """Shardings for a data batch: leading dim = batch."""

        def one(leaf):
            dims = ("batch",) + (None,) * (len(leaf.shape) - 1)
            return self.resolve(mesh, leaf.shape, dims)

        return jax.tree.map(one, batch_tree)

    def cache_specs(self, mesh: Mesh, cache_tree):
        """Shardings for a KV/SSM cache tree (path-aware)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
        specs = []
        for path, leaf in flat:
            key = None
            for p in reversed(path):
                if hasattr(p, "key"):
                    key = p.key
                    break
            dims = CACHE_KINDS.get(key)
            if dims is None or len(dims) != len(leaf.shape):
                # attn caches inside hybrid have no leading layer dim variants
                if key in ("k", "v", "ck", "cv") and len(leaf.shape) == 5:
                    dims = CACHE_KINDS[key]
                elif key in ("conv", "ssm") and len(leaf.shape) == len(CACHE_KINDS[key]):
                    dims = CACHE_KINDS[key]
                else:
                    dims = (None,) * len(leaf.shape)
            specs.append(self.resolve(mesh, leaf.shape, dims))
        return jax.tree_util.tree_unflatten(treedef, specs)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["rules"] = dict(self.rules)
        d["kernel_blocks"] = dict(self.kernel_blocks)
        return d


class PlanCtx:
    """Callable constrain hook carrying plan attributes for model dispatch."""

    def __init__(self, fn, attn_impl: str = "chunked"):
        self._fn = fn
        self.attn_impl = attn_impl

    def __call__(self, x, kind):
        return self._fn(x, kind)


# ---------------------------------------------------------------------------
# Baseline plan factory — the "expert initial design" that seeds the DSE loop
# ---------------------------------------------------------------------------
def baseline_rules(multi_pod: bool = False) -> Dict[str, Any]:
    data = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": data,
        "moe_groups": data,
        "seq": "model",  # sequence-sharded residuals (SP) — memory floor
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "expert_ffn": None,
        "vocab": "model",
        "experts": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "seq_kv": "model",  # decode KV caches: shard the sequence dim
        "lora_rank": None,
        "layers": None,
        "conv": None,
        "capacity": None,
    }


def baseline_plan(cfg, cell, *, multi_pod: bool = False) -> ShardingPlan:
    """Paper-faithful starting point: an expert-written initial configuration
    (SECDA-DSE §3.1 — 'an accelerator design generated initially by an expert
    designer') from which the DSE explores."""
    rules = baseline_rules(multi_pod)
    remat = "full" if cell.kind == "train" else "none"
    return ShardingPlan(name=f"baseline/{cfg.name}/{cell.name}", rules=rules, remat=remat)
