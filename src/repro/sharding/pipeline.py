"""Pipeline parallelism via shard_map + collective_permute (GPipe schedule).

Stages are carved from a mesh axis (on the multi-pod mesh the natural choice
is the ``pod`` axis: activations cross pods once per stage boundary — the
cheapest possible inter-pod traffic pattern, vs per-layer collectives).

Layout: layer-stacked params [L, ...] are reshaped to [S, L/S, ...] and
sharded on the stage axis, so each stage's device group holds only its
layers. Microbatches stream through the classic GPipe schedule:

    T = n_micro + S - 1 ticks; at tick t, stage s processes microbatch
    (t - s); activations hop stage->stage+1 via ppermute.

Forward pass (serving pipelines / pipelined prefill). Training composes it
with grad accumulation outside; bwd-through-ppermute works under jax AD but
the interleaved 1F1B schedule is future work (documented in DESIGN.md).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(
    layer_fn: Callable,  # (layer_params, x [mb, ...]) -> x
    stacked_params,  # pytree, leaves [L, ...]
    x_mb: jax.Array,  # [n_micro, mb, ...] microbatched inputs
    mesh: Mesh,
    *,
    stage_axis: str = "stage",
):
    """Returns y [n_micro, mb, ...] = sequential-layer application, executed
    as an S-stage pipeline over ``stage_axis``."""
    S = mesh.shape[stage_axis]
    n_micro = x_mb.shape[0]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % S == 0, (L, S)

    # [L, ...] -> [S, L/S, ...]; shard dim 0 on the stage axis
    grouped = jax.tree.map(
        lambda a: a.reshape(S, L // S, *a.shape[1:]), stacked_params)

    def stage_body(params_local, x_mb_local):
        # params_local: [1, L/S, ...] (this stage's layers); x_mb_local: full
        # microbatch stream, replicated along the stage axis
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        T = n_micro + S - 1

        def run_layers(x):
            def body(c, lp):
                return layer_fn(lp, c), None

            y, _ = jax.lax.scan(body, x, params_local)
            return y

        def tick(t, carry):
            buf, out = carry  # buf: [mb, ...] activation entering this stage
            mb_idx = t - sid  # which microbatch this stage works on
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 ingests a fresh microbatch each tick
            fresh = jax.lax.dynamic_index_in_dim(
                x_mb_local, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            x_in = jnp.where(sid == 0, fresh, buf)
            y = run_layers(x_in)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where((sid == S - 1) & active, y, jax.lax.dynamic_index_in_dim(out, jnp.clip(mb_idx, 0, n_micro - 1), 0, keepdims=False)),
                jnp.clip(mb_idx, 0, n_micro - 1), 0)
            # hop: stage s sends y to stage s+1
            nxt = jax.lax.ppermute(
                y, stage_axis, [(i, i + 1) for i in range(S - 1)])
            return (nxt, out)

        out0 = jnp.zeros_like(x_mb_local)
        buf0 = jnp.zeros_like(x_mb_local[0])
        _, out = jax.lax.fori_loop(0, T, tick, (buf0, out0))
        # only the last stage holds real outputs; masked psum broadcasts them
        out = jax.lax.psum(
            jnp.where(sid == S - 1, out, jnp.zeros_like(out)), stage_axis)
        return out

    pspec = jax.tree.map(lambda _: P(stage_axis), grouped)
    f = shard_map(
        stage_body, mesh=mesh,
        in_specs=(pspec, P()), out_specs=P(),
        check_vma=False,
    )
    return f(grouped, x_mb)
