"""Fault-tolerant training loop.

Production behaviours implemented (and tested on the CPU mesh):
  * periodic checkpoints (atomic; resume is bit-exact — tested),
  * step-level fault handling: a failing step (injected via ``fault_hook`` in
    tests; a real pod would surface XLA/ICI errors the same way) triggers
    restore-from-latest-checkpoint and replay, up to ``max_retries``,
  * elastic restart: the checkpoint stores full logical tensors, so a restart
    with a different device count resharding-on-restore just works,
  * straggler watchdog: an EMA of step wall-time flags outliers and calls the
    rebalance hook (in multi-host deployments this re-maps data shards;
    simulated in tests).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt_mod
from repro.train.data import DataConfig, Prefetcher, SyntheticLM


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "artifacts/ckpt"
    max_retries: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0  # flag steps slower than factor x EMA
    ema_alpha: float = 0.2


@dataclass
class Trainer:
    cfg: Any  # ArchConfig
    plan: Any  # ShardingPlan
    step_fn: Callable  # jitted (state, batch) -> (state, metrics)
    state: Any
    data: SyntheticLM
    tcfg: TrainerConfig = field(default_factory=TrainerConfig)
    fault_hook: Optional[Callable[[int], None]] = None  # raises to inject faults
    rebalance_hook: Optional[Callable[[int], None]] = None
    history: List[Dict[str, float]] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)

    def run(self, start_step: int = 0) -> Dict[str, Any]:
        t = self.tcfg
        step = start_step
        retries = 0
        ema = None
        last_ckpt = start_step
        if start_step == 0:
            ckpt_mod.save_checkpoint(t.ckpt_dir, 0, self.state)

        n_timed = 0
        while step < t.total_steps:
            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch(step).items()}
            t0 = time.perf_counter()
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                self.state, metrics = self.step_fn(self.state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
            except Exception as e:  # noqa: BLE001 — any step fault is retryable
                retries += 1
                if retries > t.max_retries:
                    raise RuntimeError(
                        f"step {step} failed {retries} times; giving up") from e
                restore = ckpt_mod.latest_step(t.ckpt_dir)
                self.state, step, _ = ckpt_mod.restore_checkpoint(
                    t.ckpt_dir, self.state, step=restore)
                print(f"[trainer] fault at step: {e!r} -> restored step {step}, "
                      f"retry {retries}/{t.max_retries}", flush=True)
                continue

            dt = time.perf_counter() - t0
            n_timed += 1
            if n_timed == 1:
                pass  # first step includes jit compile — never in the EMA
            elif ema is None:
                ema = dt
            else:
                if dt > t.straggler_factor * ema:
                    self.stragglers.append(step)
                    if self.rebalance_hook is not None:
                        self.rebalance_hook(step)
                ema = (1 - t.ema_alpha) * ema + t.ema_alpha * dt

            self.history.append({"step": step, "loss": loss, "dt": dt})
            if step % t.log_every == 0:
                print(f"[trainer] step {step:5d} loss {loss:.4f} {dt*1e3:.0f}ms",
                      flush=True)
            step += 1
            retries = 0
            if step - last_ckpt >= t.ckpt_every:
                ckpt_mod.save_checkpoint(t.ckpt_dir, step, self.state)
                last_ckpt = step

        ckpt_mod.save_checkpoint(t.ckpt_dir, step, self.state)
        return {"final_step": step, "history": self.history,
                "stragglers": self.stragglers}
