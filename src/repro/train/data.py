"""Deterministic synthetic token pipeline with background prefetch.

Data is generated from a seeded Zipf-ish unigram mixture with injected
n-gram structure (so tiny models actually *learn* and the loss curve is a
meaningful end-to-end signal), sharded by host (``host_id``/``n_hosts`` — the
straggler-rebalance hook re-maps this), and prefetched on a worker thread.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class SyntheticLM:
    """Order-2 Markov chain with a Zipf marginal — learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        self._zipf = 1.0 / np.arange(1, v + 1) ** 1.1
        self._zipf /= self._zipf.sum()
        # sparse bigram successor table: each token prefers a few successors
        self._succ = rng.integers(0, v, size=(min(v, 4096), 4))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        assert c.global_batch % c.n_hosts == 0
        b_local = c.global_batch // c.n_hosts
        rng = np.random.default_rng((c.seed, step, c.host_id))
        toks = np.empty((b_local, c.seq_len + 1), np.int32)
        cur = rng.choice(c.vocab, size=b_local, p=self._zipf)
        toks[:, 0] = cur
        for t in range(1, c.seq_len + 1):
            follow = rng.random(b_local) < 0.8
            succ_rows = self._succ[cur % self._succ.shape[0]]
            pick = succ_rows[np.arange(b_local), rng.integers(0, 4, b_local)]
            fresh = rng.choice(c.vocab, size=b_local, p=self._zipf)
            cur = np.where(follow, pick, fresh).astype(np.int32)
            toks[:, t] = cur
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class Prefetcher:
    """Background-thread prefetch of the next ``depth`` batches."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self) -> Dict[str, np.ndarray]:
        step, batch = self.q.get()
        return batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
