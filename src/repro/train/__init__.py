"""repro subpackage."""
