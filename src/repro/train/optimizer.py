"""AdamW with optional ZeRO-1 sharded optimizer state and f32 master weights.

The optimizer state is a plain pytree so it checkpoints/reshards with the
same machinery as params. ZeRO-1: m/v (and master weights) are additionally
sharded over the data axis on the largest dim that is divisible and not
already sharded — gradients then reduce-scatter instead of all-reduce under
GSPMD.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


# ---------------------------------------------------------------------------
# int8 moment quantization (8-bit Adam, Dettmers-style)
# Scales are per-row (last dim): no reshape/flatten, so the quantized moments
# keep exactly the param's sharding (a flattened blockwise layout would force
# XLA to replicate 2-D-sharded tensors during (de)quantization).
# ---------------------------------------------------------------------------
def _q8(x):
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale, shape):
    return (q.astype(jnp.float32) * scale).reshape(shape)


def lr_schedule(c: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(c.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - c.warmup_steps) / jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return c.lr * warm * (c.min_lr_frac + (1 - c.min_lr_frac) * cos)


def init_opt_state(params, *, master_weights: bool = False, int8_moments: bool = False):
    if int8_moments:
        def zq(p):
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}

        st = {"m": jax.tree.map(zq, params), "v": jax.tree.map(zq, params),
              "step": jnp.zeros((), jnp.int32)}
    else:
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        st = {
            "m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params),
            "step": jnp.zeros((), jnp.int32),
        }
    if master_weights:
        st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return st


def _is_q(x):
    return isinstance(x, dict) and set(x) == {"q", "s"}


def uses_int8(opt_state) -> bool:
    leaves = jax.tree.leaves(opt_state["m"], is_leaf=_is_q)
    return bool(leaves) and _is_q(leaves[0])


def adamw_update(c: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics). All math in f32; moments
    optionally stored blockwise-int8 (8-bit Adam)."""
    int8 = uses_int8(opt_state)
    step = opt_state["step"] + 1
    lr = lr_schedule(c, step)
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)) + 1e-16)
    scale = jnp.minimum(1.0, c.grad_clip / gnorm)
    g32 = jax.tree.map(lambda g: g * scale, g32)

    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)
    base = opt_state.get("master", params)

    def upd(p, g, m, v):
        if int8:
            m = _dq8(m["q"], m["s"], p.shape)
            v = _dq8(v["q"], v["s"], p.shape)
        m = c.b1 * m + (1 - c.b1) * g
        v = c.b2 * v + (1 - c.b2) * g * g
        mh, vh = m / b1c, v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p32)
        if int8:
            mq, msc = _q8(m)
            vq, vsc = _q8(v)
            m, v = {"q": mq, "s": msc}, {"q": vq, "s": vsc}
        return p32, m, v

    is_leaf = lambda t: isinstance(t, tuple) or _is_q(t)
    out = jax.tree.map(upd, base, g32, opt_state["m"], opt_state["v"],
                       is_leaf=lambda x: _is_q(x))
    p32s = jax.tree.map(lambda t: t[0], out, is_leaf=is_leaf)
    ms = jax.tree.map(lambda t: t[1], out, is_leaf=is_leaf)
    vs = jax.tree.map(lambda t: t[2], out, is_leaf=is_leaf)

    new_params = jax.tree.map(lambda p32, p: p32.astype(p.dtype), p32s, params)
    new_state = {"m": ms, "v": vs, "step": step}
    if "master" in opt_state:
        new_state["master"] = p32s
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of the optimizer state
# ---------------------------------------------------------------------------
def opt_specs(mesh: Mesh, param_spec_tree, params, *, zero1: bool, master: bool,
              int8: bool = False):
    """PartitionSpecs for the optimizer state given resolved param specs."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]

    if int8:
        # per-row-quantized moments keep the param's sharding; the scale
        # tensor drops the (size-1) last dim's sharding
        def qspec(spec: P, p) -> dict:
            parts = list(spec) + [None] * (p.ndim - len(spec))
            sparts = list(parts)
            if sparts:
                sparts[-1] = None
            while sparts and sparts[-1] is None:
                sparts.pop()
            return {"q": P(*parts), "s": P(*sparts)}

        mv = jax.tree.map(qspec, param_spec_tree, params)
        st = {"m": mv, "v": mv, "step": P()}
        if master:
            st["master"] = param_spec_tree
        return st

    def zero_shard(spec: P, leaf) -> P:
        if not zero1 or not data_axes:
            return spec
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        used = {a for part in parts if part is not None
                for a in ((part,) if isinstance(part, str) else part)}
        if any(a in used for a in data_axes):
            return spec  # param sharding already consumes the data axis (FSDP)
        # shard the largest unsharded, divisible dim over the data axes
        cand = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in cand:
            if parts[i] is None and leaf.shape[i] % dsize == 0 and leaf.shape[i] >= dsize:
                parts[i] = data_axes[0] if len(data_axes) == 1 else data_axes
                break
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    mv = jax.tree.map(zero_shard, param_spec_tree, params)
    st = {"m": mv, "v": mv, "step": P()}
    if master:
        st["master"] = mv
    return st
