"""Sharded numpy checkpointing with atomic manifest commit.

Layout:
    <dir>/step_000100/
        manifest.json      # tree structure, shapes, dtypes, step, mesh info
        arr_00000.npy ...  # one file per leaf (full logical tensors)
        COMMIT             # written last — a checkpoint without it is ignored

Checkpoints store *full logical tensors* (gathered from the mesh), which
makes them mesh-agnostic: restore may reshard onto any device count
(elastic restart). Writes go to a temp dir + atomic rename so a crash
mid-write can never corrupt the latest checkpoint. Fault-tolerance contract:
``latest_step`` only ever returns fully-committed checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.ioutil import write_json_atomic


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str | Path, step: int, state, *,
                    extra: Optional[Dict[str, Any]] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    paths, leaves, _ = _flatten_with_paths(state)

    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        manifest = {"step": step, "time": time.time(), "extra": extra or {},
                    "leaves": []}
        for i, (p, leaf) in enumerate(zip(paths, leaves)):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append({
                "path": p, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)})
        # atomic even inside the staging dir: an elastic-restart reader that
        # races the final os.replace must never parse a torn manifest
        write_json_atomic(tmp / "manifest.json", manifest)
        (tmp / "COMMIT").write_text(str(step))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic on POSIX
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.name.startswith("step_") and (d / "COMMIT").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str | Path, like_state, *,
                       step: Optional[int] = None,
                       shardings=None) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``like_state``; optionally device_put
    each leaf with the given shardings tree (elastic reshard-on-restore)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_path = {l["path"]: l for l in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(like_state)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, like, sh in zip(paths, leaves, shard_leaves):
        rec = by_path.get(p)
        if rec is None:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = np.load(d / rec["file"])
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{p}: shape {arr.shape} != expected {like.shape}")
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr, dtype=like.dtype))
    state = jax.tree_util.tree_unflatten(treedef, out)
    return state, step, manifest.get("extra", {})
