"""Elastic re-scaling: restore a checkpoint onto a different mesh.

Checkpoints hold full logical tensors, so scaling events (node loss, pool
resize) are handled by rebuilding the mesh from the surviving device count
and ``device_put``-ing every leaf with the new plan-resolved sharding.
The *global batch is preserved* (per-device batch grows/shrinks), so the
optimizer trajectory is unchanged — verified bit-close in tests.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.launch.mesh import make_mesh
from repro.train import checkpoint as ckpt_mod
from repro.train import step as step_mod


def choose_mesh_shape(n_devices: int) -> Tuple[Tuple[int, int], Tuple[str, str]]:
    """Largest (data, model) factorization with model <= data."""
    best = (n_devices, 1)
    m = 1
    while m * m <= n_devices:
        if n_devices % m == 0:
            best = (n_devices // m, m)
        m *= 2
    return best, ("data", "model")


def rebuild(cfg, plan, ckpt_dir: str, *, devices: Optional[int] = None,
            opt_cfg=None):
    """(state, mesh, jitted step, restored step) for the surviving devices."""
    n = devices or len(jax.devices())
    shape, axes = choose_mesh_shape(n)
    mesh = make_mesh(shape, axes)
    jstep, abstract, (s_shard, _) = step_mod.jit_train_step(
        cfg, plan, mesh, opt_cfg, donate=False)
    state, step, extra = ckpt_mod.restore_checkpoint(
        ckpt_dir, abstract, shardings=s_shard)
    return state, mesh, jstep, step
