"""Train step factory: loss + grad (+ microbatch accumulation) + AdamW.

The step is a pure function (state, batch) -> (state, metrics), jit-able with
in/out shardings resolved from the plan — the artifact the dry-run lowers.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.train import optimizer as opt_mod
from repro.train.grad_compress import compress_decompress, init_error_feedback


def init_train_state(cfg, key, plan, opt_cfg: Optional[opt_mod.AdamWConfig] = None):
    params, specs = M.materialize_params(cfg, key)
    state = {
        "params": params,
        "opt": opt_mod.init_opt_state(params, master_weights=plan.master_weights,
                                      int8_moments=getattr(plan, "opt_int8", False)),
    }
    if plan.grad_compress != "none":
        state["ef"] = init_error_feedback(params)
    return state, specs


def abstract_train_state(cfg, plan):
    """ShapeDtypeStructs for the train state (dry-run path, no allocation)."""
    values, specs = M.abstract_params(cfg)
    state = {
        "params": values,
        "opt": jax.eval_shape(
            lambda: opt_mod.init_opt_state(
                values, master_weights=plan.master_weights,
                int8_moments=getattr(plan, "opt_int8", False))
        ),
    }
    if plan.grad_compress != "none":
        state["ef"] = jax.eval_shape(lambda: init_error_feedback(values))
    return state, specs


def state_specs(mesh: Mesh, plan, state, logical_specs):
    pspecs = plan.param_specs(mesh, state["params"], logical_specs)
    ospecs = opt_mod.opt_specs(
        mesh, pspecs, state["params"], zero1=plan.zero1,
        master=plan.master_weights, int8=getattr(plan, "opt_int8", False)
    )
    out = {"params": pspecs, "opt": ospecs}
    if "ef" in state:
        out["ef"] = opt_mod.opt_specs(mesh, pspecs, state["params"],
                                      zero1=plan.zero1, master=False)["m"]
    return out


def make_train_step(cfg, plan, mesh: Optional[Mesh] = None,
                    opt_cfg: Optional[opt_mod.AdamWConfig] = None):
    opt_cfg = opt_cfg or opt_mod.AdamWConfig()
    constrain = plan.make_constrain(mesh)

    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch, constrain, plan.remat,
                         getattr(plan, "loss_chunk", 0))

    # ZeRO-2-style sharding for the microbatch grad accumulator: without it a
    # k-microbatch step holds a full f32 grad copy (params/TP x 4B) per device
    acc_shard = None
    if mesh is not None and plan.zero1 and plan.microbatches > 1:
        values, logical = M.abstract_params(cfg)
        pspecs = plan.param_specs(mesh, values, logical)
        aspecs = opt_mod.opt_specs(mesh, pspecs, values, zero1=True, master=False)["m"]
        acc_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), aspecs)

    # batch-shard degree: microbatch slicing must be strided so every device
    # keeps b_local/k rows per microbatch (a contiguous reshape would leave
    # 1/k of the devices active and force XLA to rematerialize/replicate)
    bdeg = 1
    if mesh is not None:
        for ax in plan.mesh_axes("batch"):
            bdeg *= mesh.shape.get(ax, 1)

    def train_step(state, batch) -> Tuple[Any, Dict[str, Any]]:
        params = state["params"]
        k = plan.microbatches
        if k > 1:
            def to_mb(a):
                B = a.shape[0]
                D = bdeg if (bdeg > 1 and B % bdeg == 0 and (B // bdeg) % k == 0) else 1
                if D > 1:
                    x = a.reshape(D, k, B // (D * k), *a.shape[1:])
                    return x.transpose(1, 0, *range(2, x.ndim)).reshape(
                        k, B // k, *a.shape[1:])
                return a.reshape(k, B // k, *a.shape[1:])

            mb = jax.tree.map(to_mb, batch)

            def acc(gsum, b1):
                (l, mets), g = jax.value_and_grad(loss_of, has_aux=True)(params, b1)
                gsum = jax.tree.map(lambda s, x: s + x.astype(jnp.float32), gsum, g)
                if acc_shard is not None:  # reduce-scatter per microbatch (ZeRO-2)
                    gsum = jax.tree.map(jax.lax.with_sharding_constraint, gsum, acc_shard)
                return gsum, l

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if acc_shard is not None:
                g0 = jax.tree.map(jax.lax.with_sharding_constraint, g0, acc_shard)
            gsum, losses = jax.lax.scan(acc, g0, mb)
            grads = jax.tree.map(lambda g: g / k, gsum)
            loss = losses.mean()
            mets = {"loss": loss}
        else:
            (loss, mets), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)

        new_ef = None
        if plan.grad_compress != "none":
            grads, new_ef = compress_decompress(plan.grad_compress, grads, state["ef"])

        new_params, new_opt, omets = opt_mod.adamw_update(opt_cfg, params, grads, state["opt"])
        new_state = {"params": new_params, "opt": new_opt}
        if new_ef is not None:
            new_state["ef"] = new_ef
        metrics = {"loss": loss, **omets}
        if "tokens" in mets:
            metrics["tokens"] = mets["tokens"]
        return new_state, metrics

    return train_step


def jit_train_step(cfg, plan, mesh, opt_cfg=None, *, abstract: bool = True, donate: bool = True):
    """Returns (jitted step, abstract state, (state_shardings, batch_shardings))."""
    step = make_train_step(cfg, plan, mesh, opt_cfg)
    state, logical = abstract_train_state(cfg, plan)
    sspecs = state_specs(mesh, plan, state, logical)
    s_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs)
    metric_shard = NamedSharding(mesh, P())
    jstep = jax.jit(
        step,
        in_shardings=(s_shard, None),
        out_shardings=(s_shard, None),
        donate_argnums=(0,) if donate else (),
    )
    return jstep, state, (s_shard, None)
