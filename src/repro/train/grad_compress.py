"""Gradient compression with error feedback.

Two codecs, both applied to gradients before the optimizer:

* ``int8`` — per-tensor symmetric quantization (32x -> 8x bytes on the wire
  for the cross-pod gradient reduction; 4x vs f32).
* ``topk`` — keep the top 1% magnitudes per tensor (sparse all-reduce model).

Error feedback (Seide et al.; 1-bit SGD lineage) accumulates the residual
``g - decompress(compress(g))`` into the next step so compression bias does
not accumulate. In a single-process simulation the codec round-trip is the
numerics-faithful stand-in for the compressed collective; the byte saving is
credited in the roofline evaluator's collective term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TOPK_FRAC = 0.01


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_roundtrip(g):
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g):
    flat = g.reshape(-1)
    n = flat.shape[0]
    if n <= 1 << 22:
        k = max(int(n * TOPK_FRAC), 1)
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    else:
        # huge tensors: lax.top_k would overflow int32 indices (and sort
        # billions of elements) — estimate the magnitude threshold from a
        # strided sample instead
        stride = n // (1 << 20)
        sample = jnp.abs(flat[:: stride])
        k = max(int(sample.shape[0] * TOPK_FRAC), 1)
        thresh = jax.lax.top_k(sample, k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)


def compress_decompress(kind: str, grads, ef):
    """Returns (decompressed grads, new error-feedback state)."""
    codec = {"int8": _int8_roundtrip, "topk": _topk_roundtrip}[kind]

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        dec = codec(g32)
        return dec, g32 - dec

    out = jax.tree.map(one, grads, ef)
    dec = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return dec, new_ef


def wire_bytes_factor(kind: str) -> float:
    """Bytes-on-the-wire multiplier vs uncompressed bf16 gradients."""
    return {"none": 1.0, "int8": 0.5, "topk": 2.5 * TOPK_FRAC}[kind]
