"""Architecture config registry: one module per assigned architecture."""
from __future__ import annotations

from repro.configs.base import ArchConfig, MoESpec, SSMSpec, ShapeCell, SHAPES, SHAPE_BY_NAME, reduced

_MODULES = {
    "llama3-8b": "llama3_8b",
    "qwen3-8b": "qwen3_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "stablelm-3b": "stablelm_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mamba2-780m": "mamba2_780m",
    "llava-next-34b": "llava_next_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


__all__ = [
    "ArchConfig", "MoESpec", "SSMSpec", "ShapeCell", "SHAPES", "SHAPE_BY_NAME",
    "ARCH_NAMES", "get_config", "reduced",
]
