"""Architecture configuration schema.

Every assigned architecture is expressed as an :class:`ArchConfig`. The config
is the *workload* half of a SECDA-DSE design point; the *plan* half
(sharding / remat / tiling) lives in ``repro.core.design_space``.

Configs are frozen dataclasses so they can be hashed into cost-DB keys.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoESpec:
    """Mixture-of-experts layer spec (token-choice top-k, grouped capacity)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Tokens are partitioned into groups of this size; expert capacity is
    # per-group (bounds the dispatch one-hot to group_size**2 * top_k * cf).
    group_size: int = 64

    def capacity(self) -> int:
        cap = int(self.top_k * self.group_size * self.capacity_factor) // self.n_experts
        return max(cap, 1)


@dataclass(frozen=True)
class SSMSpec:
    """Mamba2 / SSD block spec."""

    d_state: int
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # defaults to d_model // n_heads
    qk_norm: bool = False
    swa_window: Optional[int] = None  # sliding-window attention width
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    # hybrid (zamba2): run a single *shared* attention+MLP block every k
    # mamba layers, with per-invocation LoRA deltas on its projections.
    hybrid_attn_every: Optional[int] = None
    hybrid_lora_rank: int = 64
    # encoder-decoder (seamless): n_layers is the decoder depth.
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: "patches" (vlm) / "frames" (audio). input_specs
    # provides precomputed embeddings of this many positions.
    frontend: Optional[str] = None
    frontend_len: int = 0
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def head_dim(self) -> int:
        if self.d_head is not None:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    def attention_free(self) -> bool:
        return self.family == "ssm"

    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token context? (SSM / hybrid / bounded SWA)."""
        return self.family in ("ssm", "hybrid") or self.swa_window is not None

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks); used for MODEL_FLOPS."""
        d, dh = self.d_model, self.head_dim()
        p = self.vocab * d  # embedding
        if not self.tie_embeddings:
            p += self.vocab * d  # lm head

        def attn_params() -> int:
            q = d * self.n_heads * dh
            kv = 2 * d * self.n_kv_heads * dh
            o = self.n_heads * dh * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            return 3 * d * ff  # gated SwiGLU

        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per = (
                d * (2 * di + 2 * s.d_state + nh)  # in_proj -> (z, x, B, C, dt)
                + s.conv_width * (di + 2 * s.d_state)
                + di * d  # out_proj
                + 2 * nh  # A_log, D
            )
            return p + self.n_layers * per
        if self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per = (
                d * (2 * di + 2 * s.d_state + nh)
                + s.conv_width * (di + 2 * s.d_state)
                + di * d
                + 2 * nh
            )
            p += self.n_layers * per
            p += attn_params() + mlp_params(self.d_ff)  # one shared block
            n_uses = self.n_layers // (self.hybrid_attn_every or self.n_layers)
            r = self.hybrid_lora_rank
            p += n_uses * r * (4 * d + self.n_heads * dh + 2 * self.n_kv_heads * dh + 2 * self.d_ff)
            return p
        per = attn_params()
        if self.moe is not None:
            per += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            per += d * self.moe.n_experts  # router
        else:
            per += mlp_params(self.d_ff)
        per += 2 * d  # norms
        n_blocks = self.n_layers + (self.n_enc_layers if self.enc_dec else 0)
        if self.enc_dec:
            per += attn_params()  # cross attention (decoder side, approx)
        return p + n_blocks * per

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        d = self.d_model
        moe_all = self.n_layers * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        moe_active = self.n_layers * self.moe.top_k * 3 * d * self.moe.d_ff_expert
        return full - moe_all + moe_active


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assigned grid."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else cfg.n_kv_heads,
        d_ff=128,
        vocab=256,
        d_head=16,
        frontend_len=8 if cfg.frontend else 0,
    )
    if cfg.moe is not None:
        small["moe"] = replace(cfg.moe, n_experts=4, top_k=2, d_ff_expert=64, group_size=16)
    if cfg.ssm is not None:
        small["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=16)
    if cfg.hybrid_attn_every:
        small["hybrid_attn_every"] = 2
        small["hybrid_lora_rank"] = 4
    if cfg.enc_dec:
        small["n_enc_layers"] = 2
    if cfg.swa_window:
        small["swa_window"] = 16
    small["dtype"] = "float32"
    small.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **small)
