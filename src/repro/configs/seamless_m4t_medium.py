"""seamless-m4t-medium [audio] — encoder-decoder backbone; audio frontend is
a stub (precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    enc_dec=True, n_enc_layers=12,
    frontend="frames", frontend_len=1024,
)
