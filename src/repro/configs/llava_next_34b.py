"""llava-next-34b [vlm] — LM backbone only; anyres patch embeddings are a
stub input from input_specs(). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, d_head=128, rope_theta=5_000_000.0,
    frontend="patches", frontend_len=2880,  # anyres: 5 tiles x 576 patches
)
