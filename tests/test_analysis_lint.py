"""repro.analysis.lint: rule fixtures (true positives AND the tricky
false positives each rule must tolerate), the ratcheting baseline, and
the acceptance check that the shipped tree is clean."""
import json
import textwrap

from repro.analysis.baseline import load_baseline
from repro.analysis.lint import build_parser, lint_paths, main

REPO_ROOT = __import__("pathlib").Path(__file__).resolve().parents[1]


def mini_repo(tmp_path, files):
    """Materialize a fixture tree: {relpath: source} -> root dir."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


def rules_hit(tmp_path, files):
    return sorted({f.rule for f in lint_paths(mini_repo(tmp_path, files))})


# ---------------------------------------------------------------------------
# RPR001: non-atomic JSON writes
# ---------------------------------------------------------------------------

def test_rpr001_flags_inplace_json_writes(tmp_path):
    findings = lint_paths(mini_repo(tmp_path, {
        "src/repro/launch/report.py": """
            import json
            from pathlib import Path

            def save(path, payload):
                Path(path).write_text(json.dumps(payload))

            def save2(payload):
                with open("artifacts/report.json", "w") as f:
                    json.dump(payload, f)
        """}))
    assert [f.rule for f in findings] == ["RPR001", "RPR001", "RPR001"]
    assert findings[0].line == 6  # write_text(json.dumps(...))


def test_rpr001_tolerates_tmp_rename_idiom_and_non_json(tmp_path):
    """The write_json_atomic implementation itself (tmp write + replace)
    and non-JSON writes must not fire."""
    assert rules_hit(tmp_path, {
        "src/repro/launch/ioutil.py": """
            import json
            import os
            from pathlib import Path

            def write_json_atomic(path, payload):
                path = Path(path)
                tmp = path.with_suffix(".json.tmp")
                tmp.write_text(json.dumps(payload, indent=1, default=str))
                tmp.replace(path)
                return path

            def write_marker(path):
                Path(path).write_text("armed")  # not JSON: fine in place
        """}) == []


def test_rpr001_scope_excludes_core(tmp_path):
    """The same in-place write outside launch/ (and not the checkpoint
    manifest) is out of scope for RPR001."""
    assert rules_hit(tmp_path, {
        "src/repro/core/report.py": """
            import json
            from pathlib import Path

            def save(path, payload):
                Path(path).write_text(json.dumps(payload))
        """}) == []


# ---------------------------------------------------------------------------
# RPR002: unseeded module-level RNG
# ---------------------------------------------------------------------------

def test_rpr002_flags_module_level_rng(tmp_path):
    findings = lint_paths(mini_repo(tmp_path, {
        "src/repro/search/strategy.py": """
            import random
            import numpy as np
            from random import choice

            def propose():
                x = random.random()
                y = np.random.uniform()
                g = np.random.default_rng()
                return x + y
        """}))
    assert [f.rule for f in findings] == ["RPR002"] * 4


def test_rpr002_tolerates_seeded_instances(tmp_path):
    assert rules_hit(tmp_path, {
        "src/repro/search/strategy.py": """
            import random
            import numpy as np
            from random import Random

            def propose(seed):
                rng = random.Random(seed)
                g = np.random.default_rng(seed)
                return rng.random() + g.uniform()
        """,
        # module-level RNG OUTSIDE the determinism scope is allowed
        "src/repro/launch/jitter.py": """
            import random

            def backoff():
                return random.random()
        """}) == []


# ---------------------------------------------------------------------------
# RPR003: wall-clock reads in declared-pure functions
# ---------------------------------------------------------------------------

def test_rpr003_flags_clock_in_registered_function_only(tmp_path):
    findings = lint_paths(mini_repo(tmp_path, {
        "src/repro/launch/orchestrator.py": """
            import time

            def plan_steals(counts, now):
                deadline = time.time() + 5  # BAD: registry says pure
                return deadline

            def heartbeat_loop():
                return time.time()  # fine: not in the purity registry
        """}))
    assert [f.rule for f in findings] == ["RPR003"]
    assert "plan_steals" in findings[0].message


# ---------------------------------------------------------------------------
# RPR004: jax leaking into jax-free scope (direct + transitive)
# ---------------------------------------------------------------------------

def test_rpr004_flags_direct_and_transitive_jax(tmp_path):
    findings = lint_paths(mini_repo(tmp_path, {
        "benchmarks/bench.py": """
            import jax

            def run():
                return jax.devices()
        """,
        "src/repro/train/ckpt.py": """
            import jax
        """,
        "src/repro/launch/orchestrator.py": """
            from repro.train import ckpt
        """}))
    assert [f.rule for f in findings] == ["RPR004", "RPR004"]
    transitive = [f for f in findings
                  if f.rel == "src/repro/launch/orchestrator.py"]
    assert len(transitive) == 1
    assert "repro.train.ckpt -> jax" in transitive[0].message


def test_rpr004_tolerates_lazy_and_type_checking_imports(tmp_path):
    assert rules_hit(tmp_path, {
        "src/repro/train/ckpt.py": """
            import jax
        """,
        "src/repro/launch/executors.py": """
            from typing import TYPE_CHECKING

            if TYPE_CHECKING:
                import jax  # annotation-only: never executed

            def launch():
                from repro.train import ckpt  # lazy: the sanctioned form
                return ckpt
        """}) == []


# ---------------------------------------------------------------------------
# RPR005: O_CREAT-capable writes in the queue
# ---------------------------------------------------------------------------

def test_rpr005_flags_creating_writes_in_scheduler(tmp_path):
    findings = lint_paths(mini_repo(tmp_path, {
        "src/repro/launch/scheduler.py": """
            import os

            class CellQueue:
                def rewrite(self, path, text):
                    path.write_text(text)  # BAD: creates if missing

                def claim(self, path):
                    fd = os.open(path, os.O_WRONLY | os.O_CREAT)  # BAD
                    os.close(fd)
        """}))
    assert [f.rule for f in findings] == ["RPR005", "RPR005"]


def test_rpr005_tolerates_tmp_paths_and_primitive_layer(tmp_path):
    assert rules_hit(tmp_path, {
        "src/repro/launch/scheduler.py": """
            import os
            from pathlib import Path

            class LocalFS:
                def write_text(self, path, text):
                    Path(path).write_text(text)  # the primitive layer

                def rewrite_nocreate(self, path, text):
                    fd = os.open(path, os.O_WRONLY)  # no O_CREAT: legal
                    os.close(fd)

            class CellQueue:
                def _write(self, fs, path, ticket):
                    tmp = path.with_name(path.name + ".tmp")
                    fs.write_text(tmp, ticket)  # tmp + replace idiom
                    fs.replace(tmp, path)
        """}) == []


# ---------------------------------------------------------------------------
# RPR006: swallowed broad exceptions
# ---------------------------------------------------------------------------

def test_rpr006_flags_silent_broad_catch_only(tmp_path):
    findings = lint_paths(mini_repo(tmp_path, {
        "src/repro/launch/heal.py": """
            def kill(pid):
                try:
                    raise OSError(pid)
                except Exception:
                    pass  # BAD: the supervisor never learns

            def kill2(pid):
                try:
                    raise OSError(pid)
                except (ProcessLookupError, OSError):
                    pass  # narrow, deliberate race tolerance: fine

            def kill3(pid):
                try:
                    raise OSError(pid)
                except Exception as e:
                    print(f"heal: {e}")  # broad but surfaced: fine
        """}))
    assert [f.rule for f in findings] == ["RPR006"]
    assert findings[0].snippet == "except Exception:"


# ---------------------------------------------------------------------------
# fingerprints + the ratcheting baseline
# ---------------------------------------------------------------------------

BAD_SEARCH = """
    import random

    def propose():
        return random.random()
"""


def test_fingerprint_survives_line_drift(tmp_path):
    root = mini_repo(tmp_path, {"src/repro/search/s.py": BAD_SEARCH})
    fp1 = lint_paths(root)[0].fingerprint
    src = (root / "src/repro/search/s.py").read_text()
    (root / "src/repro/search/s.py").write_text("# new header\n\n" + src)
    drifted = lint_paths(root)
    assert len(drifted) == 1
    assert drifted[0].fingerprint == fp1  # same debt, new line number
    assert drifted[0].line != 5 or True


def test_baseline_grow_fails_shrink_tightens(tmp_path, capsys):
    root = mini_repo(tmp_path, {"src/repro/search/s.py": BAD_SEARCH})
    argv = ["--root", str(root), "--baseline", "bl.json"]

    # a violation with no accepted debt fails
    assert main(argv) == 1
    # bootstrap accepts the current debt...
    assert main(argv + ["--write-baseline"]) == 0
    assert len(load_baseline(root / "bl.json")) == 1
    # ...and the gated run is now green
    assert main(argv) == 0

    # GROW: a second violation is new debt -> fail
    (root / "src/repro/search/s2.py").write_text(
        "import random\n\ndef f():\n    return random.choice([1])\n")
    assert main(argv) == 1
    (root / "src/repro/search/s2.py").unlink()

    # SHRINK: fixing the original violation auto-tightens the baseline
    (root / "src/repro/search/s.py").write_text(
        "import random\n\ndef propose(seed):\n"
        "    return random.Random(seed).random()\n")
    capsys.readouterr()
    assert main(argv) == 0
    assert "ratchet tightened" in capsys.readouterr().out
    assert load_baseline(root / "bl.json") == {}

    # the ratchet is one-way: the fixed debt cannot silently return
    (root / "src/repro/search/s.py").write_text(textwrap.dedent(BAD_SEARCH))
    assert main(argv) == 1


def test_rules_filter_and_unknown_rule(tmp_path):
    root = mini_repo(tmp_path, {
        "src/repro/search/s.py": BAD_SEARCH,
        "src/repro/launch/r.py": """
            import json
            from pathlib import Path

            def save(p, d):
                Path(p).write_text(json.dumps(d))
        """})
    assert main(["--root", str(root), "--rules", "RPR002"]) == 1
    assert {f.rule for f in lint_paths(root)} == {"RPR001", "RPR002"}
    assert main(["--root", str(root), "--rules", "RPR999"]) == 2


def test_unparseable_source_is_exit_2(tmp_path):
    root = mini_repo(tmp_path, {"src/repro/search/s.py": "def f(:\n"})
    assert main(["--root", str(root)]) == 2


# ---------------------------------------------------------------------------
# acceptance: the shipped tree is clean and the shipped baseline is empty
# ---------------------------------------------------------------------------

def test_shipped_tree_is_clean():
    findings = lint_paths(REPO_ROOT)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_shipped_baseline_is_empty():
    data = json.loads((REPO_ROOT / "analysis_baseline.json").read_text())
    assert data["findings"] == []


def test_parser_matches_documented_flags():
    opts = {a for action in build_parser()._actions
            for a in action.option_strings}
    assert {"--baseline", "--write-baseline", "--root", "--rules"} <= opts
