"""Orchestrator supervision: CLI surface, quickstart drift guard, and the
kill/restart/merge fault-handling contract (supervisor restarts a killed
shard, no cell is evaluated twice, the healed merged leaderboard is
byte-identical to an uninterrupted run AND to the manual shard+merge flow)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch import orchestrator as orch
from repro.launch.campaign import parse_shard, read_progress, write_progress

REPO = Path(__file__).resolve().parents[1]
TINY_PRELUDE_FILE = REPO / "tests" / "ci" / "tiny_prelude.py"
SLOW_PRELUDE_FILE = REPO / "tests" / "ci" / "slow_cell_prelude.py"


# ---------------------------------------------------------------------------
# CLI surface + spec parsing (no jax, no subprocesses)
# ---------------------------------------------------------------------------
def test_build_parser_flags_and_defaults():
    ns = orch.build_parser().parse_args(
        ["--archs", "all", "--shapes", "all", "--shards", "2",
         "--out", "artifacts/run"])
    assert ns.shards == 2 and ns.strategy == "ensemble"
    # iteration-granularity heartbeats let the default timeout sit well
    # below one cell: it only has to exceed the slowest single batch
    assert ns.max_restarts == 2 and ns.hang_timeout == 300.0
    assert ns.executor == "local" and ns.hosts is None
    ns2 = orch.build_parser().parse_args(
        ["--executor", "ssh", "--hosts", "h0,h1",
         "--remote-root", "/scratch/run"])
    assert ns2.executor == "ssh" and ns2.hosts == "h0,h1"
    assert orch.build_parser().parse_args(
        ["--executor", "loopback"]).executor == "loopback"
    with pytest.raises(SystemExit):
        orch.build_parser().parse_args(["--strategy", "nope"])
    with pytest.raises(SystemExit):
        orch.build_parser().parse_args(["--mesh", "huge"])
    with pytest.raises(SystemExit):
        orch.build_parser().parse_args(["--executor", "k8s"])


def test_parse_inject_kill_and_shard_specs():
    assert orch.parse_inject_kill(None) is None
    assert orch.parse_inject_kill("0:1") == (0, 1)
    assert orch.parse_inject_kill("3:7") == (3, 7)
    for bad in ("1", "a:b", "0:0", "-1:2"):
        with pytest.raises(ValueError):
            orch.parse_inject_kill(bad)
    assert parse_shard(None) is None
    assert parse_shard("1/4") == (1, 4)
    for bad in ("x/y", "4/4", "1-4"):
        with pytest.raises(ValueError):
            parse_shard(bad)


def test_build_shard_cmd_replays_campaign_flags(tmp_path):
    cmd = orch.build_shard_cmd(
        1, 3, tmp_path / "s1", archs="all", shapes="train_4k", mesh="tiny",
        iterations=2, budget=3, workers=1, strategy="ensemble+transfer",
        gate_factor=2.5, llm="mock")
    assert cmd[:3] == [sys.executable, "-m", "repro.launch.campaign"]
    assert cmd[cmd.index("--shard") + 1] == "1/3"
    assert cmd[cmd.index("--strategy") + 1] == "ensemble+transfer"
    assert cmd[cmd.index("--gate-factor") + 1] == "2.5"
    # the command must parse against the campaign CLI it replays
    from repro.launch.campaign import build_parser

    build_parser().parse_args(cmd[3:])


def test_shard_dirs_never_alias_out(tmp_path):
    dirs = orch.shard_dirs_for(tmp_path / "run", 3)
    assert len(dirs) == 3 and len(set(dirs)) == 3
    assert all(d != tmp_path / "run" for d in dirs)
    assert all(d.parent == tmp_path / "run" / "shards" for d in dirs)


def test_run_orchestrator_rejects_bad_specs(tmp_path):
    with pytest.raises(ValueError):
        orch.run_orchestrator(archs="nope-arch", shapes="train_4k", shards=1,
                              out_dir=tmp_path / "x")
    with pytest.raises(ValueError):
        orch.run_orchestrator(archs="qwen3-0.6b", shapes="train_4k", shards=0,
                              out_dir=tmp_path / "x")
    with pytest.raises(ValueError):
        orch.run_orchestrator(archs="qwen3-0.6b", shapes="train_4k", shards=2,
                              out_dir=tmp_path / "x", inject_kill=(5, 1))
    with pytest.raises(ValueError):  # ssh needs hosts
        orch.run_orchestrator(archs="qwen3-0.6b", shapes="train_4k", shards=1,
                              out_dir=tmp_path / "x", executor="ssh")
    with pytest.raises(ValueError):  # the kill token is a local file
        orch.run_orchestrator(archs="qwen3-0.6b", shapes="train_4k", shards=1,
                              out_dir=tmp_path / "x", executor="ssh",
                              hosts=["h0"], inject_kill=(0, 1))
    assert not (tmp_path / "x" / "summary.json").exists()  # failed fast


# ---------------------------------------------------------------------------
# heartbeat file contract
# ---------------------------------------------------------------------------
def test_progress_roundtrip_and_torn_reads(tmp_path):
    assert read_progress(tmp_path) == {}  # missing file = no news
    write_progress(tmp_path, {"cells_done": 2, "ts": 1.0})
    assert read_progress(tmp_path)["cells_done"] == 2
    (tmp_path / "progress.json").write_text('{"cells_done": ')  # torn
    assert read_progress(tmp_path) == {}
    # atomic replace leaves no temp droppings
    write_progress(tmp_path, {"cells_done": 3, "ts": 2.0})
    assert [p.name for p in tmp_path.glob("*.tmp")] == []


def test_aggregate_best_merges_shard_heartbeats(tmp_path):
    a = orch.ShardProc(index=0, out_dir=tmp_path, cmd=[], env={})
    b = orch.ShardProc(index=1, out_dir=tmp_path, cmd=[], env={})
    a.last_payload = {"best": [{"cell": "x/s", "bound_s": 2.0},
                              {"cell": "y/s", "bound_s": None}]}
    b.last_payload = {"best": [{"cell": "z/s", "bound_s": 1.0}]}
    top = orch.aggregate_best([a, b])
    assert [r["cell"] for r in top] == ["z/s", "x/s"]  # fastest first, no Nones


# ---------------------------------------------------------------------------
# quickstart drift guard: the documented commands parse, and the checker
# actually fails on drift (a never-silent canary for the CI smoke job)
# ---------------------------------------------------------------------------
def test_check_quickstart_passes_on_repo_docs():
    r = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_quickstart.py")],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("[ok]") >= 3


def test_check_quickstart_fails_on_drifted_command(tmp_path):
    drifted = tmp_path / "README.md"
    drifted.write_text("```bash\nPYTHONPATH=src python -m "
                       "repro.launch.orchestrator --no-such-flag 1\n"
                       "python -m repro.launch.dse --arch llama3-8b --shape train_4k\n"
                       "python -m repro.launch.merge_db a b --out c\n```\n")
    r = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_quickstart.py"),
         str(drifted)],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert r.returncode == 1
    assert "--no-such-flag" in r.stdout + r.stderr


def test_orchestrator_fails_fast_on_unhealable_shard(tmp_path, monkeypatch):
    """A shard whose every attempt crashes (poisoned prelude) must fail the
    run as soon as its restart budget is spent — terminating the other
    shards — instead of letting them run to completion first."""
    poison = tmp_path / "poison_prelude.py"
    poison.write_text("raise RuntimeError('poisoned prelude')\n")
    monkeypatch.setenv("REPRO_CAMPAIGN_PRELUDE", str(poison))
    t0 = __import__("time").time()
    with pytest.raises(RuntimeError, match="restart"):
        orch.run_orchestrator(archs="qwen3-0.6b", shapes="train_4k",
                              shards=2, out_dir=tmp_path / "run",
                              mesh="tiny", iterations=1, budget=2, workers=1,
                              poll_interval=0.1, max_restarts=1,
                              verbose=False)
    assert __import__("time").time() - t0 < 60  # no waiting out healthy shards
    assert not (tmp_path / "run" / "leaderboard.json").exists()  # no merge
    # crash logs survive for the post-mortem
    assert (tmp_path / "run" / "shards" / "shard0" / "shard.log").exists()


# ---------------------------------------------------------------------------
# the fault-handling contract, end-to-end (real subprocesses, tiny configs)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_orchestrator_heals_killed_shard_and_merges_identically(tmp_path,
                                                                monkeypatch):
    """Kill shard 0 after its first completed cell; the supervisor must
    restart it, the restarted shard must not re-run the finished cell, and
    the merged leaderboard must be byte-identical to (a) an uninterrupted
    orchestrator run and (b) the manual shard+merge_db flow."""
    monkeypatch.setenv("REPRO_CAMPAIGN_PRELUDE", str(TINY_PRELUDE_FILE))
    grid = dict(archs="qwen3-0.6b,stablelm-3b", shapes="train_4k,decode_32k",
                mesh="tiny", iterations=1, budget=2, workers=1,
                poll_interval=0.2, hang_timeout=300.0, verbose=False)

    s_kill = orch.run_orchestrator(shards=2, out_dir=tmp_path / "killed",
                                   inject_kill=(0, 1), **grid)
    assert s_kill["restarts"] == 1, s_kill
    assert s_kill["restarts_per_shard"]["shard0"] == 1

    # the healed shard resumed its finished cell instead of re-running it
    final = read_progress(tmp_path / "killed" / "shards" / "shard0")
    assert final["status"] == "done" and final["cells_done"] == 2
    assert final["resumed"] == 1 and final["ran"] == 1, final
    # counters are run-local: the restarted attempt reports only its own
    # work, while *_total keeps the cumulative view (the first attempt's
    # rows persist in the shard DB) — no more phantom re-done work
    assert 0 < final["evaluations"] < final["evaluations_total"], final
    db_rows = [ln for ln in (tmp_path / "killed" / "shards" / "shard0"
                             / "cost_db.jsonl").read_text().splitlines()
               if ln.strip()]
    assert final["evaluations_total"] == len(db_rows), final
    assert final["compiles_total"] >= final["compiles"] >= 0, final
    # cell boundary fields reset once the shard is done
    assert final["cell_in_progress"] is None and final["iteration"] is None
    # and the one-shot crash token disarmed itself
    assert not (tmp_path / "killed" / "shards" / "shard0"
                / orch.CRASH_TOKEN_FILE).exists()

    s_clean = orch.run_orchestrator(shards=2, out_dir=tmp_path / "clean",
                                    **grid)
    assert s_clean["restarts"] == 0, s_clean

    # manual flow: the two campaign commands + merge_db, same env hooks
    from repro.launch.merge_db import merge

    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "REPRO_CAMPAIGN_PRELUDE": str(TINY_PRELUDE_FILE)}
    for i in range(2):
        cmd = orch.build_shard_cmd(
            i, 2, tmp_path / f"manual{i}", archs=grid["archs"],
            shapes=grid["shapes"], mesh="tiny", iterations=1, budget=2,
            workers=1, strategy="ensemble", gate_factor=None, llm="mock")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    merge([tmp_path / "manual0", tmp_path / "manual1"],
          tmp_path / "manual", verbose=False)

    killed = (tmp_path / "killed" / "leaderboard.json").read_bytes()
    clean = (tmp_path / "clean" / "leaderboard.json").read_bytes()
    manual = (tmp_path / "manual" / "leaderboard.json").read_bytes()
    assert killed == clean == manual, (killed[:300], clean[:300], manual[:300])
    rows = json.loads(killed)
    assert len(rows) == 4 and all(r["status"] == "complete" for r in rows)
    # every cell appears exactly once (no double evaluation survived merge)
    cells = [(r["arch"], r["shape"]) for r in rows]
    assert len(cells) == len(set(cells))

    # summary written and internally consistent
    summary = json.loads((tmp_path / "killed" / "summary.json").read_text())
    assert summary["restarts"] == 1 and summary["shards"] == 2
    assert summary["executor"] == "local"


# ---------------------------------------------------------------------------
# the hang-heal false-kill regression (the bug this PR fixes): a healthy
# cell slower than --hang-timeout must NOT be killed, because the campaign
# now heartbeats every iteration/batch, not just at cell boundaries
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_slow_cell_is_not_false_killed(tmp_path, monkeypatch):
    """One cell whose wall time exceeds --hang-timeout (every evaluation
    sleeps, via the slow-cell prelude) must finish with restarts == 0 —
    with cell-boundary heartbeats the supervisor would SIGKILL the healthy
    shard on a loop until --max-restarts exhausted — and the merged
    leaderboard must match an unsupervised campaign of the same cell."""
    monkeypatch.setenv("REPRO_CAMPAIGN_PRELUDE", str(SLOW_PRELUDE_FILE))
    monkeypatch.setenv("REPRO_TEST_EVAL_SLEEP_S", "12")
    hang_timeout = 40.0  # >> one step (sleep 12 + one tiny compile, or the
    #                      jax import before the first beat),
    #                      << one cell (baseline + 3 iterations of sleeps)
    s = orch.run_orchestrator(
        archs="qwen3-0.6b", shapes="train_4k", shards=1,
        out_dir=tmp_path / "run", mesh="tiny", iterations=3, budget=1,
        workers=1, poll_interval=0.2, hang_timeout=hang_timeout,
        max_restarts=0,  # any spurious kill fails the run loudly
        verbose=False)
    assert s["restarts"] == 0, s
    report = json.loads(next((tmp_path / "run" / "shards" / "shard0"
                              / "reports").glob("*.json")).read_text())
    # the scenario is real: the cell outlived the hang timeout
    assert report["wall_s"] > hang_timeout, report

    # and healing semantics stayed byte-stable: same leaderboard as the
    # manual (unsupervised) campaign over the same cell, sleeps off
    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "REPRO_CAMPAIGN_PRELUDE": str(TINY_PRELUDE_FILE)}
    env.pop("REPRO_TEST_EVAL_SLEEP_S", None)
    cmd = orch.build_shard_cmd(
        0, 1, tmp_path / "manual0", archs="qwen3-0.6b", shapes="train_4k",
        mesh="tiny", iterations=3, budget=1, workers=1, strategy="ensemble",
        gate_factor=None, llm="mock")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    from repro.launch.merge_db import merge

    merge([tmp_path / "manual0"], tmp_path / "manual", verbose=False)
    assert ((tmp_path / "run" / "leaderboard.json").read_bytes()
            == (tmp_path / "manual" / "leaderboard.json").read_bytes())

    # mid-cell heartbeats carried the new payload fields (the last written
    # heartbeat is the final "done" one, so check the contract keys exist)
    final = read_progress(tmp_path / "run" / "shards" / "shard0")
    for key in ("cell_in_progress", "iteration", "evaluations",
                "evaluations_total", "compiles", "compiles_total"):
        assert key in final, final


# ---------------------------------------------------------------------------
# executor seam: the ssh code path (loopback transport) must reproduce the
# local executor's merged leaderboard byte-for-byte
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_loopback_executor_merges_identically(tmp_path, monkeypatch):
    """Run the same 2-shard campaign through the LoopbackExecutor (ssh
    command templating + remote-dir heartbeats + collect-before-merge, all
    on this machine) and through the manual shard+merge flow: identical
    leaderboard bytes, shard dirs collected local, zero restarts."""
    monkeypatch.setenv("REPRO_CAMPAIGN_PRELUDE", str(TINY_PRELUDE_FILE))
    grid = dict(archs="qwen3-0.6b,stablelm-3b", shapes="train_4k",
                mesh="tiny", iterations=1, budget=2, workers=1)

    s = orch.run_orchestrator(
        shards=2, out_dir=tmp_path / "loop", poll_interval=0.2,
        executor="loopback", remote_root=str(tmp_path / "remote"),
        verbose=False, **grid)
    assert s["restarts"] == 0 and s["executor"] == "loopback", s
    # shards ran in the "remote" root and were collected into OUT/shards
    assert (tmp_path / "remote" / "shard0" / "progress.json").exists()
    for i in range(2):
        sd = tmp_path / "loop" / "shards" / f"shard{i}"
        assert (sd / "cost_db.jsonl").exists() and (sd / "reports").is_dir()

    env = {**os.environ, "PYTHONPATH": str(REPO / "src"),
           "REPRO_CAMPAIGN_PRELUDE": str(TINY_PRELUDE_FILE)}
    for i in range(2):
        cmd = orch.build_shard_cmd(
            i, 2, tmp_path / f"manual{i}", archs=grid["archs"],
            shapes=grid["shapes"], mesh="tiny", iterations=1, budget=2,
            workers=1, strategy="ensemble", gate_factor=None, llm="mock")
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    from repro.launch.merge_db import merge

    merge([tmp_path / "manual0", tmp_path / "manual1"],
          tmp_path / "manual", verbose=False)
    assert ((tmp_path / "loop" / "leaderboard.json").read_bytes()
            == (tmp_path / "manual" / "leaderboard.json").read_bytes())
