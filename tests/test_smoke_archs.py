"""Per-architecture smoke tests: reduced config, one forward + one train step
on CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import model as M
from repro.sharding.plan import ShardingPlan
from repro.train import step as step_mod
from repro.train.optimizer import AdamWConfig


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.full((b, s), 3, jnp.int32),
             "targets": jnp.ones((b, s), jnp.int32)}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = 0.1 * jnp.ones((b, cfg.frontend_len, 1024), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_and_train_step(name):
    cfg = reduced(get_config(name))
    plan = ShardingPlan(rules={}, remat="none", zero1=False)
    key = jax.random.key(0)
    state, _ = step_mod.init_train_state(cfg, key, plan)
    step = jax.jit(step_mod.make_train_step(
        cfg, plan, None, AdamWConfig(warmup_steps=1, total_steps=10)))
    batch = _batch(cfg)

    loss0, _ = M.loss_fn(cfg, state["params"], batch)
    assert np.isfinite(float(loss0)), name

    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed and kept shapes
    p0 = jax.tree.leaves(state["params"])
    p1 = jax.tree.leaves(new_state["params"])
    assert all(a.shape == b.shape for a, b in zip(p0, p1))
    assert any(not np.allclose(a, b) for a, b in zip(p0, p1))
    assert int(new_state["opt"]["step"]) == 1


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_shapes(name):
    cfg = reduced(get_config(name))
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    batch.pop("targets")
    cache = M.init_cache(cfg, b, 64)
    logits, cache = M.prefill_fn(cfg, params, batch, cache)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = M.decode_fn(cfg, params, {"tokens": nxt}, cache)
    assert logits2.shape == (b, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
