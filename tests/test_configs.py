"""Assigned architecture configs: exact values + parameter-count sanity."""
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, reduced

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
    "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
}

# published sizes (billions), tolerance band
PARAM_BANDS = {
    "llama3-8b": (7.5, 8.5), "qwen3-8b": (7.5, 8.8), "qwen3-0.6b": (0.5, 0.8),
    "stablelm-3b": (2.5, 3.1), "zamba2-2.7b": (2.2, 3.0),
    "qwen3-moe-235b-a22b": (225, 245), "mixtral-8x7b": (44, 49),
    "mamba2-780m": (0.7, 0.85), "llava-next-34b": (30, 37),
    "seamless-m4t-medium": (0.8, 1.3),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_exact_config(name):
    c = get_config(name)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
        == EXPECTED[name]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_counts(name):
    c = get_config(name)
    lo, hi = PARAM_BANDS[name]
    n = c.n_params() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.2f}B outside [{lo},{hi}]"


def test_active_params_moe():
    c = get_config("qwen3-moe-235b-a22b")
    assert 20 <= c.n_active_params() / 1e9 <= 24  # "a22b"
    m = get_config("mixtral-8x7b")
    assert 11 <= m.n_active_params() / 1e9 <= 14


def test_shapes_grid():
    names = [s.name for s in SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert SHAPES[0].seq_len == 4096 and SHAPES[0].global_batch == 256
    assert SHAPES[3].seq_len == 524288 and SHAPES[3].global_batch == 1


def test_long_ctx_applicability():
    assert not get_config("llama3-8b").sub_quadratic()
    assert get_config("mixtral-8x7b").sub_quadratic()  # SWA
    assert get_config("mamba2-780m").sub_quadratic()
    assert get_config("zamba2-2.7b").sub_quadratic()
    assert not get_config("llava-next-34b").sub_quadratic()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_is_small(name):
    r = reduced(get_config(name))
    assert r.n_params() < 5e6
    assert r.family == get_config(name).family
