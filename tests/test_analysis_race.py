"""repro.analysis.race: MemFS POSIX semantics, deterministic replay,
exhaustive passes over the real CellQueue scenarios, and the broken
check-then-act variant producing a minimized counterexample."""
import pytest

from repro.analysis.race import (NOW, BrokenCellQueue, Built, MemFS,
                                 SCENARIOS, explore, main, minimize,
                                 one_state_per_ticket, run_once,
                                 ticket_locations)
from repro.launch.scheduler import CellQueue


# ---------------------------------------------------------------------------
# MemFS: the POSIX behaviors the protocol relies on
# ---------------------------------------------------------------------------

def test_memfs_rename_is_win_or_enoent():
    fs = MemFS()
    fs.mkdirs("Q/pending")
    fs.write_text("Q/pending/x.json", "{}")
    fs.rename("Q/pending/x.json", "Q/leased/x.json.lease-a")
    with pytest.raises(FileNotFoundError):
        fs.rename("Q/pending/x.json", "Q/leased/x.json.lease-b")
    assert fs.read_text("Q/leased/x.json.lease-a") == "{}"


def test_memfs_rename_preserves_mtime_write_refreshes():
    fs = MemFS(clock=50.0)
    fs.write_text("Q/a", "1")
    m1 = fs.mtime("Q/a")
    fs.rename("Q/a", "Q/b")
    assert fs.mtime("Q/b") == m1
    fs.rewrite_nocreate("Q/b", "2")
    assert fs.mtime("Q/b") > m1


def test_memfs_link_is_exclusive_create():
    fs = MemFS()
    fs.write_text("Q/t.tmp1", "{}")
    fs.link("Q/t.tmp1", "Q/t")
    with pytest.raises(FileExistsError):
        fs.link("Q/t.tmp1", "Q/t")
    fs.unlink("Q/t.tmp1")
    assert fs.read_text("Q/t") == "{}"
    with pytest.raises(FileNotFoundError):
        fs.unlink("Q/t.tmp1")
    fs.unlink("Q/t.tmp1", missing_ok=True)


def test_memfs_rmdir_refuses_nonempty():
    fs = MemFS()
    fs.mkdir_exclusive("Q")
    with pytest.raises(FileExistsError):
        fs.mkdir_exclusive("Q")
    fs.write_text("Q/x", "1")
    with pytest.raises(OSError):
        fs.rmdir("Q")
    fs.unlink("Q/x")
    fs.rmdir("Q")
    fs.mkdir_exclusive("Q")  # lock is reacquirable once released


def test_memfs_rewrite_nocreate_cannot_resurrect():
    fs = MemFS()
    assert fs.rewrite_nocreate("Q/gone", "text") is False
    assert "Q/gone" not in fs.files


def test_memfs_glob_is_sorted_and_nonrecursive():
    fs = MemFS()
    for name in ("Q/leased/b.json.lease-x", "Q/leased/a.json.lease-y",
                 "Q/leased/deep/c.json", "Q/leased/a.json.tmp1"):
        fs.write_text(name, "{}")
    got = [p.name for p in fs.glob("Q/leased", "*.json*")]
    assert got == ["a.json.lease-y", "a.json.tmp1", "b.json.lease-x"]


def test_cellqueue_runs_unchanged_on_memfs():
    """The real queue, ungated, behaves identically over MemFS — the
    seam substitution itself changes nothing."""
    fs = MemFS(clock=NOW)
    q = CellQueue("Q", lease_s=100.0, fs=fs)
    assert q.seed([("a", "s"), ("b", "s")]) == 2
    assert q.seed([("a", "s")]) == 0  # idempotent
    t = q.acquire("w1", now=NOW)
    assert t is not None and t.owner == "w1" and t.attempt == 1
    assert q.counts() == {"pending": 1, "leased": 1, "done": 0}
    assert q.complete(t, now=NOW) is True
    assert q.counts() == {"pending": 1, "leased": 0, "done": 1}
    assert one_state_per_ticket(fs) is None


# ---------------------------------------------------------------------------
# the explorer: determinism, exhaustive passes, violation plumbing
# ---------------------------------------------------------------------------

def test_replay_is_deterministic():
    build = SCENARIOS["two_acquirers"]
    r1 = run_once(build, ["bob", "alice", "bob"])
    r2 = run_once(build, ["bob", "alice", "bob"])
    assert r1.trace == r2.trace
    assert r1.violation is None


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_real_queue_scenarios_pass_exhaustively(name):
    res = explore(SCENARIOS[name], scenario=name)
    assert res.ok, res.counterexample.violation
    assert res.schedules >= 2  # contention actually branched
    assert res.schedules < 5000  # exhaustive, not budget-capped
    assert res.max_decisions < 24  # within the default branching horizon


def test_conservation_check_catches_a_lost_ticket():
    """A (synthetic) op that unlinks a pending ticket trips the
    ticket-conservation end check."""
    def build():
        fs = MemFS(clock=NOW)
        q = CellQueue("Q", lease_s=100.0, fs=fs)
        q.seed([("a", "s")])
        ops = [("eater", lambda: fs.unlink("Q/pending/a__s.json"))]
        return Built(fs=fs, ops=ops, final_check=lambda results: None,
                     initial_tickets=set(ticket_locations(fs)))

    res = run_once(build, [])
    assert res.violation is not None
    assert "conservation" in res.violation


def test_escaping_exception_is_a_violation():
    def build():
        fs = MemFS(clock=NOW)
        CellQueue("Q", lease_s=100.0, fs=fs)

        def boom():
            fs.read_text("Q/pending/never.json")  # FileNotFoundError

        return Built(fs=fs, ops=[("boom", boom)],
                     final_check=lambda results: None,
                     initial_tickets=set())

    res = run_once(build, [])
    assert res.violation is not None
    assert "FileNotFoundError" in res.violation


# ---------------------------------------------------------------------------
# the broken variant: the explorer must catch it and shrink the schedule
# ---------------------------------------------------------------------------

def broken_two_acquirers():
    return SCENARIOS["two_acquirers"](queue_cls=BrokenCellQueue)


def test_broken_queue_produces_counterexample():
    res = explore(broken_two_acquirers, scenario="broken")
    assert not res.ok
    assert "one-state-per-ticket" in res.counterexample.violation
    # the forked ticket is visible in both locations in the message
    assert "pending/" in res.counterexample.violation
    assert "leased/" in res.counterexample.violation


def test_counterexample_minimization():
    res = explore(broken_two_acquirers, scenario="broken")
    mini = minimize(broken_two_acquirers, res.counterexample.choices)
    assert mini.violation is not None
    assert len(mini.trace) <= len(res.counterexample.trace)
    rendered = mini.render_schedule()
    # the schedule reads step by step and ends at the resurrecting write
    assert "step  1:" in rendered
    assert "write Q/leased/" in rendered


def test_real_queue_same_schedule_is_clean():
    """The schedule that breaks BrokenCellQueue is harmless against the
    real protocol — the bug is in the queue variant, not the harness."""
    res = explore(broken_two_acquirers, scenario="broken")
    replay = run_once(SCENARIOS["two_acquirers"],
                      res.counterexample.choices)
    assert replay.violation is None


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_all_scenarios_pass(capsys):
    assert main([]) == 0
    out = capsys.readouterr().out
    assert out.count("OK") == len(SCENARIOS)
    assert "exhaustively" in out


def test_cli_broken_self_test(capsys):
    assert main(["--broken"]) == 0
    out = capsys.readouterr().out
    assert "VIOLATION" in out
    assert "minimal counterexample schedule" in out
    assert "self-test passed" in out


def test_cli_list(capsys):
    assert main(["--list"]) == 0
    out = capsys.readouterr().out
    for name in SCENARIOS:
        assert name in out
