"""Pipeline parallelism: GPipe schedule == sequential layer application."""
import pytest

from conftest import run_subprocess


@pytest.mark.slow
def test_pipeline_matches_sequential():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.sharding.pipeline import pipeline_forward

        mesh = make_mesh((4, 2), ("stage", "model"))
        L, d, mb, n_micro = 8, 16, 4, 6
        key = jax.random.key(0)
        W = 0.3 * jax.random.normal(key, (L, d, d))
        b = 0.1 * jax.random.normal(jax.random.key(1), (L, d))
        params = {"w": W, "b": b}
        x = jax.random.normal(jax.random.key(2), (n_micro, mb, d))

        def layer_fn(lp, x):
            return jnp.tanh(x @ lp["w"] + lp["b"])

        with mesh:
            got = pipeline_forward(layer_fn, params, x, mesh)

        # sequential reference
        def seq(x):
            def body(c, i):
                return jnp.tanh(c @ W[i] + b[i]), None
            y, _ = jax.lax.scan(body, x, jnp.arange(L))
            return y
        want = jax.vmap(seq)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE_OK")
    """, n_devices=8)
    assert "PIPELINE_OK" in out


@pytest.mark.slow
def test_pipeline_multipod_pod_axis():
    """Pipeline over the 'pod' axis of a (2, 2, 2) multi-pod style mesh."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.sharding.pipeline import pipeline_forward

        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        L, d = 4, 8
        W = 0.3 * jax.random.normal(jax.random.key(0), (L, d, d))
        x = jax.random.normal(jax.random.key(1), (4, 2, d))

        def layer_fn(lp, x):
            return jnp.tanh(x @ lp)

        with mesh:
            got = pipeline_forward(layer_fn, W, x, mesh, stage_axis="pod")
        def seq(x):
            for i in range(L):
                x = jnp.tanh(x @ W[i])
            return x
        want = jax.vmap(seq)(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE_POD_OK")
    """, n_devices=8)
    assert "PIPELINE_POD_OK" in out
