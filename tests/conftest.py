"""Shared test fixtures. IMPORTANT: no XLA_FLAGS here — smoke tests and
benches must see the real single CPU device; multi-device tests spawn
subprocesses (see helpers.run_subprocess)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a python snippet with a forced host device count; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"subprocess failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


@pytest.fixture(scope="session")
def single_mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((1, 1), ("data", "model"))
