"""Kernel cells through the DSE engine: template closure, the pinned
validate() message contract, the correctness gate, exactly-once measurement
under queue re-lease, and shard-order-invariant merges.

Everything runs interpret-mode on CPU over the small KERNEL_SHAPES registry
workloads, so the whole file is tier-1-fast despite executing real Pallas
kernels end to end.
"""
import json
import random

import pytest

from repro.core.kernel_space import (KERNEL_SHAPES, kernel_arch,
                                     parse_kernel_arch)
from repro.launch.kernel_cell import (KERNEL_MESH_NAME, kernel_grid_cells,
                                      resolve_kernel_grid)


# ---------------------------------------------------------------------------
# grid cut (pure, RPR003-registered)
# ---------------------------------------------------------------------------
def test_resolve_kernel_grid_all_and_unknowns():
    kernels, shapes = resolve_kernel_grid("all", "all")
    assert "flash_attention" in kernels and len(shapes) == len(KERNEL_SHAPES)
    # explicit shapes of a selected kernel pass through
    k2, s2 = resolve_kernel_grid("vecmul", "vec_64k_f32")
    assert (k2, s2) == (["vecmul"], ["vec_64k_f32"])
    with pytest.raises(ValueError, match="unknown kernel/shape"):
        resolve_kernel_grid("vecmul,nope", "all")
    with pytest.raises(ValueError, match="unknown kernel/shape"):
        resolve_kernel_grid("vecmul", "not_a_shape")


def test_kernel_grid_cells_sharding_is_disjoint_and_exhaustive():
    kernels, shapes = resolve_kernel_grid("all", "all")
    cells = kernel_grid_cells(kernels, shapes)
    assert cells == sorted(cells) and len(cells) == len(KERNEL_SHAPES)
    # arch encoding survives a round trip
    for arch, _ in cells:
        assert parse_kernel_arch(arch) in kernels
    parts = [kernel_grid_cells(kernels, shapes, (i, 3)) for i in range(3)]
    assert sorted(c for p in parts for c in p) == cells
    assert sum(len(p) for p in parts) == len(cells)
    with pytest.raises(ValueError, match="shard index"):
        kernel_grid_cells(kernels, shapes, (3, 3))
    # shapes pair only with their own kernel, never a cross product
    assert kernel_grid_cells(["vecmul"], ["vec_64k_f32", "rms_512x512_f32"]) \
        == [(kernel_arch("vecmul"), "vec_64k_f32")]


# ---------------------------------------------------------------------------
# template validity closure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kshape", KERNEL_SHAPES, ids=lambda s: s.name)
def test_kernel_template_closure(kshape):
    """Every point a KernelTemplate can emit — baseline, neighbors,
    random samples — passes its own validate()."""
    from repro.core.design_space import KernelTemplate, baseline_kernel_point

    template = KernelTemplate(kshape)
    base = baseline_kernel_point(kshape, template)
    ok, why = template.validate(base)
    assert ok, f"{kshape.name} baseline invalid: {why}"
    neighbors = list(template.neighbors(base))
    assert neighbors, f"{kshape.name} baseline has no legal neighbors"
    rng = random.Random(0)
    for p in neighbors + template.random_points(rng, 16):
        ok, why = template.validate(p)
        assert ok, f"{kshape.name} emitted invalid point {p.dims}: {why}"


def test_kernel_template_repair_snaps_into_validity():
    from repro.core.design_space import KernelPoint, KernelTemplate
    from repro.core.kernel_space import KERNEL_SHAPE_BY_NAME

    template = KernelTemplate(KERNEL_SHAPE_BY_NAME["vec_64k_f32"])
    fixed = template.repair(KernelPoint(dims={"block": 999, "bogus": 1}))
    assert template.validate(fixed)[0]
    assert "bogus" not in fixed.dims


# ---------------------------------------------------------------------------
# the pinned validate() message contract (Plan AND Kernel templates)
# ---------------------------------------------------------------------------
def _plan_template():
    from repro.configs import SHAPE_BY_NAME, get_config
    from repro.core.design_space import PlanTemplate

    return PlanTemplate(get_config("qwen3-0.6b"), SHAPE_BY_NAME["train_4k"],
                        {"data": 2, "model": 4})


def test_plan_validate_messages_are_pinned():
    from repro.core.design_space import PlanPoint, baseline_point

    template = _plan_template()
    base = baseline_point(template.cell, template)
    ok, why = template.validate(PlanPoint(dims={**base.dims, "bogus": 1}))
    assert (ok, why) == (False, "unknown dimension bogus")
    legal = template.dims()
    bad = PlanPoint(dims={**base.dims, "microbatches": -7})
    ok, why = template.validate(bad)
    assert not ok
    assert why == (f"microbatches=-7 outside device-aware range "
                   f"{legal['microbatches']}")
    # the cross-dimension clash message carries the batch_rule context
    mb = max(v for v in legal["microbatches"] if isinstance(v, int))
    clash = PlanPoint(dims={**base.dims, "microbatches": mb,
                            "batch_rule": "data+model"})
    ok, why = template.validate(clash)
    if not ok:  # only asserted when the cell is small enough to clash
        assert why.startswith(f"microbatches={mb} but only ")
        assert why.endswith("rows/device under batch_rule=data+model")


def test_kernel_validate_messages_are_pinned():
    import dataclasses

    from repro.core.design_space import (KernelPoint, KernelTemplate,
                                         baseline_kernel_point)
    from repro.core.device import TPU_V5E
    from repro.core.kernel_space import KERNEL_SHAPE_BY_NAME

    kshape = KERNEL_SHAPE_BY_NAME["vec_64k_f32"]
    template = KernelTemplate(kshape)
    ok, why = template.validate(KernelPoint(dims={"block": 512, "bogus": 1}))
    assert (ok, why) == (False, "unknown dimension bogus")
    legal = template.dims()
    ok, why = template.validate(KernelPoint(dims={"block": 999}))
    assert (ok, why) == (
        False, f"block=999 outside device-aware range {legal['block']}")
    # the VMEM bound message: same pools, starved device
    starved = dataclasses.replace(TPU_V5E, vmem_bytes=64)
    tiny = KernelTemplate(kshape, starved)
    base = baseline_kernel_point(kshape)
    ok, why = tiny.validate(base)
    assert not ok
    from repro.core.kernel_space import kernel_resources

    res = kernel_resources(kshape, base.dims, starved)
    assert why == (f"VMEM {res.vmem_bytes} B double-buffered exceeds "
                   f"{starved.vmem_bytes} B budget")


# ---------------------------------------------------------------------------
# the correctness gate
# ---------------------------------------------------------------------------
def test_correctness_gate_rejects_injected_bad_variant(tmp_path, monkeypatch):
    """A fast-but-wrong tile (REPRO_KERNEL_INJECT_BAD perturbation) becomes
    status="infeasible" with its max error recorded — and can never be the
    cell's best design."""
    from repro.core.cost_db import CostDB
    from repro.core.design_space import KernelPoint
    from repro.core.evaluator import KernelEvaluator
    from repro.kernels.conformance import INJECT_ENV

    monkeypatch.setenv(INJECT_ENV, "vecmul:block=1024")
    arch, shape = kernel_arch("vecmul"), "vec_64k_f32"
    ev = KernelEvaluator(mesh=None, mesh_name=KERNEL_MESH_NAME)
    bad, good = (KernelPoint(dims={"block": 1024}),
                 KernelPoint(dims={"block": 512}))
    dp_bad, dp_good = ev.evaluate_batch(arch, shape, [bad, good])
    assert dp_good.status == "ok" and dp_good.metrics["correct"] is True
    assert dp_bad.status == "infeasible"
    assert str(dp_bad.reason).startswith("correctness gate: max|err| ")
    assert dp_bad.metrics["max_abs_err"] > dp_bad.metrics["tol"]
    # the wrong tile still carries a (fast) analytic bound, yet loses
    assert dp_bad.metrics["bound_s"] is not None
    db = CostDB(tmp_path / "db.jsonl")
    db.append_many([dp_bad, dp_good])
    best = db.best(arch, shape, mesh=KERNEL_MESH_NAME)
    assert best is not None and best.point["block"] == 512


def test_measured_tier_rechecks_correctness(monkeypatch):
    from repro.launch.measure import measure_kernel_cell
    from repro.core.kernel_space import KERNEL_SHAPE_BY_NAME
    from repro.kernels.conformance import INJECT_ENV

    monkeypatch.setenv(INJECT_ENV, "vecmul:block=2048")
    kshape = KERNEL_SHAPE_BY_NAME["vec_64k_f32"]
    rec = measure_kernel_cell(kshape, {"block": 2048}, runs=1)
    assert rec["status"] == "incorrect"
    assert rec["max_abs_err"] > rec["tol"]
    assert measure_kernel_cell(kshape, {"block": 512}, runs=1)["status"] == "ok"


# ---------------------------------------------------------------------------
# exactly-once measurement under queue re-lease
# ---------------------------------------------------------------------------
def test_measurement_exactly_once_under_queue_relase(tmp_path):
    """A re-leased cell (worker crash after measuring, queue hands the cell
    to a second worker with its own fresh DB) replays the recorded timing
    from the shared measured cache: no second timed execution, and the
    replayed row serializes byte-identically to the original."""
    from repro.launch import measure as measure_mod
    from repro.launch.kernel_cell import run_kernel_campaign

    queue = tmp_path / "queue"
    kw = dict(iterations=1, budget=2, strategy="greedy", measure_top_k=1,
              measure_runs=1, queue=queue, verbose=False)
    n0 = measure_mod.N_KERNEL_MEASUREMENTS
    s1 = run_kernel_campaign(["vecmul"], ["vec_64k_f32"],
                             out_dir=tmp_path / "w1", **kw)
    assert s1["measured"] == 1 and s1["measured_replayed"] == 0
    assert measure_mod.N_KERNEL_MEASUREMENTS - n0 == 1

    # re-lease: pretend w1's completion was lost — its done ticket goes
    # back to pending and a second worker (fresh out dir, fresh DB, same
    # queue caches) wins the cell again
    done = list((queue / "done").iterdir())
    assert len(done) == 1
    done[0].rename(queue / "pending" / done[0].name)
    s2 = run_kernel_campaign(["vecmul"], ["vec_64k_f32"],
                             out_dir=tmp_path / "w2", **kw)
    assert s2["ran"] == 1
    assert s2["measured"] == 0 and s2["measured_replayed"] == 1
    assert measure_mod.N_KERNEL_MEASUREMENTS - n0 == 1  # still exactly once

    def measured_lines(d):
        rows = [json.loads(line) for line in
                (d / "cost_db.jsonl").read_text().splitlines()]
        return [json.dumps(r, sort_keys=True) for r in rows
                if r.get("fidelity") == "measured"]

    m1, m2 = measured_lines(tmp_path / "w1"), measured_lines(tmp_path / "w2")
    assert m1 and m1 == m2  # byte-identical replayed measurement rows


# ---------------------------------------------------------------------------
# shard-order-invariant merge
# ---------------------------------------------------------------------------
def test_kernel_shard_merge_is_order_invariant(tmp_path):
    from repro.launch.kernel_cell import run_kernel_campaign
    from repro.launch.merge_db import merge

    kernels, shapes = ["vecmul", "rmsnorm"], ["vec_64k_f32",
                                              "rms_512x512_f32",
                                              "rms_1kx256_bf16"]
    kw = dict(iterations=1, budget=2, strategy="greedy", seed=0,
              verbose=False)
    for i in range(2):
        run_kernel_campaign(kernels, shapes, out_dir=tmp_path / f"s{i}",
                            shard=(i, 2), **kw)
    merge([tmp_path / "s0", tmp_path / "s1"], tmp_path / "ab", verbose=False)
    merge([tmp_path / "s1", tmp_path / "s0"], tmp_path / "ba", verbose=False)
    lb_ab = (tmp_path / "ab" / "leaderboard.json").read_bytes()
    lb_ba = (tmp_path / "ba" / "leaderboard.json").read_bytes()
    assert lb_ab == lb_ba
    rows = json.loads(lb_ab)
    assert {(r["arch"], r["shape"]) for r in rows} == {
        (kernel_arch("vecmul"), "vec_64k_f32"),
        (kernel_arch("rmsnorm"), "rms_512x512_f32"),
        (kernel_arch("rmsnorm"), "rms_1kx256_bf16")}
    assert all(r["mesh"] == KERNEL_MESH_NAME for r in rows)
