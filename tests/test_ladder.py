"""Promotion ladder (surrogate -> dry-run -> measured): tier-2 policy
purity, the effective_factor protocol contract, measured-calibration
feedback, and the tier-1 acceptance contract — measurement is exactly-once
per design under queue re-lease, and merged leaderboards are byte-identical
under any shard order."""
import itertools

import pytest

from conftest import run_subprocess
from repro.core.cost_db import (CostDB, DataPoint, _val_row, featurize)
from repro.core.promotion import plan_promotions, select_measured_row
from test_campaign_engine import TINY_PRELUDE

WL = {"n_params": 6e8, "seq_len": 4096, "global_batch": 8, "n_layers": 28,
      "d_model": 1024, "vocab": 151936, "n_experts": 0,
      "is_train": 1.0, "is_decode": 0.0}


def _head(key, bound, ts=0.0):
    return DataPoint(arch="a", shape="s", mesh="m",
                     point={"__key__": key, "microbatches": 1},
                     status="ok", metrics={"bound_s": bound, "workload": WL},
                     ts=ts)


# ---------------------------------------------------------------------------
# the two pure decision functions (RPR003 registry)
# ---------------------------------------------------------------------------
def test_plan_promotions_policy():
    heads = [_head("k1", 1.0), _head("k2", 2.0), _head("k1", 1.0),
             _head("k3", 3.0), _head("", 4.0)]
    assert plan_promotions(heads, set(), top_k=0) == []
    assert plan_promotions([], set(), top_k=3) == []
    # best-first, duplicates and key-less heads skipped
    got = plan_promotions(heads, set(), top_k=2)
    assert [d.point["__key__"] for d in got] == ["k1", "k2"]
    # already-measured designs never re-promoted (exactly-once bookkeeping)
    got = plan_promotions(heads, {"k1"}, top_k=2)
    assert [d.point["__key__"] for d in got] == ["k2", "k3"]
    # campaign-wide budget caps after top_k selection
    got = plan_promotions(heads, set(), top_k=3, budget_left=1)
    assert [d.point["__key__"] for d in got] == ["k1"]
    assert plan_promotions(heads, set(), top_k=3, budget_left=0) == []


def test_select_measured_row_order_invariant_earliest_wins():
    a = _head("ka", 1.0, ts=5.0)
    b = _head("kb", 1.0, ts=3.0)
    c = _head("ka", 1.0, ts=3.0)  # ts tie with b -> serialized form decides
    expected = min([a, b, c], key=lambda d: (d.ts, d.to_json()))
    for perm in itertools.permutations([a, b, c]):
        assert select_measured_row(list(perm)) is expected
    assert select_measured_row([]) is None
    assert select_measured_row(iter([a])) is a


# ---------------------------------------------------------------------------
# effective_factor is a protocol contract, not duck-typing
# ---------------------------------------------------------------------------
def test_effective_factor_contract_fails_loudly(tmp_path):
    """The evaluator reads ``gate.effective_factor`` directly when recording
    a pruned row — a gate implementation missing the property must raise,
    never silently record a wrong threshold (the old ``getattr`` fallback
    would have)."""
    from repro.core.design_space import PlanTemplate, baseline_point
    from repro.core.evaluator import SHAPE_BY_NAME, Evaluator, get_config
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg, cell = get_config("qwen3-0.6b"), SHAPE_BY_NAME["train_4k"]
    point = baseline_point(cell, PlanTemplate(cfg, cell, dict(mesh.shape)))

    class NoFactorGate:
        def prune_verdicts(self, points, workload, incumbent_bound):
            return [(123.0, 1.0)] * len(points)

    ev = Evaluator(mesh, "tiny1x1", artifact_dir=str(tmp_path))
    with pytest.raises(AttributeError, match="effective_factor"):
        ev.evaluate_batch("qwen3-0.6b", "train_4k", [point],
                          gate=NoFactorGate(), incumbent_bound=1.0)


def test_ladder_inherits_gate_protocol():
    from repro.search import PromotionLadder, SurrogateGate

    ladder = PromotionLadder(None, factor=3.0)
    assert isinstance(ladder, SurrogateGate)
    assert ladder.effective_factor == 3.0 and not ladder.active
    assert ladder.min_measured_points == 3
    # uncalibrated ladder prunes nothing, exactly like the base gate
    assert ladder.prune_verdicts([], {}, 1.0) == []
    assert ladder.calibrate(CostDB.__new__(CostDB)) is False  # untrained cm


# ---------------------------------------------------------------------------
# measured calibration: RMSE monotone in disagreement, offset-invariant,
# and the ladder anneals tighter as wall clocks confirm predictions
# ---------------------------------------------------------------------------
def _non_val_keys(n):
    """Point keys outside the validation split: keeps validation_error at
    (nan, 0) so the ladder's annealing signal is *only* the measured RMSE."""
    keys, i = [], 0
    while len(keys) < n:
        if not _val_row(f"p{i}"):
            keys.append(f"p{i}")
        i += 1
    return keys


def _calibrated_db(tmp_path, label, noise, offset=3.0, n=24, k=8):
    """A synthetic cell: n dry-run rows train the surrogate, then k measured
    rows whose log10 wall clock is the model's own prediction plus a
    constant ``offset`` and alternating +/- ``noise`` decades — so the
    offset-corrected RMSE is ``noise`` by construction."""
    from repro.core.cost_model import CostModel

    db = CostDB(tmp_path / f"db_{label}.jsonl")
    keys = _non_val_keys(n)
    for i, key in enumerate(keys):
        point = {"__key__": key, "microbatches": 2 ** (i % 5),
                 "loss_chunk": 64 * (1 + i % 3), "zero1": bool(i % 2)}
        db.append(DataPoint(arch="a", shape="s", mesh="m", point=point,
                            status="ok",
                            metrics={"bound_s": 1e-4 * (1 + i % 7),
                                     "fits_hbm": True, "workload": WL}))
    cm = CostModel.create(in_dim=featurize({}, {}).shape[0])
    cm.pretrain(db, split=None)
    for i, d in enumerate(db.all()[:k]):
        pred = float(cm.predict(featurize(d.point, WL)[None])[0][0])
        eps = noise if i % 2 else -noise
        db.append(DataPoint(arch="a", shape="s", mesh="m", point=d.point,
                            status="ok", fidelity="measured", source="ladder",
                            metrics={"measured_s": 10 ** (pred + offset + eps),
                                     "workload": WL}))
    return cm, db


def test_measured_calibration_rmse_monotone_and_offset_invariant(tmp_path):
    rmses = []
    for noise in (0.02, 0.10, 0.30):
        cm, db = _calibrated_db(tmp_path, f"n{noise}", noise)
        rmse, n, off = cm.measured_calibration(db)
        assert n == 8
        assert rmse == pytest.approx(noise, rel=1e-3)
        assert off == pytest.approx(3.0, abs=0.05)
        rmses.append(rmse)
    assert rmses == sorted(rmses) and rmses[0] < rmses[-1]

    # a pure scale change (interpret-mode backend vs device) lands entirely
    # in the offset, never in the RMSE
    cm, db5 = _calibrated_db(tmp_path, "off5", 0.10, offset=5.0)
    rmse5, _, off5 = cm.measured_calibration(db5)
    assert rmse5 == pytest.approx(0.10, rel=1e-3)
    assert off5 == pytest.approx(5.0, abs=0.05)

    # untrained model / empty DB degrade to (nan, 0, nan)
    from repro.core.cost_model import CostModel
    fresh = CostModel.create(in_dim=featurize({}, {}).shape[0])
    r, n, o = fresh.measured_calibration(db5)
    assert n == 0 and r != r and o != o


def test_ladder_anneals_tighter_as_measured_agreement_improves(tmp_path):
    from repro.search import PromotionLadder, SurrogateGate

    factors = []
    for noise in (0.02, 0.15, 0.40):
        cm, db = _calibrated_db(tmp_path, f"g{noise}", noise)
        # plain gate: no validation rows (all keys dodge the val split), so
        # its annealing signal is nan and the threshold stays at factor
        gate = SurrogateGate(cm, factor=4.0, min_factor=1.5,
                             require_calibration=False)
        assert gate.calibrate(db) and gate.effective_factor == 4.0
        ladder = PromotionLadder(cm, factor=4.0, min_factor=1.5,
                                 require_calibration=False)
        assert ladder.calibrate(db)
        assert ladder.last_measured_n == 8
        assert ladder.last_measured_rmse == pytest.approx(noise, rel=1e-3)
        factors.append(ladder.effective_factor)
    tight, mid, loose = factors
    # monotone: better wall-clock agreement -> tighter pruning; and the
    # ladder never exceeds the configured factor (noise 0.40 > max_val_rmse
    # clamps to the loose end)
    assert tight < mid < loose <= 4.0
    assert tight == pytest.approx(1.5 + (4.0 - 1.5) * 0.02 / 0.35, rel=1e-6)

    # below min_measured_points the measured signal is ignored entirely
    cm, db = _calibrated_db(tmp_path, "few", 0.02, k=2)
    ladder = PromotionLadder(cm, factor=4.0, min_factor=1.5,
                             require_calibration=False)
    assert ladder.calibrate(db)
    assert ladder.last_measured_n == 2
    assert ladder.effective_factor == 4.0


# ---------------------------------------------------------------------------
# merge identity: a design's dry-run row and measured row both survive;
# duplicate measurements collapse to one canonical row, any shard order
# ---------------------------------------------------------------------------
def test_merge_keeps_measured_and_dryrun_rows_dedupes_duplicates(tmp_path):
    from repro.launch.merge_db import merge_cost_dbs

    dry = _head("k1", 1.0, ts=1.0)
    meas = DataPoint(arch="a", shape="s", mesh="m",
                     point={"__key__": "k1", "microbatches": 1}, status="ok",
                     fidelity="measured", source="ladder",
                     metrics={"measured_s": 0.5, "workload": WL}, ts=2.0)
    a = CostDB(tmp_path / "a.jsonl")
    a.append(dry)
    a.append(meas)
    b = CostDB(tmp_path / "b.jsonl")
    b.append(dry)   # stolen cell: second owner re-recorded both rows
    b.append(meas)  # (byte-identical by the measured cache's replay contract)

    outs = []
    for label, order in (("ab", [a.path, b.path]), ("ba", [b.path, a.path])):
        out = tmp_path / f"m_{label}.jsonl"
        kept, dups = merge_cost_dbs(order, out)
        assert kept == 2 and dups == 2
        outs.append(out.read_bytes())
    assert outs[0] == outs[1]
    merged = CostDB(tmp_path / "m_ab.jsonl").all()
    assert sorted(d.fidelity for d in merged) == ["dryrun", "measured"]


# ---------------------------------------------------------------------------
# tier-1 acceptance: exactly-once measurement under queue re-lease, and
# shard-order-invariant merged leaderboards with measured rows present
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_measured_exactly_once_under_queue_release_and_merge(tmp_path):
    out = run_subprocess(f"""{TINY_PRELUDE}
        import json, shutil
        from pathlib import Path
        import repro.launch.measure as measure
        from repro.launch.campaign import run_campaign
        from repro.launch.merge_db import merge

        grid = dict(archs=["qwen3-0.6b"], shapes=["train_4k", "decode_32k"])
        qdir = Path(r"{tmp_path}/q")
        a = run_campaign(**grid, mesh=mesh, mesh_name="tiny1x1",
                         out_dir=r"{tmp_path}/A", iterations=1, budget=2,
                         workers=1, verbose=False, measure_top_k=1,
                         queue=qdir, queue_owner="w0")
        assert a["measured"] == 2 and a["measured_replayed"] == 0, a
        assert measure.N_MEASUREMENTS == 2

        def measured_lines(p):
            return sorted(l for l in Path(p).read_text().splitlines()
                          if '"fidelity": "measured"' in l)
        rows_a = measured_lines(r"{tmp_path}/A/cost_db.jsonl")
        assert len(rows_a) == 2, rows_a

        # owner w0 "dies" after the work but before anyone trusts it: wipe
        # the queue's done/ state so a second owner re-leases both cells
        # and re-runs them against its own empty out dir
        shutil.rmtree(qdir / "done")
        b = run_campaign(**grid, mesh=mesh, mesh_name="tiny1x1",
                         out_dir=r"{tmp_path}/B", iterations=1, budget=2,
                         workers=1, verbose=False, measure_top_k=1,
                         queue=qdir, queue_owner="w1")
        # the re-leased cells replay their recorded wall clocks from the
        # queue-shared measured_cache — not a single re-timing
        assert measure.N_MEASUREMENTS == 2, measure.N_MEASUREMENTS
        assert b["measured"] == 0 and b["measured_replayed"] == 2, b
        # the replayed rows serialize byte-identically (ts included)
        assert measured_lines(r"{tmp_path}/B/cost_db.jsonl") == rows_a

        # merge in both shard orders: byte-identical leaderboards, one
        # canonical measured row per cell, measured_us populated
        lbs = []
        for label, order in (("AB", ["A", "B"]), ("BA", ["B", "A"])):
            m = merge([Path(r"{tmp_path}") / s for s in order],
                      Path(r"{tmp_path}") / f"m{{label}}", verbose=False,
                      extra_cache_dirs=[qdir / "dryrun_cache",
                                        qdir / "measured_cache"])
            mdb = Path(m["out"]) / "cost_db.jsonl"
            assert measured_lines(mdb) == rows_a
            lbs.append(Path(m["leaderboard"]).read_bytes())
        assert lbs[0] == lbs[1]
        lb = json.loads(lbs[0])
        assert len(lb) == 2
        assert all(r["measured_us"] and r["measured_us"] > 0 for r in lb), lb
        assert all(r["measured_backend"] == "cpu" for r in lb), lb
        print("EXACTLY_ONCE_OK")
    """, n_devices=1, timeout=900)
    assert "EXACTLY_ONCE_OK" in out


@pytest.mark.slow
def test_measure_cell_interpret_mode_min_of_n(tmp_path):
    out = run_subprocess(f"""{TINY_PRELUDE}
        from repro.launch import measure

        try:
            measure.measure_cell("qwen3-0.6b", "train_4k", mesh, "tiny1x1",
                                 runs=0)
            raise AssertionError("runs=0 must be rejected")
        except ValueError:
            pass
        assert measure.N_MEASUREMENTS == 0

        rec = measure.measure_cell("qwen3-0.6b", "train_4k", mesh, "tiny1x1",
                                   runs=3)
        assert rec["status"] == "ok", rec
        assert measure.N_MEASUREMENTS == 1
        assert rec["n"] == 3 and len(rec["times_s"]) == 3
        assert rec["measured_s"] == min(rec["times_s"]) > 0
        assert rec["warm_s"] > 0 and rec["backend"] == "cpu"
        assert rec["fidelity"] == "measured" and rec["measured_at"] > 0
        print("MEASURE_OK")
    """, n_devices=1, timeout=900)
    assert "MEASURE_OK" in out
