"""Shard-executor backends: protocol selection, ssh command templating, and
the loopback (ssh-code-path, local-transport) lifecycle — spawn, heartbeat
fetch, pid-file group kill, exit-code propagation, collect-before-merge."""
import json
import signal
import sys
import time
from pathlib import Path

import pytest

from repro.launch.executors import (EXECUTOR_CHOICES, PID_FILE,
                                    LocalProcessExecutor, LoopbackExecutor,
                                    ShardProc, SSHExecutor, make_executor)

REPO = Path(__file__).resolve().parents[1]


def _shard(tmp_path, index=0, cmd=None, env=None) -> ShardProc:
    return ShardProc(index=index, out_dir=tmp_path / f"shard{index}",
                     cmd=cmd or [sys.executable, "-m",
                                 "repro.launch.campaign", "--out", "X"],
                     env=env or {})


# ---------------------------------------------------------------------------
# selection + configuration (no processes)
# ---------------------------------------------------------------------------
def test_make_executor_selects_and_validates():
    assert isinstance(make_executor("local"), LocalProcessExecutor)
    ex = make_executor("ssh", hosts=["h0", "h1"], remote_python="python3.11")
    assert isinstance(ex, SSHExecutor) and ex.python == "python3.11"
    assert isinstance(make_executor("loopback"), LoopbackExecutor)
    with pytest.raises(ValueError):
        make_executor("ssh")  # hosts required
    with pytest.raises(ValueError):
        make_executor("k8s")
    assert set(EXECUTOR_CHOICES) == {"local", "ssh", "loopback"}


def test_ssh_round_robin_hosts_and_remote_dirs(tmp_path):
    ex = SSHExecutor(hosts=["h0", "h1"], remote_root="/scratch/run")
    shards = [_shard(tmp_path, i) for i in range(4)]
    assert [ex.host_for(s) for s in shards] == ["h0", "h1", "h0", "h1"]
    assert ex.remote_dir(shards[3]) == "/scratch/run/shard3"
    # no remote_root: the shared-FS convention — same absolute path
    ex2 = SSHExecutor(hosts=["h0"])
    assert ex2.remote_dir(shards[0]) == str(shards[0].out_dir.resolve())
    assert ex2.remote_repo == str(REPO)  # defaults to this checkout


def test_ssh_remote_command_templating(tmp_path):
    ex = SSHExecutor(hosts=["h0"], remote_root="/scratch/run",
                     remote_repo="/opt/repro", python="python3.12")
    s = _shard(tmp_path, 1, env={"REPRO_CAMPAIGN_PRELUDE": "/p.py",
                                 "DRYRUN_XLA_FLAGS": "--flag=2",
                                 "SECRET_LOCAL_VAR": "nope"})
    cmd = ex.remote_command(s)
    assert "mkdir -p /scratch/run/shard1" in cmd
    assert f"echo $$ > /scratch/run/shard1/{PID_FILE}" in cmd
    assert "setsid -w bash -c" in cmd
    # argv re-targeted: remote python, remote --out
    assert "python3.12 -m repro.launch.campaign" in cmd
    assert "--out /scratch/run/shard1" in cmd
    # test/CI hooks forwarded, local noise not; PYTHONPATH -> remote src
    assert "REPRO_CAMPAIGN_PRELUDE=/p.py" in cmd
    assert "DRYRUN_XLA_FLAGS=--flag=2" in cmd
    assert "SECRET_LOCAL_VAR" not in cmd
    assert "PYTHONPATH=/opt/repro/src" in cmd
    # transport argv wraps the command for ssh
    argv = ex._transport_argv("h0", cmd)
    assert argv[0] == "ssh" and argv[-1] == cmd and "h0" in argv


def test_loopback_transport_is_local_sh(tmp_path):
    ex = LoopbackExecutor()
    assert ex._transport_argv("ignored", "echo hi")[:2] == ["/bin/sh", "-c"]
    assert ex.python == sys.executable  # this interpreter, not 'python3'


# ---------------------------------------------------------------------------
# loopback lifecycle: the ssh seam with real processes, no jax, no network
# ---------------------------------------------------------------------------
_FAKE_CAMPAIGN = ("import json, sys, time; "
                  "d = sys.argv[sys.argv.index('--out') + 1]; "
                  "json.dump({'cells_done': 1, 'status': 'running', "
                  "'ts': 1.0}, open(d + '/progress.json', 'w')); "
                  "time.sleep(120)")


def _wait_for(predicate, timeout=20.0):
    t0 = time.time()
    while time.time() - t0 < timeout:
        if predicate():
            return True
        time.sleep(0.1)
    return False


def test_loopback_spawn_heartbeat_kill(tmp_path):
    """Spawn a fake campaign through the ssh code path: the pid file lands
    in the remote dir, the heartbeat is fetched from the remote
    progress.json, and signal() kills the remote process group."""
    ex = LoopbackExecutor(remote_root=str(tmp_path / "remote"))
    s = _shard(tmp_path, 0,
               cmd=[sys.executable, "-c", _FAKE_CAMPAIGN, "--out", "X"])
    rdir = Path(ex.remote_dir(s))
    ex.spawn(s)
    try:
        assert _wait_for(lambda: (rdir / "progress.json").exists()), \
            s.log_path.read_text()
        assert (rdir / PID_FILE).exists()
        assert ex.read_heartbeat(s) == {"cells_done": 1, "status": "running",
                                        "ts": 1.0}
        assert ex.poll(s) is None  # still running
        ex.signal(s, signal.SIGKILL)
        s.proc.wait(timeout=20)
        assert ex.poll(s) not in (None, 0)
    finally:
        ex.signal(s, signal.SIGKILL)
        s.close_log()
    # the local shard dir only holds the log until collect() mirrors it
    assert s.log_path.exists()
    assert not (s.out_dir / "progress.json").exists()
    ex.collect(s)
    assert (s.out_dir / "progress.json").exists()
    assert (s.out_dir / PID_FILE).exists()


def test_loopback_respawn_kills_stale_group(tmp_path):
    """A restart whose preceding kill round-trip was lost (transport
    outage) must not leave two campaigns sharing one shard dir: the spawn
    command kills any stale process group recorded in shard.pid first."""
    ex = LoopbackExecutor(remote_root=str(tmp_path / "remote"))
    s1 = _shard(tmp_path, 0,
                cmd=[sys.executable, "-c", _FAKE_CAMPAIGN, "--out", "X"])
    ex.spawn(s1)
    rdir = Path(ex.remote_dir(s1))
    assert _wait_for(lambda: (rdir / PID_FILE).exists()), "no pid file"
    stale_pid = int((rdir / PID_FILE).read_text())
    s2 = _shard(tmp_path, 0,
                cmd=[sys.executable, "-c", _FAKE_CAMPAIGN, "--out", "X"])
    ex.spawn(s2)  # no signal() first — simulates the lost kill
    try:
        assert _wait_for(lambda: s1.proc.poll() is not None), \
            "stale attempt survived the respawn"
        def new_pid_recorded():
            txt = (rdir / PID_FILE).read_text().strip()
            return txt.isdigit() and int(txt) != stale_pid
        assert _wait_for(new_pid_recorded), "pid file not re-stamped"
        assert ex.poll(s2) is None  # the new attempt is the one running
    finally:
        ex.signal(s2, signal.SIGKILL)
        ex.signal(s1, signal.SIGKILL)
        s1.close_log()
        s2.close_log()


def test_loopback_exit_code_propagates(tmp_path):
    ex = LoopbackExecutor(remote_root=str(tmp_path / "remote"))
    s = _shard(tmp_path, 0, cmd=[sys.executable, "-c",
                                 "import sys; sys.exit(86)", "--out", "X"])
    ex.spawn(s)
    try:
        assert _wait_for(lambda: ex.poll(s) is not None), "never exited"
        assert ex.poll(s) == 86  # os._exit(86)-style crashes stay visible
    finally:
        s.close_log()


def test_loopback_read_heartbeat_tolerates_missing_and_torn(tmp_path):
    ex = LoopbackExecutor(remote_root=str(tmp_path / "remote"))
    s = _shard(tmp_path, 0)
    assert ex.read_heartbeat(s) == {}  # no remote dir yet = no news
    rdir = Path(ex.remote_dir(s))
    rdir.mkdir(parents=True)
    (rdir / "progress.json").write_text('{"cells_done": ')  # torn
    assert ex.read_heartbeat(s) == {}
    (rdir / "progress.json").write_text('{"cells_done": 3}')
    assert ex.read_heartbeat(s) == {"cells_done": 3}


def test_loopback_collect_copies_and_skips_alias(tmp_path):
    ex = LoopbackExecutor(remote_root=str(tmp_path / "remote"))
    s = _shard(tmp_path, 0)
    rdir = Path(ex.remote_dir(s))
    (rdir / "reports").mkdir(parents=True)
    (rdir / "cost_db.jsonl").write_text('{"arch": "a"}\n')
    (rdir / "reports" / "c.json").write_text("{}")
    ex.collect(s)
    assert (s.out_dir / "cost_db.jsonl").read_text() == '{"arch": "a"}\n'
    assert (s.out_dir / "reports" / "c.json").exists()
    # a missing remote dir must fail loudly, not merge an empty shard
    s2 = _shard(tmp_path, 1)
    with pytest.raises(RuntimeError, match="collect failed"):
        ex.collect(s2)
    # no remote_root: remote dir IS the local dir — collect must not
    # attempt to copy a directory onto itself
    ex_alias = LoopbackExecutor()
    s3 = _shard(tmp_path, 2)
    s3.out_dir.mkdir(parents=True)
    ex_alias.collect(s3)  # no-op, no error


def test_local_executor_matches_shardproc_behavior(tmp_path):
    """The default backend is the original ShardProc lifecycle: local
    subprocess in its own session, heartbeat from the local shard dir."""
    ex = LocalProcessExecutor()
    s = _shard(tmp_path, 0, cmd=[sys.executable, "-c",
                                 "import time; time.sleep(120)"])
    ex.spawn(s)
    try:
        assert ex.poll(s) is None
        assert ex.read_heartbeat(s) == {}
        (s.out_dir / "progress.json").write_text('{"cells_done": 2}')
        assert ex.read_heartbeat(s) == {"cells_done": 2}
        ex.signal(s, signal.SIGKILL)
        s.proc.wait(timeout=20)
        assert ex.poll(s) not in (None, 0)
        ex.collect(s)  # no-op
    finally:
        ex.signal(s, signal.SIGKILL)
        s.close_log()
