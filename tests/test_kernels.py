"""Pallas kernels vs pure-jnp oracles: fixed cases + hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.resource_model import (flash_attention_resources,
                                          rmsnorm_resources, ssd_scan_resources,
                                          vecmul_resources)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# vecmul — the paper's §4 accelerator
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 5000), block=st.sampled_from([128, 256, 1024]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_vecmul_sweep(L, block, dtype):
    dt = jnp.dtype(dtype)
    x = jax.random.normal(jax.random.key(L), (L,)).astype(dt)
    y = jax.random.normal(jax.random.key(L + 1), (L,)).astype(dt)
    got = ops.vecmul(x, y, block=block)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.vecmul_ref(x, y), np.float32),
                               rtol=1e-6)


def test_vecmul_resources_feasible():
    r = vecmul_resources(4096, 1024, itemsize=4)
    assert r.feasible and r.vmem_util < 0.01
    r2 = vecmul_resources(1 << 26, 1 << 25, itemsize=4)  # absurd block
    assert not r2.feasible  # rejected as a negative datapoint


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 300), d=st.sampled_from([64, 128, 256]),
       block=st.sampled_from([32, 128]))
def test_rmsnorm_sweep(rows, d, block):
    x = jax.random.normal(jax.random.key(rows), (rows, d), jnp.float32)
    w = jax.random.normal(jax.random.key(d), (d,), jnp.float32)
    got = ops.rmsnorm(x, w, block_rows=block)
    np.testing.assert_allclose(got, ref.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([64, 128, 256]), h=st.sampled_from([4, 8]),
       kh=st.sampled_from([2, 4]), d=st.sampled_from([32, 64]),
       causal=st.booleans(), bq=st.sampled_from([32, 64]))
def test_flash_attention_sweep(sq, h, kh, d, causal, bq):
    if h % kh:
        kh = h
    b = 2
    q = 0.3 * jax.random.normal(jax.random.key(1), (b, sq, h, d))
    k = 0.3 * jax.random.normal(jax.random.key(2), (b, sq, kh, d))
    v = 0.3 * jax.random.normal(jax.random.key(3), (b, sq, kh, d))
    got = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bq)
    kr = jnp.repeat(k, h // kh, axis=2)
    vr = jnp.repeat(v, h // kh, axis=2)
    want = ref.attention_ref(q, kr, vr, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    b, s, h, d = 1, 128, 4, 64
    q = (0.3 * jax.random.normal(jax.random.key(1), (b, s, h, d))).astype(jnp.bfloat16)
    k = (0.3 * jax.random.normal(jax.random.key(2), (b, s, h, d))).astype(jnp.bfloat16)
    v = (0.3 * jax.random.normal(jax.random.key(3), (b, s, h, d))).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


def test_flash_resources_vmem_gate():
    ok = flash_attention_resources(1, 4096, 4096, 32, 8, 128, 512, 512)
    assert ok.feasible
    too_big = flash_attention_resources(1, 32768, 524288, 32, 8, 128, 32768, 32768)
    assert not too_big.feasible


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([32, 64, 128]), chunk=st.sampled_from([16, 32]),
       nh=st.sampled_from([2, 4]), N=st.sampled_from([16, 32]))
def test_ssd_sweep(s, chunk, nh, N):
    b, dh = 2, 16
    x = 0.5 * jax.random.normal(jax.random.key(1), (b, s, nh, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(2), (b, s, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(3), (nh,)))
    B = 0.3 * jax.random.normal(jax.random.key(4), (b, s, N))
    C = 0.3 * jax.random.normal(jax.random.key(5), (b, s, N))
    got_y, got_S = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want_y, want_S = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(got_y, want_y, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(got_S, want_S, rtol=3e-3, atol=3e-3)


def test_ssd_initial_state_threading():
    """Chunked scan with a carried initial state == one long exact scan."""
    b, s, nh, dh, N = 1, 64, 2, 16, 16
    x = 0.5 * jax.random.normal(jax.random.key(1), (b, s, nh, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(2), (b, s, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(3), (nh,)))
    B = 0.3 * jax.random.normal(jax.random.key(4), (b, s, N))
    C = 0.3 * jax.random.normal(jax.random.key(5), (b, s, N))
    _, S_half = ops.ssd_scan(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], chunk=16)
    y2, S_full = ops.ssd_scan(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
                              chunk=16, initial_state=S_half)
    want_y, want_S = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y2, want_y[:, 32:], rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(S_full, want_S, rtol=3e-3, atol=3e-3)


def test_ssd_resources():
    r = ssd_scan_resources(8, 4096, 48, 64, 128, 256)
    assert r.feasible
