"""Pallas kernels vs pure-jnp oracles: fixed cases + hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.resource_model import (flash_attention_resources,
                                          rmsnorm_resources, ssd_scan_resources,
                                          vecmul_resources)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# vecmul — the paper's §4 accelerator
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 5000), block=st.sampled_from([128, 256, 1024]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_vecmul_sweep(L, block, dtype):
    dt = jnp.dtype(dtype)
    x = jax.random.normal(jax.random.key(L), (L,)).astype(dt)
    y = jax.random.normal(jax.random.key(L + 1), (L,)).astype(dt)
    got = ops.vecmul(x, y, block=block)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.vecmul_ref(x, y), np.float32),
                               rtol=1e-6)


def test_vecmul_resources_feasible():
    r = vecmul_resources(4096, 1024, itemsize=4)
    assert r.feasible and r.vmem_util < 0.01
    r2 = vecmul_resources(1 << 26, 1 << 25, itemsize=4)  # absurd block
    assert not r2.feasible  # rejected as a negative datapoint


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 300), d=st.sampled_from([64, 128, 256]),
       block=st.sampled_from([32, 128]))
def test_rmsnorm_sweep(rows, d, block):
    x = jax.random.normal(jax.random.key(rows), (rows, d), jnp.float32)
    w = jax.random.normal(jax.random.key(d), (d,), jnp.float32)
    got = ops.rmsnorm(x, w, block_rows=block)
    np.testing.assert_allclose(got, ref.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(sq=st.sampled_from([64, 128, 256]), h=st.sampled_from([4, 8]),
       kh=st.sampled_from([2, 4]), d=st.sampled_from([32, 64]),
       causal=st.booleans(), bq=st.sampled_from([32, 64]))
def test_flash_attention_sweep(sq, h, kh, d, causal, bq):
    if h % kh:
        kh = h
    b = 2
    q = 0.3 * jax.random.normal(jax.random.key(1), (b, sq, h, d))
    k = 0.3 * jax.random.normal(jax.random.key(2), (b, sq, kh, d))
    v = 0.3 * jax.random.normal(jax.random.key(3), (b, sq, kh, d))
    got = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bq)
    kr = jnp.repeat(k, h // kh, axis=2)
    vr = jnp.repeat(v, h // kh, axis=2)
    want = ref.attention_ref(q, kr, vr, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    b, s, h, d = 1, 128, 4, 64
    q = (0.3 * jax.random.normal(jax.random.key(1), (b, s, h, d))).astype(jnp.bfloat16)
    k = (0.3 * jax.random.normal(jax.random.key(2), (b, s, h, d))).astype(jnp.bfloat16)
    v = (0.3 * jax.random.normal(jax.random.key(3), (b, s, h, d))).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=3e-2, atol=3e-2)


def test_flash_resources_vmem_gate():
    ok = flash_attention_resources(1, 4096, 4096, 32, 8, 128, 512, 512)
    assert ok.feasible
    too_big = flash_attention_resources(1, 32768, 524288, 32, 8, 128, 32768, 32768)
    assert not too_big.feasible


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([32, 64, 128]), chunk=st.sampled_from([16, 32]),
       nh=st.sampled_from([2, 4]), N=st.sampled_from([16, 32]))
def test_ssd_sweep(s, chunk, nh, N):
    b, dh = 2, 16
    x = 0.5 * jax.random.normal(jax.random.key(1), (b, s, nh, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(2), (b, s, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(3), (nh,)))
    B = 0.3 * jax.random.normal(jax.random.key(4), (b, s, N))
    C = 0.3 * jax.random.normal(jax.random.key(5), (b, s, N))
    got_y, got_S = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want_y, want_S = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(got_y, want_y, rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(got_S, want_S, rtol=3e-3, atol=3e-3)


def test_ssd_initial_state_threading():
    """Chunked scan with a carried initial state == one long exact scan."""
    b, s, nh, dh, N = 1, 64, 2, 16, 16
    x = 0.5 * jax.random.normal(jax.random.key(1), (b, s, nh, dh))
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(2), (b, s, nh)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.key(3), (nh,)))
    B = 0.3 * jax.random.normal(jax.random.key(4), (b, s, N))
    C = 0.3 * jax.random.normal(jax.random.key(5), (b, s, N))
    _, S_half = ops.ssd_scan(x[:, :32], dt[:, :32], A, B[:, :32], C[:, :32], chunk=16)
    y2, S_full = ops.ssd_scan(x[:, 32:], dt[:, 32:], A, B[:, 32:], C[:, 32:],
                              chunk=16, initial_state=S_half)
    want_y, want_S = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y2, want_y[:, 32:], rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(S_full, want_S, rtol=3e-3, atol=3e-3)


def test_ssd_resources():
    r = ssd_scan_resources(8, 4096, 48, 64, 128, 256)
    assert r.feasible


# ---------------------------------------------------------------------------
# full-grid conformance: every legal template point of every registry shape
# passes the correctness gate (the DSE engine's oracle check)
# ---------------------------------------------------------------------------
def _grid_cases():
    import itertools

    from repro.core.kernel_space import (KERNEL_SHAPES, KernelShape,
                                         legal_kernel_dims)

    shapes = list(KERNEL_SHAPES) + [
        # odd / non-divisible sizes: the kernels' internal padding paths
        KernelShape("rms_odd_173x96_f32", "rmsnorm",
                    {"rows": 173, "d": 96}, "float32"),
        KernelShape("vec_odd_5000_bf16", "vecmul", {"L": 5000}, "bfloat16"),
    ]
    cases = []
    for shape in shapes:
        pools = legal_kernel_dims(shape)
        keys = sorted(pools)
        for combo in itertools.product(*(pools[k] for k in keys)):
            dims = dict(zip(keys, combo))
            label = shape.name + "-" + ",".join(f"{k}={v}" for k, v in dims.items())
            cases.append(pytest.param(shape, dims, id=label))
    return cases


@pytest.mark.parametrize("shape,dims", _grid_cases())
def test_kernel_grid_conformance(shape, dims):
    from repro.kernels.conformance import check_candidate

    res = check_candidate(shape, dims, interpret=True)
    assert res["passed"], (
        f"{shape.name} {dims}: max|err|={res['max_abs_err']:.3g} "
        f"> tol={res['tol']:.3g}")
    assert res["max_abs_err"] <= res["tol"]


def test_legal_pools_respect_divisibility():
    from repro.core.kernel_space import (KERNEL_SHAPE_BY_NAME,
                                         legal_kernel_dims)

    attn = legal_kernel_dims(KERNEL_SHAPE_BY_NAME["attn_s128_f32"])
    assert attn["block_q"] == (64, 128) and attn["block_k"] == (64, 128)
    ssd = legal_kernel_dims(KERNEL_SHAPE_BY_NAME["ssd_s256_f32"])
    assert all(256 % c == 0 for c in ssd["chunk"])
    # rmsnorm/vecmul pad internally: pools pass through unfiltered
    rms = legal_kernel_dims(KERNEL_SHAPE_BY_NAME["rms_1kx256_bf16"])
    assert rms["block_rows"] == (32, 64, 128, 256)


def test_default_kernel_dims_snap_to_legal():
    from repro.core.kernel_space import (KERNEL_SHAPE_BY_NAME,
                                         default_kernel_dims,
                                         legal_kernel_dims)

    # block_q/block_k=512 defaults snap down to 128 on a 128-long sequence
    shape = KERNEL_SHAPE_BY_NAME["attn_s128_f32"]
    d = default_kernel_dims(shape)
    assert d == {"block_q": 128, "block_k": 128, "causal": True}
    for s in KERNEL_SHAPE_BY_NAME.values():
        legal = legal_kernel_dims(s)
        assert all(v in legal[k] for k, v in default_kernel_dims(s).items())


# ---------------------------------------------------------------------------
# resource model: closed-form arithmetic against the device constants
# ---------------------------------------------------------------------------
def test_vecmul_resources_closed_form():
    from repro.core.device import TPU_V5E

    L, block, isz = 65536, 1024, 4
    r = vecmul_resources(L, block, itemsize=isz)
    assert r.vmem_bytes == 2 * 3 * block * isz  # X,Y,Z double-buffered
    assert r.vmem_util == pytest.approx(r.vmem_bytes / TPU_V5E.vmem_bytes)
    t_block = max(block / TPU_V5E.peak_flops_bf16,
                  3 * block * isz / TPU_V5E.hbm_bw)
    assert r.est_latency_us == pytest.approx(t_block * (L // block) * 1e6)
    assert r.est_cycles_per_block == pytest.approx(t_block * 940e6)
    assert r.mxu_aligned  # vecmul never touches the MXU
    assert vecmul_resources(4096, 1024).vpu_aligned  # 1024 = 8*128
    assert not vecmul_resources(4096, 512).vpu_aligned


def test_rmsnorm_resources_closed_form():
    from repro.core.device import TPU_V5E

    rows, d, br, isz = 1024, 256, 128, 2
    r = rmsnorm_resources(rows, d, br, itemsize=isz)
    assert r.vmem_bytes == 2 * ((2 * br * d + d) * isz + br * 4)
    assert r.est_latency_us == pytest.approx(
        max(3 * br * d / TPU_V5E.peak_flops_bf16,
            2 * br * d * isz / TPU_V5E.hbm_bw) * (rows // br) * 1e6)
    assert rmsnorm_resources(64, 256, 32).vpu_aligned  # d % 128 == 0
    assert not rmsnorm_resources(64, 96, 32).vpu_aligned
    # ceil-div block count: 173 rows at block 128 -> 2 blocks
    a = rmsnorm_resources(173, 128, 128)
    b = rmsnorm_resources(256, 128, 128)
    assert a.est_latency_us == pytest.approx(b.est_latency_us)


def test_flash_resources_closed_form():
    b, sq, sk, h, kh, d, bq, bk, isz = 2, 128, 128, 4, 4, 64, 64, 64, 4
    r = flash_attention_resources(b, sq, sk, h, kh, d, bq, bk, itemsize=isz)
    vmem = (bq * d + 2 * bk * d) * isz + bq * d * 4 + 2 * bq * 4 + bq * bk * 4
    assert r.vmem_bytes == 2 * vmem
    assert r.feasible and not r.mxu_aligned  # 64-tiles miss the 128 MXU edge
    full = flash_attention_resources(1, 256, 256, 8, 8, 128, 128, 128)
    assert full.mxu_aligned
    # halving block_q doubles the block count and re-streams the full K/V
    # window per block: total latency goes UP — the roofline term the DSE
    # engine actually optimizes against
    r2 = flash_attention_resources(b, sq, sk, h, kh, d, bq // 2, bk, itemsize=isz)
    assert r2.est_latency_us > r.est_latency_us


def test_ssd_resources_closed_form():
    b, s, nh, dh, N, chunk, isz = 1, 256, 4, 32, 32, 64, 4
    r = ssd_scan_resources(b, s, nh, dh, N, chunk, itemsize=isz)
    vmem = (chunk * nh * dh + chunk * nh + 2 * chunk * N) * isz \
        + chunk * chunk * nh * 4 + chunk * nh * dh * 4 + nh * dh * N * 4
    assert r.vmem_bytes == 2 * vmem
    assert r.feasible
    assert not r.mxu_aligned and r.vpu_aligned  # chunk=64 < 128; dh % 8 == 0


def test_resource_model_feasibility_boundary():
    """The double-buffered footprint is what is charged against VMEM: a
    block just under half the budget is feasible, just over is not."""
    from repro.core.device import TPU_V5E

    half = TPU_V5E.vmem_bytes // 2
    block_ok = half // (3 * 4)           # 3 f32 buffers, double-buffered
    assert vecmul_resources(1 << 26, block_ok, itemsize=4).feasible
    assert not vecmul_resources(1 << 26, block_ok + 1, itemsize=4).feasible
