"""Serving: batcher end-to-end + sequence-parallel decode attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serve.batcher import Batcher
from repro.serve import step as serve_step
from repro.sharding.plan import ShardingPlan


def test_batcher_end_to_end():
    cfg = reduced(get_config("qwen3-0.6b"))
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    plan = ShardingPlan(rules={})
    prefill = jax.jit(serve_step.make_prefill_step(cfg, plan, None))
    decode = jax.jit(serve_step.make_decode_step(cfg, plan, None))

    b = Batcher(cfg, params, prefill, decode,
                init_cache=lambda bs, ml: M.init_cache(cfg, bs, ml),
                max_batch=3, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [b.submit(rng.integers(0, cfg.vocab, size=n), max_new=6)
            for n in (5, 9, 3, 7)]  # 4 requests > max_batch: two waves
    done = b.run()
    assert len(done) == 4
    assert all(r.done and len(r.out) == 6 for r in done)
    assert b.stats["tokens"] == 24
    assert b.stats["tok_per_s"] > 0


def test_sp_decode_attention_matches_reference():
    """shard_map flash-decoding == dense decode attention, incl. cache insert."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.serve.sp_attention import make_sp_decode
        from repro.sharding.plan import ShardingPlan, baseline_rules
        from repro.models.layers import decode_attention

        mesh = make_mesh((2, 4), ("data", "model"))
        plan = ShardingPlan(rules=baseline_rules())
        sp = make_sp_decode(mesh, plan)
        b, S, h, kh, d = 4, 32, 8, 4, 16
        key = jax.random.key
        q = 0.5 * jax.random.normal(key(0), (b, 1, h, d))
        k_new = 0.5 * jax.random.normal(key(1), (b, 1, kh, d))
        v_new = 0.5 * jax.random.normal(key(2), (b, 1, kh, d))
        kc = 0.5 * jax.random.normal(key(3), (b, S, kh, d))
        vc = 0.5 * jax.random.normal(key(4), (b, S, kh, d))
        ln = jnp.array([5, 13, 29, 31], jnp.int32)  # filled lengths
        slot, kv_len = ln, ln + 1

        with mesh:
            o, kc2, vc2 = jax.jit(sp)(q, k_new, v_new, kc, vc, slot, kv_len)

        # reference: dense insert + decode attention
        bidx = jnp.arange(b)[:, None]
        kref = kc.at[bidx, ln[:, None]].set(k_new)
        vref = vc.at[bidx, ln[:, None]].set(v_new)
        want = decode_attention(q, kref, vref, kv_len)
        np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(kc2), np.asarray(kref), atol=1e-6)
        print("SP_DECODE_OK")
    """, n_devices=8)
    assert "SP_DECODE_OK" in out


def test_decode_step_with_sp_plan_small_mesh():
    """A full decode step with decode_attn=sp_shardmap lowers and runs."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_mesh
        from repro.models import model as M
        from repro.serve import step as serve_step
        from repro.sharding.plan import ShardingPlan, baseline_rules

        cfg = reduced(get_config("llama3-8b"), n_kv_heads=2, n_heads=4)
        mesh = make_mesh((2, 2), ("data", "model"))
        plan = ShardingPlan(rules=baseline_rules(), decode_attn="sp_shardmap")
        params, _ = M.materialize_params(cfg, jax.random.key(0))
        cache = M.init_cache(cfg, 4, 32)
        # prefill 8 tokens with the plain path, then sp-decode one token
        toks = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab)
        prefill = serve_step.make_prefill_step(cfg, plan, mesh)
        with mesh:
            lp, cache = jax.jit(prefill)(params, {"tokens": toks}, cache)
            decode = serve_step.make_decode_step(cfg, plan, mesh)
            nxt = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
            ld, cache2 = jax.jit(decode)(params, {"tokens": nxt}, cache)
        # reference: no-sp decode
        plan0 = ShardingPlan(rules=baseline_rules(), decode_attn="gspmd")
        decode0 = serve_step.make_decode_step(cfg, plan0, None)
        ld0, _ = jax.jit(decode0)(params, {"tokens": nxt}, cache)
        np.testing.assert_allclose(np.asarray(ld, np.float32),
                                   np.asarray(ld0, np.float32), rtol=2e-2, atol=2e-2)
        print("SP_DECODE_STEP_OK")
    """, n_devices=8)
    assert "SP_DECODE_STEP_OK" in out
