"""Serving: batcher end-to-end + sequence-parallel decode attention."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serve.batcher import Batcher
from repro.serve import step as serve_step
from repro.sharding.plan import ShardingPlan


@pytest.fixture(scope="module")
def serving_stack():
    cfg = reduced(get_config("qwen3-0.6b"))
    params, _ = M.materialize_params(cfg, jax.random.key(0))
    plan = ShardingPlan(rules={})
    prefill = jax.jit(serve_step.make_prefill_step(cfg, plan, None))
    decode = jax.jit(serve_step.make_decode_step(cfg, plan, None))
    return cfg, params, prefill, decode


def _make_batcher(serving_stack, **kw):
    cfg, params, prefill, decode = serving_stack
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    return Batcher(cfg, params, prefill, decode,
                   init_cache=lambda bs, ml: M.init_cache(cfg, bs, ml), **kw)


def _static_wave_outputs(serving_stack, prompts, max_news, max_batch,
                         max_len=64):
    """The pre-continuous-batching reference: waves of ``max_batch`` decoded
    in lock-step to the wave-max ``max_new``. Returns (per-request outputs,
    total decode steps)."""
    cfg, params, prefill, decode = serving_stack
    outs = [[] for _ in prompts]
    n_steps = 0
    start = 0
    while start < len(prompts):
        idx = list(range(start, min(start + max_batch, len(prompts))))
        start += len(idx)
        plen = max(len(prompts[j]) for j in idx)
        toks = np.zeros((len(idx), plen), np.int32)
        for k, j in enumerate(idx):
            toks[k, plen - len(prompts[j]):] = prompts[j]
        cache = M.init_cache(cfg, len(idx), max_len)
        logits, cache = prefill(params, {"tokens": jnp.asarray(toks)}, cache)
        cur = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
        for k, j in enumerate(idx):
            outs[j].append(int(cur[k]))
        active = [True] * len(idx)
        steps = 0
        while any(active) and steps < max(max_news[j] for j in idx) - 1:
            logits, cache = decode(params, {"tokens": jnp.asarray(cur[:, None])},
                                   cache)
            cur = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
            steps += 1
            n_steps += 1
            for k, j in enumerate(idx):
                if active[k]:
                    outs[j].append(int(cur[k]))
                    if len(outs[j]) >= max_news[j]:
                        active[k] = False
    return outs, n_steps


def test_batcher_end_to_end(serving_stack):
    b = _make_batcher(serving_stack)
    rng = np.random.default_rng(0)
    reqs = [b.submit(rng.integers(0, b.cfg.vocab, size=n), max_new=6)
            for n in (5, 9, 3, 7)]  # 4 requests > max_batch: two waves
    done = b.run()
    assert len(done) == 4
    assert all(r.done and len(r.out) == 6 for r in done)
    assert b.stats["tokens"] == 24
    assert b.stats["tok_per_s"] > 0


def test_batcher_rids_unique_across_interleaved_runs(serving_stack):
    """Regression: rid=len(queue) recycled ids once requests were popped;
    interleaved submit/run must still hand out unique rids."""
    b = _make_batcher(serving_stack, max_batch=2)
    rng = np.random.default_rng(1)
    first = [b.submit(rng.integers(0, b.cfg.vocab, size=4), max_new=2)
             for _ in range(2)]
    b.run()
    second = [b.submit(rng.integers(0, b.cfg.vocab, size=4), max_new=2)
              for _ in range(2)]
    b.run()
    rids = [r.rid for r in first + second]
    assert len(set(rids)) == 4, rids


def test_batcher_refills_freed_slots(serving_stack):
    """Continuous batching: freed slots are refilled mid-decode, so a
    mixed-``max_new`` workload takes fewer decode steps than the static-wave
    schedule while every request's tokens stay byte-identical.

    Prompts share one length so the left-pad seen by each request is the
    same under both schedules (padding is attended, so unequal prompt
    lengths would legitimately change logits between groupings)."""
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 128, size=6) for _ in range(4)]
    max_news = [2, 8, 2, 8]
    want, static_steps = _static_wave_outputs(serving_stack, prompts,
                                              max_news, max_batch=2)

    b = _make_batcher(serving_stack, max_batch=2)
    reqs = [b.submit(p, max_new=mn) for p, mn in zip(prompts, max_news)]
    done = b.run()
    assert len(done) == 4 and all(r.done for r in done)
    assert [r.out for r in reqs] == want
    assert all(len(r.out) == r.max_new for r in reqs)
    # static schedule: two waves of max(2,8)-1 decode steps each = 14;
    # refilling freed slots interleaves the short requests instead
    assert b.stats["decode_steps"] < static_steps
    assert b.stats["prefills"] == 3  # initial wave + two single-slot admits


def test_batcher_t_done_marks_actual_completion(serving_stack):
    """Regression: the post-loop backstop stamped queue-drain time onto
    early finishers (and a max_new=1 request overshot its token budget)."""
    rng = np.random.default_rng(3)
    b = _make_batcher(serving_stack, max_batch=2)
    short = b.submit(rng.integers(0, b.cfg.vocab, size=5), max_new=1)
    long = b.submit(rng.integers(0, b.cfg.vocab, size=5), max_new=8)
    done = b.run()
    t_end = time.time()
    assert [r.rid for r in done] == [short.rid, long.rid]
    assert len(short.out) == 1  # exactly max_new, not one step of overshoot
    assert short.t_done is not None and long.t_done is not None
    assert short.t_done < long.t_done <= t_end


def test_sp_decode_attention_matches_reference():
    """shard_map flash-decoding == dense decode attention, incl. cache insert."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.serve.sp_attention import make_sp_decode
        from repro.sharding.plan import ShardingPlan, baseline_rules
        from repro.models.layers import decode_attention

        mesh = make_mesh((2, 4), ("data", "model"))
        plan = ShardingPlan(rules=baseline_rules())
        sp = make_sp_decode(mesh, plan)
        b, S, h, kh, d = 4, 32, 8, 4, 16
        key = jax.random.key
        q = 0.5 * jax.random.normal(key(0), (b, 1, h, d))
        k_new = 0.5 * jax.random.normal(key(1), (b, 1, kh, d))
        v_new = 0.5 * jax.random.normal(key(2), (b, 1, kh, d))
        kc = 0.5 * jax.random.normal(key(3), (b, S, kh, d))
        vc = 0.5 * jax.random.normal(key(4), (b, S, kh, d))
        ln = jnp.array([5, 13, 29, 31], jnp.int32)  # filled lengths
        slot, kv_len = ln, ln + 1

        with mesh:
            o, kc2, vc2 = jax.jit(sp)(q, k_new, v_new, kc, vc, slot, kv_len)

        # reference: dense insert + decode attention
        bidx = jnp.arange(b)[:, None]
        kref = kc.at[bidx, ln[:, None]].set(k_new)
        vref = vc.at[bidx, ln[:, None]].set(v_new)
        want = decode_attention(q, kref, vref, kv_len)
        np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(kc2), np.asarray(kref), atol=1e-6)
        print("SP_DECODE_OK")
    """, n_devices=8)
    assert "SP_DECODE_OK" in out


def test_decode_step_with_sp_plan_small_mesh():
    """A full decode step with decode_attn=sp_shardmap lowers and runs."""
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced
        from repro.launch.mesh import make_mesh
        from repro.models import model as M
        from repro.serve import step as serve_step
        from repro.sharding.plan import ShardingPlan, baseline_rules

        cfg = reduced(get_config("llama3-8b"), n_kv_heads=2, n_heads=4)
        mesh = make_mesh((2, 2), ("data", "model"))
        plan = ShardingPlan(rules=baseline_rules(), decode_attn="sp_shardmap")
        params, _ = M.materialize_params(cfg, jax.random.key(0))
        cache = M.init_cache(cfg, 4, 32)
        # prefill 8 tokens with the plain path, then sp-decode one token
        toks = jax.random.randint(jax.random.key(1), (4, 8), 0, cfg.vocab)
        prefill = serve_step.make_prefill_step(cfg, plan, mesh)
        with mesh:
            lp, cache = jax.jit(prefill)(params, {"tokens": toks}, cache)
            decode = serve_step.make_decode_step(cfg, plan, mesh)
            nxt = jnp.argmax(lp[:, -1], -1)[:, None].astype(jnp.int32)
            ld, cache2 = jax.jit(decode)(params, {"tokens": nxt}, cache)
        # reference: no-sp decode
        plan0 = ShardingPlan(rules=baseline_rules(), decode_attn="gspmd")
        decode0 = serve_step.make_decode_step(cfg, plan0, None)
        ld0, _ = jax.jit(decode0)(params, {"tokens": nxt}, cache)
        np.testing.assert_allclose(np.asarray(ld, np.float32),
                                   np.asarray(ld0, np.float32), rtol=2e-2, atol=2e-2)
        print("SP_DECODE_STEP_OK")
    """, n_devices=8)
    assert "SP_DECODE_STEP_OK" in out
